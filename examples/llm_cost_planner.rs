//! LLM CPU-vs-GPU deployment cost planner (paper §6.9, Table 9).
//!
//! The paper argues big-memory CPU instances beat multi-GPU setups for
//! LLaMA-65B inference on cost and slightly on throughput. This planner
//! reproduces the Table 9 arithmetic with the paper's published constants
//! and lets you explore other model sizes / instance counts.
//!
//! ```sh
//! cargo run --release --example llm_cost_planner [model_params_B]
//! ```

use attmemo::bench_support::TableWriter;

/// Instance types with paper-published characteristics (Table 9 context).
#[derive(Clone, Copy)]
struct Instance {
    name: &'static str,
    /// tokens/s for LLaMA-65B on ONE instance (paper's measurements:
    /// 4 GPU instances → 5.54 tok/s total; 1 CPU instance → 1.01).
    tokens_per_s: f64,
    /// Hardware acquisition cost per instance ($).
    acq_cost: f64,
    /// Cloud cost per hour per instance ($, Oracle list prices the paper
    /// cites).
    cloud_per_hr: f64,
    /// Usable memory per instance (GB).
    mem_gb: f64,
}

const GPU_INST: Instance = Instance {
    name: "2xA10 GPU instance",
    tokens_per_s: 5.54 / 4.0, // paper measured 4 instances together
    acq_cost: 61_200.0 / 4.0,
    cloud_per_hr: 1.6 / 4.0,
    mem_gb: 48.0, // 2 × 24 GB
};

const CPU_INST: Instance = Instance {
    name: "64c/1TB CPU instance",
    tokens_per_s: 1.01,
    acq_cost: 7_900.0,
    cloud_per_hr: 0.88 / 6.0, // paper: 6 instances at $0.88/hr total
    mem_gb: 1024.0,
};

/// LLaMA-65B needs 147 GB (paper); scale linearly for other sizes.
fn model_mem_gb(params_b: f64) -> f64 {
    147.0 * params_b / 65.0
}

/// Near-linear multi-instance scaling with the paper's observed efficiency
/// (6 CPU instances: 6.06/1.01 = 6.0× ⇒ ~1.0; 8 GPUs over EoIB: 5.54 over
/// 4 instances ⇒ interconnect-bound, efficiency already folded into the
/// per-instance number).
fn throughput(inst: Instance, n: usize) -> f64 {
    inst.tokens_per_s * n as f64
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let params_b: f64 = args.get(1).and_then(|s| s.parse().ok())
        .unwrap_or(65.0);
    let need_gb = model_mem_gb(params_b);
    println!("model: {params_b:.0}B params → {need_gb:.0} GB inference \
              footprint");

    let mut t = TableWriter::new(
        "Table 9 reproduction — LLM deployment cost model",
        &["config", "fits?", "tokens/s", "acq cost ($)", "cloud $/hr",
          "$ per 1M tokens (cloud)"],
    );
    let configs: [(Instance, usize); 4] =
        [(GPU_INST, 4), (CPU_INST, 1), (CPU_INST, 6), (GPU_INST, 8)];
    for (inst, n) in configs {
        let mem = inst.mem_gb * n as f64;
        let fits = mem >= need_gb;
        let tps = throughput(inst, n);
        let cloud = inst.cloud_per_hr * n as f64;
        let per_m = if tps > 0.0 { cloud / (tps * 3600.0) * 1e6 } else { 0.0 };
        t.row(&[
            format!("{} x{}", inst.name, n),
            fits.to_string(),
            format!("{tps:.2}"),
            format!("{:.0}", inst.acq_cost * n as f64),
            format!("{cloud:.2}"),
            format!("{per_m:.2}"),
        ]);
    }
    t.emit(Some(std::path::Path::new("bench_results/table9_cost.csv")));

    // The paper's headline claims, derived from the same numbers:
    let gpu4 = throughput(GPU_INST, 4);
    let cpu6 = throughput(CPU_INST, 6);
    println!("6 CPU instances vs 4 GPU instances: {:.1}% faster, {:.2}x \
              cheaper to acquire, {:.1}x cheaper on cloud",
             (cpu6 / gpu4 - 1.0) * 100.0,
             (GPU_INST.acq_cost * 4.0) / (CPU_INST.acq_cost * 6.0),
             (GPU_INST.cloud_per_hr * 4.0) / (CPU_INST.cloud_per_hr * 6.0));
}
