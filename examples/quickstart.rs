//! Quickstart: load the artifacts, build a small attention database, and
//! run one memoized inference against the baseline.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use attmemo::bench_support::workload;
use attmemo::config::MemoLevel;
use attmemo::data::tokenizer::Vocab;

fn main() -> attmemo::Result<()> {
    attmemo::util::logger::init();
    let rt = workload::open_runtime()?;
    let seq_len = rt.artifacts().serving_seq_len;
    let vocab = Vocab::load(&rt.artifacts().root().join("vocab.json"))?;

    println!("== AttMemo quickstart (family: bert) ==");
    println!("building attention database from 128 training sequences…");
    let mut engine = workload::engine_with_db(
        &rt, "bert", seq_len, MemoLevel::Moderate, 128, true)?;
    let mut baseline = workload::engine_with_db(
        &rt, "bert", seq_len, MemoLevel::Off, 0, false)?;

    let texts = [
        "the film was wonderful and the ending was superb",
        "a truly dreadful plot with lifeless acting",
        "critics felt the story was not terrible",
    ];
    for text in texts {
        let ids = vocab.encode(text, seq_len);
        let batch = attmemo::tensor::tensor::IdTensor::new(
            vec![1, seq_len], ids)?;

        let b = baseline.infer_baseline(&batch)?;
        let m = engine.infer(&batch)?;
        println!("\n  input: {text:?}");
        println!(
            "  baseline : label={} ({:.1} ms)",
            b.labels[0],
            b.seconds * 1e3
        );
        println!(
            "  attmemo  : label={} memoized_layers={}/{} ({:.1} ms)",
            m.labels[0],
            m.memo_hits[0],
            engine.runner().config().layers,
            m.seconds * 1e3
        );
    }
    println!(
        "\nengine memoization rate so far: {:.1} %",
        engine.stats.memoization_rate() * 100.0
    );
    Ok(())
}
