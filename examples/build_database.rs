//! Offline attention-database population (paper §5.1 / Table 3 flavour).
//!
//! Ingests training sequences for one family, then prints database size,
//! indexing time, calibrated thresholds and the per-layer Eq. 3 profile.
//!
//! ```sh
//! cargo run --release --example build_database [family] [db_seqs]
//! ```

use attmemo::bench_support::{workload, TableWriter};

fn main() -> attmemo::Result<()> {
    attmemo::util::logger::init();
    let args: Vec<String> = std::env::args().collect();
    let family = args.get(1).cloned().unwrap_or_else(|| "bert".into());
    let db_seqs: usize =
        args.get(2).and_then(|s| s.parse().ok()).unwrap_or(256);

    let rt = workload::open_runtime()?;
    let seq_len = rt.artifacts().serving_seq_len;
    println!("building {family} attention database from {db_seqs} sequences \
              (seq_len {seq_len})…");
    let built = workload::build_db(&rt, &family, seq_len, db_seqs)?;

    println!("\nsequences ingested : {}", built.sequences);
    println!("entries            : {}", built.db.total_entries());
    println!("database size      : {:.1} MiB",
             built.db.resident_bytes() as f64 / (1 << 20) as f64);
    println!("indexing time      : {:.2} s", built.indexing_seconds);
    println!("total build time   : {:.2} s", built.build_seconds);
    println!("thresholds         : conservative={:.4} moderate={:.4} \
              aggressive={:.4}",
             built.thresholds.conservative, built.thresholds.moderate,
             built.thresholds.aggressive);

    let mut t = TableWriter::new(
        "Per-layer Eq. 3 profile (selective memoization inputs)",
        &["layer", "t_attn (s)", "t_overhead (s)", "alpha", "PB>0?"],
    );
    for (li, p) in built.profiles.iter().enumerate() {
        let pb = p.t_attn * p.alpha - p.t_overhead;
        t.row(&[
            li.to_string(),
            format!("{:.4}", p.t_attn),
            format!("{:.4}", p.t_overhead),
            format!("{:.3}", p.alpha),
            format!("{}", pb > 0.0),
        ]);
    }
    t.emit(None);
    Ok(())
}
