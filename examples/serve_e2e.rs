//! End-to-end serving driver (the DESIGN.md §5 mandated validation run).
//!
//! Builds the attention database, starts the real TCP server with the
//! dynamic batcher, drives it with concurrent clients sending
//! template-generated requests, and reports latency / throughput /
//! memoization-rate / accuracy against the no-memoization baseline.
//! Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example serve_e2e [requests] [clients]
//! ```

use std::sync::Arc;

use attmemo::bench_support::workload;
use attmemo::config::{MemoLevel, ServingConfig};
use attmemo::data::synth::SynthGen;
use attmemo::data::tokenizer::Vocab;
use attmemo::serving::server::{Client, Server};
use attmemo::util::stats::{Stopwatch, Summary};

fn run_load(addr: &str, vocab: &Vocab, requests: usize, clients: usize,
            seed: u64) -> attmemo::Result<(Summary, usize, usize, u64)> {
    // Generate labelled workload up front so accuracy is measurable.
    let dir = workload::artifacts_dir();
    let mut gen = SynthGen::load(&dir.join("templates.json"), seed)?;
    let mut texts = Vec::with_capacity(requests);
    for _ in 0..requests {
        let (ids, label) = gen.gen_sequence(96)?;
        texts.push((vocab.decode(&ids[1..]).replace("[sep]", " "), label));
    }

    let texts = Arc::new(texts);
    let addr = addr.to_string();
    let mut handles = Vec::new();
    for c in 0..clients {
        let texts = texts.clone();
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> attmemo::Result<_> {
            let mut client = Client::connect(&addr)?;
            let mut lat = Summary::new();
            let mut correct = 0usize;
            let mut n = 0usize;
            let mut hits = 0u64;
            for (i, (text, label)) in texts.iter().enumerate() {
                if i % clients != c {
                    continue;
                }
                let (pred, memo_hits, ms) = client.infer(text)?;
                lat.record(ms);
                hits += memo_hits as u64;
                if pred == *label {
                    correct += 1;
                }
                n += 1;
            }
            client.quit()?;
            Ok((lat, correct, n, hits))
        }));
    }
    let mut all = Summary::new();
    let (mut correct, mut total, mut hits) = (0usize, 0usize, 0u64);
    for h in handles {
        let (lat, c, n, hh) = h.join().expect("client thread")?;
        correct += c;
        total += n;
        hits += hh;
        all.merge(&lat);
    }
    Ok((all, correct, total, hits))
}

fn serve_once(level: MemoLevel, requests: usize, clients: usize)
    -> attmemo::Result<()> {
    let rt = workload::open_runtime()?;
    let seq_len = rt.artifacts().serving_seq_len;
    let vocab = Arc::new(Vocab::load(
        &rt.artifacts().root().join("vocab.json"))?);

    let db_seqs = if level == MemoLevel::Off { 0 } else { 256 };
    println!("\n== level={} (db_seqs={db_seqs}) ==", level.name());
    let engine = workload::engine_with_db(
        &rt, "bert", seq_len, level, db_seqs, true)?;

    let cfg = ServingConfig {
        seq_len,
        bind: "127.0.0.1:0".into(), // ephemeral port
        max_batch: 8,
        ..ServingConfig::default()
    };
    let server = Server::start(vec![engine], vocab.clone(), cfg)?;
    let addr = server.addr.to_string();

    let sw = Stopwatch::start();
    let (mut lat, correct, total, hits) =
        run_load(&addr, &vocab, requests, clients, 424242)?;
    let secs = sw.secs();

    println!("  requests      : {total} via {clients} clients");
    println!("  throughput    : {:.2} req/s", total as f64 / secs);
    println!("  mean latency  : {:.1} ms (per-client means, p50 {:.1})",
             lat.mean(), lat.p50());
    println!("  accuracy      : {:.3}", correct as f64 / total.max(1) as f64);
    println!("  memoized lyrs : {:.2} per request",
             hits as f64 / total.max(1) as f64);
    server.shutdown();
    Ok(())
}

fn main() -> attmemo::Result<()> {
    attmemo::util::logger::init();
    let args: Vec<String> = std::env::args().collect();
    let requests: usize = args.get(1).and_then(|s| s.parse().ok())
        .unwrap_or(48);
    let clients: usize = args.get(2).and_then(|s| s.parse().ok())
        .unwrap_or(4);
    println!("end-to-end serving driver: {requests} requests, {clients} \
              concurrent clients, model=bert");
    serve_once(MemoLevel::Off, requests, clients)?;
    serve_once(MemoLevel::Moderate, requests, clients)?;
    Ok(())
}
