//! Memoization-threshold sweep (paper Fig. 4): threshold 1 → low, measuring
//! memoization rate and accuracy at each point.
//!
//! ```sh
//! cargo run --release --example accuracy_sweep [family] [n_test]
//! ```

use attmemo::bench_support::{workload, TableWriter};
use attmemo::config::{MemoConfig, MemoLevel};
use attmemo::eval::evaluate;
use attmemo::model::ModelRunner;
use attmemo::serving::engine::{Engine, EngineOptions};

fn main() -> attmemo::Result<()> {
    attmemo::util::logger::init();
    let args: Vec<String> = std::env::args().collect();
    let family = args.get(1).cloned().unwrap_or_else(|| "bert".into());
    let n: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(32);

    let rt = workload::open_runtime()?;
    let seq_len = rt.artifacts().serving_seq_len;
    let (ids, labels) = workload::test_workload(&rt, &family, seq_len, n)?;

    println!("building database once (256 seqs)…");
    let built = std::sync::Arc::new(
        workload::build_db(&rt, &family, seq_len, 256)?);
    // Sweep around the calibrated range, down to "accept anything".
    let hi = built.thresholds.conservative;
    let lo = built.thresholds.aggressive;
    let mut points = vec![1.0f32];
    for i in 0..=4 {
        points.push(hi + (lo - hi) * i as f32 / 4.0);
    }
    points.push(lo - (hi - lo).abs() * 0.5);
    points.push(-1.0); // all memoization

    let mut table = TableWriter::new(
        &format!("Fig. 4 reproduction — threshold sweep ({family})"),
        &["threshold", "memo_rate", "accuracy"],
    );
    for thr in points {
        let runner = ModelRunner::load(rt.clone(), &family)?;
        let memo = MemoConfig {
            level: MemoLevel::Moderate,
            threshold_override: Some(thr as f64),
            selective: false,
            ..MemoConfig::default()
        };
        let mut engine = Engine::new(runner, Some(built.clone()),
                                     EngineOptions { memo, seq_len })?;
        let r = evaluate(&mut engine, &ids, &labels, 8, false)?;
        table.row(&[
            format!("{thr:.3}"),
            format!("{:.3}", r.memo_rate),
            format!("{:.3}", r.accuracy()),
        ]);
    }
    table.emit(Some(std::path::Path::new("bench_results/fig4_sweep.csv")));
    Ok(())
}
