//! Fig. 10 reproduction — end-to-end inference speedup over the
//! no-memoization baseline: 4 model families × batch sizes × memoization
//! levels. Expected shape: positive speedups everywhere, growing from
//! batch 1 to the middle batch, dipping slightly at the largest batch
//! (embedding cost), DeBERTa showing the largest gains.

use std::sync::Arc;

use attmemo::bench_support::{workload, TableWriter};
use attmemo::config::MemoLevel;
use attmemo::eval::evaluate;

fn main() -> attmemo::Result<()> {
    attmemo::util::logger::init();
    let rt = workload::open_runtime()?;
    let seq_len = rt.artifacts().serving_seq_len;
    let batches = rt.artifacts().serving_batches.clone();
    let n_test = 32usize;
    let db_seqs = 192usize;

    let mut table = TableWriter::new(
        "Fig. 10 reproduction — end-to-end speedup vs baseline",
        &["model", "batch", "level", "baseline_s", "memo_s", "speedup",
          "memo_rate"],
    );

    for family in ["bert", "roberta", "deberta", "gpt"] {
        let (ids, labels) =
            workload::test_workload(&rt, family, seq_len, n_test)?;
        let built = Arc::new(
            workload::build_db(&rt, family, seq_len, db_seqs)?);
        for &batch in &batches {
            // Baseline timing (fused path), warmed.
            let mut base = workload::engine_with_shared_db(
                &rt, family, seq_len, MemoLevel::Off, None, false)?;
            evaluate(&mut base, &ids.slice0(0, batch.min(n_test))?,
                     &labels[..batch.min(n_test)], batch, true)?;
            let b = evaluate(&mut base, &ids, &labels, batch, true)?;

            for level in MemoLevel::ALL_ON {
                let mut memo = workload::engine_with_shared_db(
                    &rt, family, seq_len, level, Some(built.clone()), false)?;
                evaluate(&mut memo, &ids.slice0(0, batch.min(n_test))?,
                         &labels[..batch.min(n_test)], batch, false)?;
                let m = evaluate(&mut memo, &ids, &labels, batch, false)?;
                table.row(&[
                    family.into(),
                    batch.to_string(),
                    level.name().into(),
                    format!("{:.2}", b.seconds),
                    format!("{:.2}", m.seconds),
                    format!("{:.2}x", b.seconds / m.seconds),
                    format!("{:.2}", m.memo_rate),
                ]);
            }
        }
    }
    table.emit(Some(std::path::Path::new("bench_results/fig10_speedup.csv")));
    Ok(())
}
