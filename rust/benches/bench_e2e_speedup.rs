//! Fig. 10 reproduction — end-to-end inference speedup over the
//! no-memoization baseline: 4 model families × batch sizes × memoization
//! levels. Expected shape: positive speedups everywhere, growing from
//! batch 1 to the middle batch, dipping slightly at the largest batch
//! (embedding cost), DeBERTa showing the largest gains.
//!
//! Cold arm (hermetic, no artifacts): the 0%-hit-rate worst case. Every
//! lookup misses (threshold above the similarity ceiling), so each query
//! pays the full miss pipeline — index probe, blocked host attention
//! recompute, admission. Run twice, vectorized vs `--scalar-kernels`
//! forced, to prove the kernel layer speeds up the path memoization does
//! NOT shortcut: on AVX2 hosts the vectorized p50 must strictly beat the
//! scalar baseline. Emits `cold_miss_p50_ns` (ceiling-gated) and
//! `cold_miss_speedup` (floor-gated) into `BENCH_smoke.json` /
//! `BENCH_history.jsonl`.

use std::sync::Arc;
use std::time::Instant;

use attmemo::bench_support::{smoke, workload, SmokeSummary, TableWriter};
use attmemo::config::{MemoConfig, MemoLevel, ModelConfig};
use attmemo::eval::evaluate;
use attmemo::kernels;
use attmemo::memo::index::HnswParams;
use attmemo::memo::MemoTier;
use attmemo::model::forward::host_attn_scores;
use attmemo::tensor::Tensor;
use attmemo::util::Pcg32;

/// Tiny hermetic model family for the cold arm (no artifacts). Sized so
/// the attention recompute dominates the miss pipeline, as it does at
/// real model scale.
fn cold_cfg() -> ModelConfig {
    ModelConfig {
        family: "bert".into(),
        vocab_size: 256,
        hidden: 64,
        layers: 1,
        heads: 4,
        ffn: 64,
        max_len: 48,
        num_classes: 2,
        rel_pos_buckets: 8,
        embed_dim: 8,
        embed_hidden: 16,
        embed_segments: 4,
        causal: false,
    }
}

fn unit(rng: &mut Pcg32, d: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..d).map(|_| rng.next_gaussian()).collect();
    let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
    v.iter_mut().for_each(|x| *x /= n);
    v
}

/// One cold arm: `queries` guaranteed-miss lookups, each paying probe +
/// blocked-attention recompute + admission. Returns the per-miss p50 in
/// nanoseconds. The dispatch switch is set by the caller.
fn run_cold_arm(queries: usize) -> f64 {
    let c = cold_cfg();
    let seq = c.max_len;
    let elems = c.apm_elems(seq);
    let memo = MemoConfig {
        online_admission: true,
        max_db_entries: 4096,
        admission_min_attempts: 0,
        ..MemoConfig::default()
    };
    let tier = MemoTier::new(&c, seq, HnswParams::default(), &memo);

    let mut rng = Pcg32::seeded(0x0a77);
    // Pre-populate so the probe traverses a real index, not an empty one.
    for i in 0..128usize {
        let f = unit(&mut rng, c.embed_dim);
        let apm = vec![(10 + i) as f32; elems];
        tier.admit_batch(0, &[(f.as_slice(), apm.as_slice())], 2.0, 32)
            .expect("admit");
    }

    // Query features and hidden states built outside the timed loop —
    // the miss pipeline is what's measured, not the RNG.
    let feats: Vec<Vec<f32>> =
        (0..queries).map(|_| unit(&mut rng, c.embed_dim)).collect();
    let hiddens: Vec<Tensor> = (0..8)
        .map(|_| Tensor::random(&[1, seq, c.hidden], &mut rng))
        .collect();

    let mut dst = vec![0.0f32; elems];
    let mut ns: Vec<u64> = Vec::with_capacity(queries);
    for (i, f) in feats.iter().enumerate() {
        let t0 = Instant::now();
        // Threshold above the similarity ceiling: a guaranteed miss, the
        // 0%-hit-rate regime.
        let hit = tier.lookup_fetch(0, f, 32, 1.01, &mut dst);
        assert!(hit.is_none(), "cold arm must never hit");
        let apm = host_attn_scores(&hiddens[i % hiddens.len()], c.heads)
            .expect("host attention");
        tier.admit_batch(
            0,
            &[(f.as_slice(), &apm.data()[..elems])],
            2.0,
            32,
        )
        .expect("admit");
        ns.push(t0.elapsed().as_nanos() as u64);
    }
    ns.sort_unstable();
    ns[ns.len() / 2] as f64
}

/// The hermetic 0%-hit A/B: vectorized kernels against the
/// `--scalar-kernels` baseline on the identical miss workload.
fn cold_arm_section(summary: &mut SmokeSummary) {
    let queries = smoke::iters(200, 40);
    let prior = kernels::scalar_forced();

    kernels::set_scalar_kernels(true);
    // Warmup arm discarded: first-touch page faults and allocator churn
    // land here, not in either measured arm.
    let _ = run_cold_arm(queries.min(16));
    let scalar_p50 = run_cold_arm(queries);
    kernels::set_scalar_kernels(false);
    let vec_p50 = run_cold_arm(queries);
    kernels::set_scalar_kernels(prior);

    let speedup = scalar_p50 / vec_p50.max(1.0);
    let mut table = TableWriter::new(
        "Cold arm — 0%-hit miss pipeline, vectorized vs --scalar-kernels",
        &["arm", "miss_p50_ns", "speedup"],
    );
    table.row(&["scalar".into(), format!("{scalar_p50:.0}"), "1.00x".into()]);
    table.row(&[
        "vectorized".into(),
        format!("{vec_p50:.0}"),
        format!("{speedup:.2}x"),
    ]);
    table.emit(Some(std::path::Path::new(
        "bench_results/cold_miss_ab.csv")));

    if kernels::avx2_available() {
        // The tentpole's hard gate: on hosts with the AVX2 paths the
        // vectorized miss pipeline must strictly beat the scalar A/B
        // baseline — otherwise the kernel layer is dead weight.
        assert!(
            vec_p50 < scalar_p50,
            "vectorized miss p50 {vec_p50:.0}ns not below scalar \
             {scalar_p50:.0}ns"
        );
    } else {
        eprintln!("SKIP cold-arm speedup assert (no AVX2 on this host)");
    }

    summary.push("cold_miss_p50_ns", vec_p50);
    summary.push("cold_miss_speedup", speedup);
}

/// Artifact-gated Fig. 10 body (the original bench).
fn artifact_section() -> attmemo::Result<()> {
    let rt = workload::open_runtime()?;
    let seq_len = rt.artifacts().serving_seq_len;
    let batches = rt.artifacts().serving_batches.clone();
    let n_test = 32usize;
    let db_seqs = 192usize;

    let mut table = TableWriter::new(
        "Fig. 10 reproduction — end-to-end speedup vs baseline",
        &["model", "batch", "level", "baseline_s", "memo_s", "speedup",
          "memo_rate"],
    );

    for family in ["bert", "roberta", "deberta", "gpt"] {
        let (ids, labels) =
            workload::test_workload(&rt, family, seq_len, n_test)?;
        let built = Arc::new(
            workload::build_db(&rt, family, seq_len, db_seqs)?);
        for &batch in &batches {
            // Baseline timing (fused path), warmed.
            let mut base = workload::engine_with_shared_db(
                &rt, family, seq_len, MemoLevel::Off, None, false)?;
            evaluate(&mut base, &ids.slice0(0, batch.min(n_test))?,
                     &labels[..batch.min(n_test)], batch, true)?;
            let b = evaluate(&mut base, &ids, &labels, batch, true)?;

            for level in MemoLevel::ALL_ON {
                let mut memo = workload::engine_with_shared_db(
                    &rt, family, seq_len, level, Some(built.clone()), false)?;
                evaluate(&mut memo, &ids.slice0(0, batch.min(n_test))?,
                         &labels[..batch.min(n_test)], batch, false)?;
                let m = evaluate(&mut memo, &ids, &labels, batch, false)?;
                table.row(&[
                    family.into(),
                    batch.to_string(),
                    level.name().into(),
                    format!("{:.2}", b.seconds),
                    format!("{:.2}", m.seconds),
                    format!("{:.2}x", b.seconds / m.seconds),
                    format!("{:.2}", m.memo_rate),
                ]);
            }
        }
    }
    table.emit(Some(std::path::Path::new("bench_results/fig10_speedup.csv")));
    Ok(())
}

fn main() {
    attmemo::util::logger::init();

    let mut summary = SmokeSummary::new();
    cold_arm_section(&mut summary);
    summary.emit_merged(std::path::Path::new("BENCH_smoke.json"));
    if std::env::var("BENCH_HISTORY").map(|v| v == "1").unwrap_or(false) {
        let path = std::path::Path::new("BENCH_history.jsonl");
        // Ceiling on the miss latency (generous ratio for shared
        // runners), floor on the A/B speedup, one appending call.
        let gates = summary
            .check_history_ceiling(path, "cold_miss_p50_ns", 2.5)
            .and_then(|()| {
                summary.check_and_append_history(
                    path, "cold_miss_speedup", 2.0)
            });
        match gates {
            Ok(()) => println!("history → BENCH_history.jsonl"),
            Err(e) => {
                eprintln!("BENCH history gate failed: {e}");
                std::process::exit(1);
            }
        }
    }

    match artifact_section() {
        Ok(()) => {}
        Err(e) => eprintln!("SKIP Fig. 10 sections (no artifacts): {e}"),
    }
}
