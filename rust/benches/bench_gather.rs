//! Table 6 reproduction — APM batch-gather latency: memory copy vs the
//! memory-mapping technique, across sequence lengths and batch sizes.
//! Also reports the memtier-projected numbers for an Optane-class backing
//! store (the paper's testbed).
//!
//! Kernel A/B section: scoring a gathered batch against a probe APM
//! (`gather::score_gathered`, the compute the mapping defers), vectorized
//! vs `--scalar-kernels` forced.

use attmemo::bench_support::harness::bench_fn;
use attmemo::bench_support::TableWriter;
use attmemo::kernels;
use attmemo::memo::arena::ApmArena;
use attmemo::memo::gather::{copy_gather, score_gathered, GatherWindow};
use attmemo::memtier::TierModel;
use attmemo::util::Pcg32;

/// A/B the batch scoring pass over a gathered buffer: the similarity
/// reductions route through `kernels::simd`, so forcing the scalar path
/// isolates the vectorization win at gather shapes.
fn score_ab_section() -> attmemo::Result<()> {
    let heads = 4usize;
    let seq_len = 64usize;
    let rows = heads * seq_len;
    let elems = rows * seq_len;
    let batch = 32usize;
    let mut rng = Pcg32::seeded(7);

    let mut arena = ApmArena::new(elems)?;
    let mut buf = vec![0.0f32; elems];
    let mut ids = Vec::new();
    for _ in 0..batch {
        for v in buf.iter_mut() {
            *v = rng.next_f32();
        }
        ids.push(arena.push(&buf)?);
    }
    let gathered = copy_gather(&arena, &ids)?;
    let probe: Vec<f32> = (0..elems).map(|_| rng.next_f32()).collect();

    let prior = kernels::scalar_forced();
    let mut arms = [0.0f64; 2]; // [scalar, vectorized] p50 ms
    for (i, force) in [true, false].into_iter().enumerate() {
        kernels::set_scalar_kernels(force);
        arms[i] = bench_fn("score", 2, 60.0, || {
            std::hint::black_box(score_gathered(
                std::hint::black_box(&gathered),
                elems,
                &probe,
                rows,
                seq_len,
            ));
        })
        .p50_ms;
    }
    kernels::set_scalar_kernels(prior);

    let mut table = TableWriter::new(
        "Kernel A/B — batch APM scoring over a gathered buffer",
        &["batch", "entry_elems", "scalar_ms_p50", "vectorized_ms_p50",
          "speedup"],
    );
    table.row(&[
        batch.to_string(),
        elems.to_string(),
        format!("{:.4}", arms[0]),
        format!("{:.4}", arms[1]),
        format!("{:.2}x", arms[0] / arms[1].max(1e-12)),
    ]);
    table.emit(Some(std::path::Path::new(
        "bench_results/gather_score_ab.csv")));
    Ok(())
}

fn main() -> attmemo::Result<()> {
    attmemo::util::logger::init();
    let heads = 4usize;
    let db_entries = 256usize;
    let optane = TierModel::optane();

    let mut table = TableWriter::new(
        "Table 6 reproduction — APM gather: copy vs memory mapping",
        &["seq_len", "batch", "copy_ms", "map_ms", "speedup",
          "optane_copy_ms(model)", "optane_map_ms(model)"],
    );

    for seq_len in [64usize, 128] {
        let elems = heads * seq_len * seq_len;
        let mut arena = ApmArena::new(elems)?;
        let mut rng = Pcg32::seeded(1);
        let mut buf = vec![0.0f32; elems];
        let mut ids = Vec::new();
        for _ in 0..db_entries {
            for v in buf.iter_mut() {
                *v = rng.next_f32();
            }
            ids.push(arena.push(&buf)?);
        }
        assert!(arena.dense_mappable(), "L={seq_len} not page-dense");

        for batch in [1usize, 32, 64] {
            let picks: Vec<_> = (0..batch)
                .map(|_| ids[rng.range_usize(0, ids.len())])
                .collect();

            let copy = bench_fn("copy", 2, 80.0, || {
                std::hint::black_box(copy_gather(&arena, &picks).unwrap());
            });
            let mut win = GatherWindow::new(elems, batch)?;
            let map = bench_fn("map", 2, 80.0, || {
                let v = win.map_batch(&arena, &picks).unwrap();
                // Touch one element per entry: the mapping must be usable,
                // but the data move is deferred to compute (as the paper
                // accounts it).
                std::hint::black_box(v[0]);
            });

            // Analytic Optane projection: data movement charged at tier
            // bandwidth for the copy path; syscall-only for mapping.
            let entry_bytes = elems * 4;
            let optane_copy =
                optane.copy_gather_seconds(batch, entry_bytes) * 1e3;
            let optane_map = optane
                .map_gather_seconds(batch, map.p50_ms / 1e3 / batch as f64)
                * 1e3;

            table.row(&[
                seq_len.to_string(),
                batch.to_string(),
                format!("{:.3}", copy.p50_ms),
                format!("{:.4}", map.p50_ms),
                format!("{:.0}x", copy.p50_ms / map.p50_ms.max(1e-9)),
                format!("{:.3}", optane_copy + copy.p50_ms),
                format!("{:.4}", optane_map),
            ]);
        }
    }
    table.emit(Some(std::path::Path::new("bench_results/table6_gather.csv")));
    println!("note: optane columns add the memtier analytic model \
              (DESIGN.md §2) on top of measured DRAM numbers.");
    score_ab_section()?;
    Ok(())
}
