//! Table 3 + Fig. 13 + Fig. 11 reproduction — database-scaling behaviour —
//! plus the cold-tier scaling arm (beyond-hot-DRAM capacity).
//!
//! Table 3: pre-populated DB size / indexing time as the ingested sequence
//! count grows (embedding-training time comes from the manifest, measured
//! at build time in python).
//!
//! Fig. 13: bigger DB ⇒ higher memoization rate ⇒ lower inference time.
//!
//! Fig. 11: APM reuse counts — no hot records; most entries reused at most
//! a few times (the argument for needing big memory rather than a cache).
//!
//! Cold-tier arm (hermetic, no artifacts): a tier holding **10× more
//! entries than its hot capacity** — the overflow lives in the file-backed
//! cold tier — must preserve the warm hit rate of an all-hot tier sized
//! for the whole working set, with a bounded cold-hit latency. Emits
//! `cold_hit_p99_ns`, `hot_resident_ratio` and `cold_warm_hit_rate` into
//! `BENCH_smoke.json` (merged) and, under `BENCH_HISTORY=1`, gates +
//! appends `BENCH_history.jsonl`.

use std::sync::Arc;
use std::time::Instant;

use attmemo::bench_support::{smoke, workload, SmokeSummary, TableWriter};
use attmemo::config::{MemoConfig, MemoLevel, ModelConfig};
use attmemo::eval::evaluate;
use attmemo::memo::index::HnswParams;
use attmemo::memo::MemoTier;
use attmemo::util::Pcg32;

/// Tiny hermetic model family for the cold-tier arm (no artifacts).
fn cold_cfg() -> ModelConfig {
    ModelConfig {
        family: "bert".into(),
        vocab_size: 256,
        hidden: 32,
        layers: 1,
        heads: 2,
        ffn: 64,
        max_len: 16,
        num_classes: 2,
        rel_pos_buckets: 8,
        embed_dim: 8,
        embed_hidden: 16,
        embed_segments: 4,
        causal: false,
    }
}

fn unit(rng: &mut Pcg32, d: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..d).map(|_| rng.next_gaussian()).collect();
    let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
    v.iter_mut().for_each(|x| *x /= n);
    v
}

struct ColdArm {
    hit_rate: f64,
    cold_hits: u64,
    promotions: u64,
    cold_hit_p99_ns: f64,
    hot_resident_ratio: f64,
}

/// One arm of the cold-tier A/B: admit `n` distinct entries, then query
/// every one of them back and fetch the payload. `cold_dir = None` is the
/// all-hot baseline (hot capacity `n`); `Some(dir)` caps the hot tier at
/// `hot_cap` and spills the other 90% of the working set to disk.
fn run_cold_arm(
    hot_cap: usize, n: usize, cold_dir: Option<&std::path::Path>,
    table: &mut TableWriter,
) -> ColdArm {
    let c = cold_cfg();
    let seq = 8usize;
    let elems = c.apm_elems(seq);
    let memo = MemoConfig {
        online_admission: true,
        max_db_entries: if cold_dir.is_some() { hot_cap } else { n },
        admission_min_attempts: 0,
        cold_tier_dir: cold_dir.map(|d| d.to_path_buf()),
        cold_capacity: if cold_dir.is_some() { n } else { 0 },
        ..MemoConfig::default()
    };
    let tier = if cold_dir.is_some() {
        MemoTier::with_cold_tier(&c, seq, HnswParams::default(), &memo)
            .expect("cold tier open")
    } else {
        MemoTier::new(&c, seq, HnswParams::default(), &memo)
    };

    let mut rng = Pcg32::seeded(0xc01d);
    let feats: Vec<Vec<f32>> = (0..n).map(|_| unit(&mut rng, c.embed_dim))
                                     .collect();
    for (i, f) in feats.iter().enumerate() {
        let apm = vec![(10 + i) as f32; elems];
        // Threshold 2.0: unreachable similarity, so every distinct entry
        // is stored instead of deduplicating against a near neighbour.
        tier.admit_batch(0, &[(f.as_slice(), apm.as_slice())], 2.0, 32)
            .expect("admit");
    }

    let mut dst = vec![0.0f32; elems];
    let mut hits = 0u64;
    let mut cold_ns: Vec<u64> = Vec::new();
    for (i, f) in feats.iter().enumerate() {
        let before = tier.cold_hits();
        let t0 = Instant::now();
        let hit = tier.lookup_fetch(0, f, 32, 0.9, &mut dst);
        let ns = t0.elapsed().as_nanos() as u64;
        if let Some(h) = hit {
            assert!(h.similarity >= 0.9);
            // Random unit features can collide above 0.9 by chance, so
            // only an exact match pins the tag; any hit must still carry
            // some live entry's payload.
            if h.similarity > 0.999 {
                assert_eq!(
                    dst[0],
                    (10 + i) as f32,
                    "an exact hit must carry entry {i}'s payload tag"
                );
            }
            assert!(
                dst[0] >= 10.0 && dst[0] < (10 + n) as f32,
                "fetched payload tag {} is not a live entry's",
                dst[0]
            );
            hits += 1;
        }
        if tier.cold_hits() > before {
            cold_ns.push(ns);
        }
    }

    cold_ns.sort_unstable();
    let p99 = if cold_ns.is_empty() {
        0.0
    } else {
        cold_ns[(cold_ns.len() - 1).min(cold_ns.len() * 99 / 100)] as f64
    };
    let arm = ColdArm {
        hit_rate: hits as f64 / n as f64,
        cold_hits: tier.cold_hits(),
        promotions: tier.promotions(),
        cold_hit_p99_ns: p99,
        hot_resident_ratio: tier.hot_resident_ratio(),
    };
    table.row(&[
        if cold_dir.is_some() { "cold" } else { "all-hot" }.to_string(),
        memo.max_db_entries.to_string(),
        n.to_string(),
        format!("{:.3}", arm.hit_rate),
        arm.cold_hits.to_string(),
        arm.promotions.to_string(),
        format!("{:.0}", arm.cold_hit_p99_ns),
        format!("{:.3}", arm.hot_resident_ratio),
    ]);
    arm
}

/// Hermetic cold-tier scaling arm: 10× the hot capacity in total entries,
/// warm hit rate preserved against the all-hot baseline, cold-hit latency
/// bounded. Records the smoke keys CI gates on.
fn cold_tier_section(summary: &mut SmokeSummary) {
    let hot_cap = smoke::iters(16, 8);
    let n = hot_cap * 10;
    let dir = std::env::temp_dir().join("attmemo_bench_cold_tier");
    let _ = std::fs::remove_dir_all(&dir);

    let mut table = TableWriter::new(
        "Cold-tier scaling — 10× hot capacity spilled to the file-backed \
         tier vs an all-hot tier sized for the working set",
        &["arm", "hot_cap", "entries", "warm_hit_rate", "cold_hits",
          "promotions", "cold_hit_p99_ns", "hot_resident_ratio"],
    );
    let baseline = run_cold_arm(n, n, None, &mut table);
    let cold = run_cold_arm(hot_cap, n, Some(&dir), &mut table);
    table.emit(Some(std::path::Path::new(
        "bench_results/cold_tier_scaling.csv")));
    let _ = std::fs::remove_dir_all(&dir);

    println!(
        "cold tier: {}/{} entries beyond hot capacity; warm hit rate \
         cold={:.3} all-hot={:.3}; cold hits={} promotions={} \
         p99={:.0}ns hot_resident_ratio={:.3}",
        n - hot_cap, n, cold.hit_rate, baseline.hit_rate, cold.cold_hits,
        cold.promotions, cold.cold_hit_p99_ns, cold.hot_resident_ratio,
    );
    assert!(
        cold.hit_rate >= baseline.hit_rate,
        "spilling must not lose warm hits: cold {:.3} vs all-hot {:.3}",
        cold.hit_rate, baseline.hit_rate
    );
    assert!(
        cold.cold_hits > 0 && cold.promotions > 0,
        "the cold arm must actually exercise the fall-through path \
         (cold_hits={} promotions={})",
        cold.cold_hits, cold.promotions
    );
    // Generous ceiling for noisy shared CI runners — a cold hit is a
    // linear scan of ≤ n tiny features plus one mmap copy and one
    // appended log record, microseconds in practice.
    const COLD_HIT_CEILING_NS: f64 = 50_000_000.0;
    assert!(
        cold.cold_hit_p99_ns < COLD_HIT_CEILING_NS,
        "cold-hit p99 {}ns blew the {}ns ceiling",
        cold.cold_hit_p99_ns, COLD_HIT_CEILING_NS
    );
    assert!(
        cold.hot_resident_ratio < 0.5,
        "with 10× spill most resident bytes must live in the cold tier \
         (hot_resident_ratio={:.3})",
        cold.hot_resident_ratio
    );

    summary.push("cold_hit_p99_ns", cold.cold_hit_p99_ns);
    summary.push("hot_resident_ratio", cold.hot_resident_ratio);
    summary.push("cold_warm_hit_rate", cold.hit_rate);
}

/// Artifact-gated Table 3 / Fig. 13 / Fig. 11 sections (the original
/// bench body).
fn artifact_sections() -> attmemo::Result<()> {
    let rt = workload::open_runtime()?;
    let seq_len = rt.artifacts().serving_seq_len;
    let family = "bert";
    let (ids, labels) = workload::test_workload(&rt, family, seq_len, 32)?;

    let mut t3 = TableWriter::new(
        "Table 3 reproduction — DB size / indexing time vs #sequences",
        &["#seqs", "entries", "db_size_MiB", "indexing_s", "build_s"],
    );
    let mut fig13 = TableWriter::new(
        "Fig. 13 reproduction — memoization and latency vs DB size",
        &["#seqs", "memo_rate", "inference_s", "accuracy"],
    );

    let mut reuse_db = None;
    for &n in &[64usize, 128, 256] {
        let built = Arc::new(
            workload::build_db(&rt, family, seq_len, n)?);
        t3.row(&[
            n.to_string(),
            built.db.total_entries().to_string(),
            format!("{:.1}",
                    built.db.resident_bytes() as f64 / (1 << 20) as f64),
            format!("{:.2}", built.indexing_seconds),
            format!("{:.2}", built.build_seconds),
        ]);

        let mut e = workload::engine_with_shared_db(
            &rt, family, seq_len, MemoLevel::Moderate, Some(built.clone()),
            false)?;
        evaluate(&mut e, &ids.slice0(0, 8)?, &labels[..8], 8, false)?; // warm
        let r = evaluate(&mut e, &ids, &labels, 8, false)?;
        fig13.row(&[
            n.to_string(),
            format!("{:.3}", r.memo_rate),
            format!("{:.2}", r.seconds),
            format!("{:.3}", r.accuracy()),
        ]);
        if n == 256 {
            reuse_db = Some(built);
        }
    }
    t3.emit(Some(std::path::Path::new("bench_results/table3_db_build.csv")));
    fig13.emit(Some(std::path::Path::new(
        "bench_results/fig13_db_scaling.csv")));

    // ---- Fig. 11: reuse histogram over the largest DB ---------------------
    if let Some(built) = reuse_db {
        let mut hist = std::collections::BTreeMap::<u32, usize>::new();
        for li in 0..built.db.num_layers() {
            for c in built.db.layer(li).reuse_counts() {
                *hist.entry(c).or_default() += 1;
            }
        }
        let mut fig11 = TableWriter::new(
            "Fig. 11 reproduction — APM reuse counts (after the Fig. 13 \
             query load)",
            &["reuse_count", "#entries"],
        );
        for (c, n) in &hist {
            fig11.row(&[c.to_string(), n.to_string()]);
        }
        fig11.emit(Some(std::path::Path::new(
            "bench_results/fig11_reuse.csv")));
        let max_reuse = hist.keys().max().copied().unwrap_or(0);
        println!("max reuse of any record: {max_reuse} (paper: ≤ 6, no hot \
                  records)");
    }
    println!("\nembedder training time (python, manifest): see \
              EXPERIMENTS.md Table 3 row — recorded at artifact build.");
    Ok(())
}

fn main() {
    attmemo::util::logger::init();

    let mut summary = SmokeSummary::new();
    cold_tier_section(&mut summary);
    // Merged with bench_online_memo's keys — whichever binary runs last
    // must not erase the other's headline numbers.
    summary.emit_merged(std::path::Path::new("BENCH_smoke.json"));
    if std::env::var("BENCH_HISTORY").map(|v| v == "1").unwrap_or(false) {
        let path = std::path::Path::new("BENCH_history.jsonl");
        // Ceiling first (check-only): routing the cold probe through the
        // SIMD distance primitive must not regress the cold-hit latency.
        // Then the single appending call on the hit-rate floor.
        match summary
            .check_history_ceiling(path, "cold_hit_p99_ns", 2.5)
            .and_then(|()| summary.check_and_append_history(
                path,
                "cold_warm_hit_rate",
                0.01,
            )) {
            Ok(()) => println!("history → BENCH_history.jsonl"),
            Err(e) => {
                eprintln!("BENCH history gate failed: {e}");
                std::process::exit(1);
            }
        }
    }

    match artifact_sections() {
        Ok(()) => {}
        Err(e) => eprintln!("SKIP Table 3 / Fig. 13 / Fig. 11 sections \
                             (no artifacts): {e}"),
    }
}
