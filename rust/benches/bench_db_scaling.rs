//! Table 3 + Fig. 13 + Fig. 11 reproduction — database-scaling behaviour.
//!
//! Table 3: pre-populated DB size / indexing time as the ingested sequence
//! count grows (embedding-training time comes from the manifest, measured
//! at build time in python).
//!
//! Fig. 13: bigger DB ⇒ higher memoization rate ⇒ lower inference time.
//!
//! Fig. 11: APM reuse counts — no hot records; most entries reused at most
//! a few times (the argument for needing big memory rather than a cache).

use std::sync::Arc;

use attmemo::bench_support::{workload, TableWriter};
use attmemo::config::MemoLevel;
use attmemo::eval::evaluate;

fn main() -> attmemo::Result<()> {
    attmemo::util::logger::init();
    let rt = workload::open_runtime()?;
    let seq_len = rt.artifacts().serving_seq_len;
    let family = "bert";
    let (ids, labels) = workload::test_workload(&rt, family, seq_len, 32)?;

    let mut t3 = TableWriter::new(
        "Table 3 reproduction — DB size / indexing time vs #sequences",
        &["#seqs", "entries", "db_size_MiB", "indexing_s", "build_s"],
    );
    let mut fig13 = TableWriter::new(
        "Fig. 13 reproduction — memoization and latency vs DB size",
        &["#seqs", "memo_rate", "inference_s", "accuracy"],
    );

    let mut reuse_db = None;
    for &n in &[64usize, 128, 256] {
        let built = Arc::new(
            workload::build_db(&rt, family, seq_len, n)?);
        t3.row(&[
            n.to_string(),
            built.db.total_entries().to_string(),
            format!("{:.1}",
                    built.db.resident_bytes() as f64 / (1 << 20) as f64),
            format!("{:.2}", built.indexing_seconds),
            format!("{:.2}", built.build_seconds),
        ]);

        let mut e = workload::engine_with_shared_db(
            &rt, family, seq_len, MemoLevel::Moderate, Some(built.clone()),
            false)?;
        evaluate(&mut e, &ids.slice0(0, 8)?, &labels[..8], 8, false)?; // warm
        let r = evaluate(&mut e, &ids, &labels, 8, false)?;
        fig13.row(&[
            n.to_string(),
            format!("{:.3}", r.memo_rate),
            format!("{:.2}", r.seconds),
            format!("{:.3}", r.accuracy()),
        ]);
        if n == 256 {
            reuse_db = Some(built);
        }
    }
    t3.emit(Some(std::path::Path::new("bench_results/table3_db_build.csv")));
    fig13.emit(Some(std::path::Path::new(
        "bench_results/fig13_db_scaling.csv")));

    // ---- Fig. 11: reuse histogram over the largest DB ---------------------
    if let Some(built) = reuse_db {
        let mut hist = std::collections::BTreeMap::<u32, usize>::new();
        for li in 0..built.db.num_layers() {
            for c in built.db.layer(li).reuse_counts() {
                *hist.entry(c).or_default() += 1;
            }
        }
        let mut fig11 = TableWriter::new(
            "Fig. 11 reproduction — APM reuse counts (after the Fig. 13 \
             query load)",
            &["reuse_count", "#entries"],
        );
        for (c, n) in &hist {
            fig11.row(&[c.to_string(), n.to_string()]);
        }
        fig11.emit(Some(std::path::Path::new(
            "bench_results/fig11_reuse.csv")));
        let max_reuse = hist.keys().max().copied().unwrap_or(0);
        println!("max reuse of any record: {max_reuse} (paper: ≤ 6, no hot \
                  records)");
    }
    println!("\nembedder training time (python, manifest): see \
              EXPERIMENTS.md Table 3 row — recorded at artifact build.");
    Ok(())
}
