//! Fig. 7 reproduction — exhaustive search vs embedding-based HNSW search:
//! quality gap (similarity-score difference of the returned record) and
//! latency gap. Expected shape: quality within ~0.1, latency orders of
//! magnitude apart.
//!
//! Plus the §6.7 claim: HNSW search time varies <~1% when the database
//! doubles (measured here across three DB sizes).

use attmemo::bench_support::harness::bench_fn;
use attmemo::bench_support::{workload, TableWriter};
use attmemo::memo::builder::DbBuilder;
use attmemo::model::ModelRunner;
use attmemo::tensor::ops;

fn main() -> attmemo::Result<()> {
    attmemo::util::logger::init();
    let rt = workload::open_runtime()?;
    let seq = rt.artifacts().serving_seq_len;
    let runner = ModelRunner::load(rt.clone(), "bert")?;

    // Build a DB and keep the stored APMs for exhaustive comparison.
    let ds = workload::dataset_for(&rt, "bert", seq, true)?;
    let (train_ids, _) = rt.artifacts().load_dataset(&ds)?;
    let db_ids = train_ids.slice0(0, 128.min(train_ids.shape[0]))?;
    let built = DbBuilder::new(&runner).build(&db_ids)?;

    let (q_ids, _) = workload::test_workload(&rt, "bert", seq, 16)?;
    let layer = 0usize;
    let cfg = runner.config();
    let rows = cfg.heads * seq;

    // Query hidden states + APMs + features.
    let h = runner.embed(&q_ids)?;
    let q_apm = runner.attn_scores(&h, layer)?;
    let feats = runner.mlp_embed(&h)?;
    let n = q_ids.shape[0];
    let elems = q_apm.len() / n;

    let mut quality = TableWriter::new(
        "Fig. 7 reproduction — exhaustive vs embedding-based search",
        &["query", "exhaustive_best_sim", "hnsw_sim", "difference"],
    );
    let mut diffs = Vec::new();
    let mut exh_ms_total = 0.0;
    for i in 0..n {
        let q = &q_apm.data()[i * elems..(i + 1) * elems];
        // Exhaustive: scan every stored APM with exact Eq. 1.
        let t0 = std::time::Instant::now();
        let mut best = 0.0f32;
        for id in 0..built.db.layer(layer).len() {
            let rec = built
                .db
                .layer(layer)
                .arena()
                .get(attmemo::memo::ApmId(id as u32))?;
            best = best.max(ops::similarity_score(q, rec, rows, seq));
        }
        exh_ms_total += t0.elapsed().as_secs_f64() * 1e3;
        // HNSW on the embedding: exact similarity of the returned record.
        let hit = built.db.layer(layer).lookup(feats.row(i), 48).unwrap();
        let rec = built.db.layer(layer).arena().get(hit.id)?;
        let hnsw_sim = ops::similarity_score(q, rec, rows, seq);
        diffs.push(best - hnsw_sim);
        quality.row(&[
            i.to_string(),
            format!("{best:.4}"),
            format!("{hnsw_sim:.4}"),
            format!("{:.4}", best - hnsw_sim),
        ]);
    }
    quality.emit(Some(std::path::Path::new(
        "bench_results/fig7_quality.csv")));
    let mean_diff = diffs.iter().sum::<f32>() / diffs.len() as f32;

    // Latency comparison (per query).
    let probe = feats.row(0).to_vec();
    let hnsw_lat = bench_fn("hnsw", 3, 50.0, || {
        std::hint::black_box(built.db.layer(layer).lookup(&probe, 48));
    });
    println!(
        "\nmean similarity difference (exhaustive - hnsw): {mean_diff:.4} \
         (paper: < 0.1)"
    );
    println!(
        "exhaustive search: {:.2} ms/query; embedding+HNSW: {:.4} ms/query \
         → {:.0}x faster",
        exh_ms_total / n as f64,
        hnsw_lat.p50_ms,
        (exh_ms_total / n as f64) / hnsw_lat.p50_ms.max(1e-9)
    );

    // §6.7: search latency vs DB size.
    let mut scale = TableWriter::new(
        "§6.7 — HNSW search latency vs database size",
        &["db_entries", "search_ms_p50"],
    );
    for size in [32usize, 64, 128] {
        let ids = train_ids.slice0(0, size)?;
        let b = DbBuilder::new(&runner).build(&ids)?;
        let lat = bench_fn("s", 3, 30.0, || {
            std::hint::black_box(b.db.layer(0).lookup(&probe, 48));
        });
        scale.row(&[size.to_string(), format!("{:.4}", lat.p50_ms)]);
    }
    scale.emit(Some(std::path::Path::new("bench_results/fig7_scale.csv")));
    Ok(())
}
