//! Table 9 reproduction — LLM CPU-vs-GPU deployment cost model (§6.9).
//!
//! This experiment is an arithmetic argument in the paper (built on its
//! published measurements of LLaMA-65B on Oracle cloud instances); we
//! reproduce the arithmetic with the paper's constants and assert the
//! three headline claims: 6 CPU instances beat 4 GPU instances by ~9%,
//! acquisition ~1.29× cheaper, cloud ~1.8× cheaper.

use attmemo::bench_support::TableWriter;

struct Cfg {
    name: &'static str,
    tokens_per_s: f64,
    acq_cost: f64,
    cloud_per_hr: f64,
}

fn main() {
    // Paper Table 9 measurements (tokens/s) and costs.
    let rows = [
        Cfg { name: "4 GPU instances (8xA10)", tokens_per_s: 5.54,
              acq_cost: 61_200.0, cloud_per_hr: 1.6 },
        Cfg { name: "1 CPU instance (64c/1TB)", tokens_per_s: 1.01,
              acq_cost: 7_900.0, cloud_per_hr: 0.88 / 6.0 },
        Cfg { name: "6 CPU instances", tokens_per_s: 6.06,
              acq_cost: 47_400.0, cloud_per_hr: 0.88 },
    ];
    let mut t = TableWriter::new(
        "Table 9 reproduction — LLaMA-65B deployment options",
        &["config", "tokens/s", "acq_cost_$", "cloud_$/hr",
          "$_per_1M_tokens"],
    );
    for c in &rows {
        t.row(&[
            c.name.into(),
            format!("{:.2}", c.tokens_per_s),
            format!("{:.0}", c.acq_cost),
            format!("{:.2}", c.cloud_per_hr),
            format!("{:.2}", c.cloud_per_hr / (c.tokens_per_s * 3600.0) * 1e6),
        ]);
    }
    t.emit(Some(std::path::Path::new("bench_results/table9_llm.csv")));

    let gpu = &rows[0];
    let cpu6 = &rows[2];
    let perf_gain = (cpu6.tokens_per_s / gpu.tokens_per_s - 1.0) * 100.0;
    let acq_ratio = gpu.acq_cost / cpu6.acq_cost;
    let cloud_ratio = gpu.cloud_per_hr / cpu6.cloud_per_hr;
    println!("6 CPU vs 4 GPU: {perf_gain:+.1}% perf, acquisition {acq_ratio:.2}x \
              cheaper, cloud {cloud_ratio:.2}x cheaper");
    assert!((perf_gain - 9.0).abs() < 1.5, "perf claim drifted");
    assert!((acq_ratio - 1.29).abs() < 0.05, "acq claim drifted");
    assert!((cloud_ratio - 1.8).abs() < 0.1, "cloud claim drifted");
    println!("paper claims (9%, 1.29x, 1.8x) reproduced ✓");
}
