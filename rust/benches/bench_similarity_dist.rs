//! Figs. 3, 12 and 15 reproduction — similarity-score distributions.
//!
//! Fig. 3: per-layer distribution of the best-match Eq. 1 similarity in the
//! attention database (bert). Expect: a large high-similarity mass, with
//! clear layer-to-layer differences.
//!
//! Fig. 12: the same distribution as the input sequence length grows —
//! longer sequences show higher average similarity.
//!
//! Fig. 15: decoder (gpt) layer 0 vs a deep layer — layer 0 shows far more
//! memoization potential.

use attmemo::bench_support::{workload, TableWriter};
use attmemo::model::ModelRunner;
use attmemo::tensor::ops;
use attmemo::util::stats::Histogram;

/// Collect best-match exact similarities: for each query APM, the max
/// Eq. 1 score against every stored APM of the same layer (the paper's
/// exhaustive method for Figs. 3/12/15).
fn best_similarities(runner: &ModelRunner, db_ids: &attmemo::tensor::tensor::IdTensor,
                     q_ids: &attmemo::tensor::tensor::IdTensor,
                     layer: usize) -> attmemo::Result<Vec<f32>> {
    let cfg = runner.config();
    let heads = cfg.heads;
    // Stored APMs for this layer.
    let mut stored: Vec<Vec<f32>> = Vec::new();
    for s in (0..db_ids.shape[0]).step_by(8) {
        let chunk = db_ids.slice0(s, 8.min(db_ids.shape[0] - s))?;
        let mut h = runner.embed(&chunk)?;
        for li in 0..=layer {
            let apm = runner.attn_scores(&h, li)?;
            if li == layer {
                let n = apm.shape()[0];
                let elems = apm.len() / n;
                for i in 0..n {
                    stored.push(
                        apm.data()[i * elems..(i + 1) * elems].to_vec());
                }
                break;
            }
            h = runner.attn_apply(&h, &apm, li)?;
        }
    }
    // Queries.
    let mut best = Vec::new();
    let l = q_ids.shape[1];
    let rows = heads * l;
    for s in (0..q_ids.shape[0]).step_by(8) {
        let chunk = q_ids.slice0(s, 8.min(q_ids.shape[0] - s))?;
        let mut h = runner.embed(&chunk)?;
        for li in 0..=layer {
            let apm = runner.attn_scores(&h, li)?;
            if li == layer {
                let n = apm.shape()[0];
                let elems = apm.len() / n;
                for i in 0..n {
                    let q = &apm.data()[i * elems..(i + 1) * elems];
                    let mut m = 0.0f32;
                    for srec in &stored {
                        m = m.max(ops::similarity_score(q, srec, rows, l));
                    }
                    best.push(m);
                }
                break;
            }
            h = runner.attn_apply(&h, &apm, li)?;
        }
    }
    Ok(best)
}

fn dist_row(name: &str, sims: &[f32]) -> Vec<String> {
    let mut h = Histogram::new(0.0, 1.0001, 10);
    for &s in sims {
        h.record(s as f64);
    }
    let mean = sims.iter().sum::<f32>() / sims.len().max(1) as f32;
    let high = h.frac_at_least(0.7);
    vec![
        name.into(),
        format!("{:.3}", mean),
        format!("{:.1}%", high * 100.0),
        h.rows()
            .iter()
            .map(|(_, c)| c.to_string())
            .collect::<Vec<_>>()
            .join("|"),
    ]
}

fn main() -> attmemo::Result<()> {
    attmemo::util::logger::init();
    let rt = workload::open_runtime()?;
    let headers = ["case", "mean_sim", "frac>=0.7", "hist(0..1, 10 bins)"];

    // ---- Fig. 3: per-layer, bert, serving length -------------------------
    let runner = ModelRunner::load(rt.clone(), "bert")?;
    let seq = rt.artifacts().serving_seq_len;
    let ds = workload::dataset_for(&rt, "bert", seq, true)?;
    let (train_ids, _) = rt.artifacts().load_dataset(&ds)?;
    let db_ids = train_ids.slice0(0, 96.min(train_ids.shape[0]))?;
    let (q_ids, _) = workload::test_workload(&rt, "bert", seq, 24)?;
    let mut fig3 = TableWriter::new(
        "Fig. 3 reproduction — best-match similarity per layer (bert)",
        &headers,
    );
    for li in 0..runner.config().layers {
        let sims = best_similarities(&runner, &db_ids, &q_ids, li)?;
        fig3.row(&dist_row(&format!("layer {li}"), &sims));
    }
    fig3.emit(Some(std::path::Path::new("bench_results/fig3_similarity.csv")));

    // ---- Fig. 12: sequence-length sweep (bert, layer 0) -------------------
    let mut fig12 = TableWriter::new(
        "Fig. 12 reproduction — similarity vs input sequence length \
         (bert, layer 0)",
        &headers,
    );
    for &l in &rt.artifacts().sweep_seq_lens.clone() {
        let name = format!("cls_sweep_{l}");
        let Ok((ids, _)) = rt.artifacts().load_dataset(&name) else {
            continue;
        };
        let db = ids.slice0(0, 64.min(ids.shape[0]))?;
        let q = ids.slice0(64.min(ids.shape[0] - 16), 16)?;
        let sims = best_similarities(&runner, &db, &q, 0)?;
        fig12.row(&dist_row(&format!("L={l}"), &sims));
    }
    fig12.emit(Some(std::path::Path::new("bench_results/fig12_seqlen.csv")));

    // ---- Fig. 15: decoder layers 0 vs deep --------------------------------
    let gpt = ModelRunner::load(rt.clone(), "gpt")?;
    let ds = workload::dataset_for(&rt, "gpt", seq, true)?;
    let (lm_ids, _) = rt.artifacts().load_dataset(&ds)?;
    let db = lm_ids.slice0(0, 48.min(lm_ids.shape[0]))?;
    let (q, _) = workload::test_workload(&rt, "gpt", seq, 16)?;
    let mut fig15 = TableWriter::new(
        "Fig. 15 reproduction — decoder similarity, shallow vs deep layer",
        &headers,
    );
    let deep = gpt.config().layers - 1;
    for li in [0usize, deep] {
        let sims = best_similarities(&gpt, &db, &q, li)?;
        fig15.row(&dist_row(&format!("layer {li}"), &sims));
    }
    fig15.emit(Some(std::path::Path::new("bench_results/fig15_decoder.csv")));
    Ok(())
}
