//! Online-memoization warm-up — the serve-time admission extension.
//!
//! AttMEMO's database is built offline, so a cold or drifting workload is
//! stuck at 0% hits forever; with serve-time admission (AttnCache-style,
//! arXiv 2510.25979) the engine admits miss APMs under a capacity budget
//! and warms to a steady-state hit rate. This bench demonstrates the
//! trajectory:
//!
//! * a **memo-layer simulation** over clustered embedding traffic — always
//!   runs, no artifacts needed: per-epoch hit rate from 0% to steady
//!   state, occupancy vs the budget, eviction churn, and lookup+admit
//!   latency;
//! * a **shared-tier read-scaling** section (1→4 reader threads on one
//!   warmed tier, plus the seqlock acceptance arms: 4 readers with a
//!   full-tilt same-shard admitter vs an equal-CPU private-tier
//!   admitter — lookup throughput must not degrade when admissions run);
//! * a **write-path A/B** (dedup prepass on vs off on a steady-state
//!   all-dedup workload — the prepass must strictly reduce publishes,
//!   and on full runs its admit p99 must beat the full publish path);
//! * a **generational publish A/B** (mixed batches — one fresh row plus
//!   seven dedup rows — against 1× and 10× prefills, chunk-sharing vs
//!   the `full_index_clone` deep-copy baseline: `publish_touched_nodes`
//!   must stay flat across the growth and the generational mixed-batch
//!   admit p99 must beat the baseline at the large size);
//! * an **affinity A/B** (8 buckets vs 1 on a clustered workload) and a
//!   **signature A/B** (semantic SimHash vs prefix min-hash on a
//!   *paraphrase-clustered* workload, where word order scatters the
//!   min-hash but not the meaning) — both through the real router +
//!   `form_batch` + shared tier;
//! * a **continuous-vs-fixed batching A/B**: the same mixed-length warm
//!   workload through `run_fixed_batch` (frozen membership, stragglers
//!   hold their batch) and through the `ContinuousScheduler` (slots
//!   refill at every step boundary) over a synthetic `StepEngine` with a
//!   real shared memo tier — continuous must cut request p99 at equal
//!   work, with no warm-hit-rate or dedup-yield regression;
//! * an **end-to-end cold engine** over the real test workload when
//!   artifacts are present (skipped otherwise, like every runtime bench).
//!
//! With `BENCH_SMOKE=1` every section runs a capped short mode and the
//! headline numbers (latency, hit rate, dedup yields) land in
//! `BENCH_smoke.json` — the artifact CI uploads on every PR.

use std::sync::Arc;

use attmemo::bench_support::harness::time_ms;
use attmemo::bench_support::{smoke, SmokeSummary, TableWriter};
use attmemo::config::{MemoLevel, ModelConfig};
use attmemo::memo::index::{Hnsw, HnswParams};
use attmemo::memo::policy::AdmissionPolicy;
use attmemo::memo::semhash::SemanticSketcher;
use attmemo::memo::{AttentionDb, MemoTier};
use attmemo::serving::affinity::Signer;
use attmemo::util::Pcg32;

fn sim_cfg() -> ModelConfig {
    ModelConfig {
        family: "bert".into(),
        vocab_size: 256,
        hidden: 64,
        layers: 1,
        heads: 4,
        ffn: 128,
        max_len: 32,
        num_classes: 2,
        rel_pos_buckets: 8,
        embed_dim: 64,
        embed_hidden: 128,
        embed_segments: 4,
        causal: false,
    }
}

fn normalize(v: &mut [f32]) {
    let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
    v.iter_mut().for_each(|x| *x /= n);
}

fn unit_vec(rng: &mut Pcg32, dim: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
    normalize(&mut v);
    v
}

/// Simulated serve loop at the memoization layer: clustered queries, a
/// threshold, admission with a per-layer budget. Returns the final
/// epoch's hit rate and the mean lookup latency for the smoke summary.
fn simulate(capacity: usize, clusters: usize, epochs: usize,
            queries: usize, threshold: f32,
            table: &mut TableWriter) -> (f64, f64) {
    let cfg = sim_cfg();
    let seq = 32usize;
    let elems = cfg.apm_elems(seq);
    let mut db = AttentionDb::new(&cfg, seq, HnswParams::default());
    let gate = AdmissionPolicy::new(true, 0);
    let mut rng = Pcg32::seeded(7);
    let centres: Vec<Vec<f32>> =
        (0..clusters).map(|_| unit_vec(&mut rng, cfg.embed_dim)).collect();

    let mut attempts = 0u64;
    let mut evictions = 0u64;
    let mut last_rate = 0.0f64;
    let mut mean_lookup_ms = 0.0f64;
    for epoch in 0..epochs {
        let mut hits = 0usize;
        let mut lookup_ms = 0.0f64;
        let mut admit_ms = 0.0f64;
        for q in 0..queries {
            let mut query = centres[q % clusters].clone();
            for x in query.iter_mut() {
                *x += 0.02 * rng.next_gaussian();
            }
            normalize(&mut query);
            attempts += 1;
            let (hit, ms) =
                time_ms(|| db.layer(0).lookup(&query, 48)
                    .filter(|h| h.similarity >= threshold));
            lookup_ms += ms;
            match hit {
                Some(h) => {
                    hits += 1;
                    db.layer(0).mark_reused(h.id);
                }
                None if gate.should_admit(None, attempts, seq as u64) => {
                    let apm = vec![1.0 / seq as f32; elems];
                    let (out, ms) = time_ms(|| {
                        db.layer_mut(0).admit(&query, &apm, capacity).unwrap()
                    });
                    admit_ms += ms;
                    evictions += out.evicted.len() as u64;
                }
                None => {}
            }
            assert!(capacity == 0 || db.layer(0).len() <= capacity,
                    "occupancy exceeded the budget");
        }
        last_rate = hits as f64 / queries as f64;
        mean_lookup_ms = lookup_ms / queries as f64;
        table.row(&[
            capacity.to_string(),
            epoch.to_string(),
            format!("{last_rate:.3}"),
            db.layer(0).len().to_string(),
            evictions.to_string(),
            format!("{mean_lookup_ms:.4}"),
            format!("{:.4}", admit_ms / queries.max(1) as f64),
        ]);
    }
    (last_rate, mean_lookup_ms)
}

fn run_engine_section() -> attmemo::Result<()> {
    use attmemo::bench_support::workload;
    use attmemo::eval::evaluate;

    let rt = workload::open_runtime()?;
    let seq_len = rt.artifacts().serving_seq_len;
    let (ids, labels) = workload::test_workload(&rt, "bert", seq_len, 32)?;

    let mut table = TableWriter::new(
        "Cold engine warm-up — per-epoch hit rate (empty DB, admission on)",
        &["epoch", "memo_rate", "admitted", "evicted", "online_entries"],
    );
    let capacity = 128;
    let mut engine = workload::cold_engine(
        &rt, "bert", seq_len, MemoLevel::Aggressive, capacity, 0)?;
    for epoch in 0..smoke::iters(4, 2) {
        let r = evaluate(&mut engine, &ids, &labels, 8, false)?;
        table.row(&[
            epoch.to_string(),
            format!("{:.3}", r.memo_rate),
            engine.stats.total_admitted().to_string(),
            engine.stats.total_evicted().to_string(),
            engine
                .online()
                .map_or(0, |t| t.total_entries())
                .to_string(),
        ]);
    }
    table.emit(Some(std::path::Path::new(
        "bench_results/online_memo_engine.csv")));
    if let Some(tier) = engine.online() {
        for li in 0..tier.num_layers() {
            assert!(tier.layer_len(li) <= capacity,
                    "layer {li} over capacity");
        }
    }
    Ok(())
}

/// Run `threads` reader threads of exact-match `lookup_fetch`es against
/// `tier`'s layer 0, optionally with one background admitter thread
/// churning `admit_into`'s layer 0 at full tilt. The admitter's batches
/// are dedup-admissions (every row already stored above the dedup
/// threshold), so the entry set never changes and the read workload is
/// identical across arms. With the dedup prepass on (the default) each
/// such batch resolves against the published snapshot and *skips* the
/// publish — the steady-state cheap-write path; with it off, each batch
/// runs the complete writer path (snapshot clone, publish, slot reclaim).
/// Returns (total hits, wall seconds of the reader side).
fn read_throughput(tier: &Arc<MemoTier>, entries: &Arc<Vec<Vec<f32>>>,
                   elems: usize, threads: usize, lookups_per_thread: usize,
                   admit_into: Option<Arc<MemoTier>>) -> (usize, f64) {
    use std::sync::atomic::{AtomicBool, Ordering};

    let stop = Arc::new(AtomicBool::new(false));
    let admitter = admit_into.map(|t| {
        let stop = stop.clone();
        let entries = entries.clone();
        std::thread::spawn(move || {
            let apm = vec![1.0f32; elems];
            let mut k = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let rows: Vec<(&[f32], &[f32])> = (0..8)
                    .map(|j| {
                        (entries[(k + j) % entries.len()].as_slice(),
                         apm.as_slice())
                    })
                    .collect();
                t.admit_batch(0, &rows, 0.9, 48).unwrap();
                k = (k + 8) % entries.len();
            }
        })
    });
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for t in 0..threads {
        let tier = tier.clone();
        let entries = entries.clone();
        handles.push(std::thread::spawn(move || {
            let mut dst = vec![0.0f32; elems];
            let mut hits = 0usize;
            for i in 0..lookups_per_thread {
                let q = &entries[(i * (t + 1)) % entries.len()];
                if tier.lookup_fetch(0, q, 48, 0.9, &mut dst).is_some() {
                    hits += 1;
                }
            }
            hits
        }));
    }
    let hits: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let secs = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    if let Some(a) = admitter {
        a.join().unwrap();
    }
    (hits, secs)
}

/// Shared-tier read scaling: one warmed `MemoTier`, 1..=4 reader threads
/// doing lookup+fetch concurrently, then the seqlock acceptance
/// measurement — 4 readers with a full-tilt admitter on the *same* shard
/// versus the same CPU load admitting into a *private* tier. Under the
/// old per-shard write lock the same-shard admitter stalled readers for
/// whole admission batches; on the seqlock read path lookups never
/// block, so the two arms must stay close. Returns (4-thread
/// lookups/sec, shared-vs-private throughput ratio) for the smoke
/// summary.
fn shared_tier_section(table: &mut TableWriter) -> (f64, f64) {
    use attmemo::config::MemoConfig;

    let cfg = sim_cfg();
    let seq = 32usize;
    let elems = cfg.apm_elems(seq);
    let memo = MemoConfig {
        online_admission: true,
        max_db_entries: 256,
        admission_min_attempts: 0,
        intra_batch_dedup: true, // admitter arms dedup: write-path churn
                                 // with a stable entry set
        ..MemoConfig::default()
    };
    let mut rng = Pcg32::seeded(21);
    let entries: Arc<Vec<Vec<f32>>> = Arc::new(
        (0..256).map(|_| unit_vec(&mut rng, cfg.embed_dim)).collect());
    let apm = vec![1.0f32; elems];
    let warm = |tier: &Arc<MemoTier>| {
        let rows: Vec<(&[f32], &[f32])> = entries
            .iter()
            .map(|f| (f.as_slice(), apm.as_slice()))
            .collect();
        // Threshold 2.0: nothing clears it, so every row admits.
        tier.admit_batch(0, &rows, 2.0, 48).unwrap();
    };
    let tier = Arc::new(MemoTier::new(&cfg, seq, Default::default(), &memo));
    warm(&tier);

    // Smoke mode keeps a sizeable window here: the admitter-ratio arms
    // time a multi-thread region, and a sub-millisecond window on a
    // 2-vCPU CI runner would be all scheduler jitter.
    let lookups_per_thread = smoke::iters(2000, 800);
    let mut emit_row = |threads: usize, admitter: &str, hits: usize,
                        secs: f64| -> f64 {
        let total = threads * lookups_per_thread;
        let rate = total as f64 / secs;
        table.row(&[
            threads.to_string(),
            admitter.to_string(),
            total.to_string(),
            format!("{:.3}", hits as f64 / total as f64),
            format!("{:.1}", secs * 1e3),
            format!("{rate:.0}"),
        ]);
        rate
    };

    let mut base4 = 0.0f64;
    for threads in [1usize, 2, 4] {
        let (hits, secs) = read_throughput(&tier, &entries, elems, threads,
                                           lookups_per_thread, None);
        let rate = emit_row(threads, "none", hits, secs);
        if threads == 4 {
            base4 = rate;
        }
    }
    // Fair baseline: the same CPU load (an admitter churning a private
    // warm tier) with zero shared-state interaction with the readers.
    let private =
        Arc::new(MemoTier::new(&cfg, seq, Default::default(), &memo));
    warm(&private);
    let (hits, secs) = read_throughput(&tier, &entries, elems, 4,
                                       lookups_per_thread,
                                       Some(private.clone()));
    let rate_private = emit_row(4, "private", hits, secs);
    // Contended arm: the admitter hammers the shard the readers use.
    let (hits, secs) = read_throughput(&tier, &entries, elems, 4,
                                       lookups_per_thread,
                                       Some(tier.clone()));
    let rate_shared = emit_row(4, "shared", hits, secs);
    let ratio = rate_shared / rate_private.max(1e-9);
    println!(
        "shared-tier read scaling: 4t baseline {base4:.0}/s, private \
         admitter {rate_private:.0}/s, same-shard admitter \
         {rate_shared:.0}/s (shared/private ratio {ratio:.3})"
    );
    // Hard gate only on full runs: a CI smoke runner (2 vCPUs, capped
    // iterations) can deschedule one arm long enough to fail an
    // otherwise-healthy build, and the smoke summary records the ratio
    // for the history trend either way.
    if !smoke::smoke() {
        assert!(
            ratio > 0.7,
            "a concurrent admitter degraded lookup throughput to \
             {ratio:.3} of the equal-CPU baseline — the seqlock read \
             path must not block readers"
        );
    } else if ratio <= 0.7 {
        eprintln!(
            "warn: smoke-mode admitter ratio {ratio:.3} <= 0.7 \
             (not fatal under BENCH_SMOKE; check on a full run)"
        );
    }
    (base4, ratio)
}

/// Write-path A/B (tentpole satellite): dedup prepass on vs off over an
/// identical steady-state workload. Each arm warms its own tier with the
/// same 256 entries, then admits batches whose rows are *all already
/// stored* — the shape a warm clustered workload converges to. With the
/// prepass on, every such batch is resolved against the published
/// snapshot and the snapshot clone + publish + retiree churn are skipped
/// outright; with it off, every batch pays the full copy-on-write writer
/// path just to rediscover row by row that nothing changed. The prepass
/// must *strictly* reduce publishes (that is deterministic); the latency
/// win is asserted on full runs only (a 2-vCPU smoke runner is all
/// scheduler jitter at these timescales). Returns the prepass arm's
/// (admit_p50_ns, admit_p99_ns, publish_skips) for the smoke summary.
fn write_path_section(table: &mut TableWriter) -> (f64, f64, f64) {
    use attmemo::config::MemoConfig;
    use attmemo::util::stats::Summary;

    let cfg = sim_cfg();
    let seq = 32usize;
    let elems = cfg.apm_elems(seq);
    let mut rng = Pcg32::seeded(33);
    let entries: Vec<Vec<f32>> =
        (0..256).map(|_| unit_vec(&mut rng, cfg.embed_dim)).collect();
    let apm = vec![1.0f32; elems];
    let batches = smoke::iters(400, 100);

    // One arm: warm, then `batches` all-dedup 8-row admissions, timed.
    let run_arm = |prepass: bool| -> (Summary, u64, u64) {
        let memo = MemoConfig {
            online_admission: true,
            max_db_entries: 512,
            admission_min_attempts: 0,
            intra_batch_dedup: true,
            dedup_prepass: prepass,
            ..MemoConfig::default()
        };
        let tier = MemoTier::new(&cfg, seq, Default::default(), &memo);
        let rows: Vec<(&[f32], &[f32])> = entries
            .iter()
            .map(|f| (f.as_slice(), apm.as_slice()))
            .collect();
        // Threshold 2.0: nothing clears it, so every row admits.
        tier.admit_batch(0, &rows, 2.0, 48).unwrap();

        let mut lat = Summary::new();
        let mut k = 0usize;
        for _ in 0..batches {
            let rows: Vec<(&[f32], &[f32])> = (0..8)
                .map(|j| {
                    (entries[(k + j) % entries.len()].as_slice(),
                     apm.as_slice())
                })
                .collect();
            let t0 = std::time::Instant::now();
            tier.admit_batch(0, &rows, 0.9, 48).unwrap();
            lat.record(t0.elapsed().as_nanos() as f64);
            k = (k + 8) % entries.len();
        }
        (lat, tier.publishes(), tier.publish_skips())
    };

    let (mut lat_on, pub_on, skips_on) = run_arm(true);
    let (mut lat_off, pub_off, skips_off) = run_arm(false);
    for (arm, lat, publishes, skips) in [
        ("prepass", &mut lat_on, pub_on, skips_on),
        ("publish", &mut lat_off, pub_off, skips_off),
    ] {
        let (p50, p99) = (lat.p50(), lat.p99());
        table.row(&[
            arm.to_string(),
            batches.to_string(),
            format!("{p50:.0}"),
            format!("{p99:.0}"),
            publishes.to_string(),
            skips.to_string(),
        ]);
    }
    println!(
        "write path A/B: prepass admit p50={:.0}ns p99={:.0}ns \
         ({} publishes, {} skips) vs full-publish p50={:.0}ns \
         p99={:.0}ns ({} publishes)",
        lat_on.p50(), lat_on.p99(), pub_on, skips_on,
        lat_off.p50(), lat_off.p99(), pub_off,
    );
    assert!(
        pub_on < pub_off,
        "the dedup prepass must strictly reduce publishes on a \
         steady-state workload: {pub_on} with prepass vs {pub_off} without"
    );
    assert!(skips_on > 0, "prepass arm never took the skip path");
    assert_eq!(skips_off, 0, "prepass off must never skip a publish");
    if !smoke::smoke() {
        assert!(
            lat_on.p99() < lat_off.p99(),
            "skipping the snapshot clone + publish must lower admit p99: \
             {:.0}ns with prepass vs {:.0}ns without",
            lat_on.p99(), lat_off.p99()
        );
    } else if lat_on.p99() >= lat_off.p99() {
        eprintln!(
            "warn: smoke-mode admit p99 {:.0}ns (prepass) >= {:.0}ns \
             (publish) — not fatal under BENCH_SMOKE; check on a full run",
            lat_on.p99(), lat_off.p99()
        );
    }
    (lat_on.p50(), lat_on.p99(), skips_on as f64)
}

/// Generational-index write path (the PR 9 tentpole): mixed batches —
/// one fresh row forcing a real clone + publish plus seven dedup rows —
/// admitted into tiers prefilled to `small` and `large` (10×) entries,
/// generational chunk-sharing publish vs the deep-copy baseline
/// (`MemoConfig::full_index_clone`) on the same build. Two claims are
/// proven:
///
/// * **O(touched)**: the generational arm's per-publish touched-node
///   count (node records + vector rows actually deep-copied) stays flat
///   across the 10× growth, while the baseline's scales with the index —
///   structural chunk-sharing properties with seeded inputs, so they
///   assert even under `BENCH_SMOKE`;
/// * **latency**: mixed-batch admit p99 on the generational arm beats
///   the full-clone baseline at the large size, where the deep copy
///   costs milliseconds against the generational microseconds — orders
///   of magnitude apart, so this too asserts in smoke mode.
///
/// Returns the generational large-size (admit_p99_ns, touched/publish)
/// for the smoke summary (`mixed_admit_p99_ns`, `publish_touched_nodes`).
fn generational_publish_section(table: &mut TableWriter) -> (f64, f64) {
    use attmemo::config::MemoConfig;
    use attmemo::util::stats::Summary;

    let cfg = sim_cfg();
    let seq = 32usize;
    let elems = cfg.apm_elems(seq);
    let small = smoke::iters(1_000, 300);
    let large = 10 * small;
    let batches = smoke::iters(100, 30);
    let apm = vec![1.0f32; elems];

    // One arm: prefill to `n` entries, then `batches` timed mixed
    // admissions. The rng is reseeded per size, so the two clone arms
    // at one size admit byte-identical workloads.
    let run_arm = |full_clone: bool, n: usize| -> (Summary, f64) {
        let memo = MemoConfig {
            online_admission: true,
            max_db_entries: 0,
            admission_min_attempts: 0,
            intra_batch_dedup: true,
            full_index_clone: full_clone,
            ..MemoConfig::default()
        };
        let tier = MemoTier::new(&cfg, seq, Default::default(), &memo);
        let mut rng = Pcg32::seeded(0x9e0 + n as u64);
        let stored: Vec<Vec<f32>> =
            (0..n).map(|_| unit_vec(&mut rng, cfg.embed_dim)).collect();
        for chunk in stored.chunks(64) {
            let rows: Vec<(&[f32], &[f32])> = chunk
                .iter()
                .map(|f| (f.as_slice(), apm.as_slice()))
                .collect();
            // Threshold 2.0: nothing clears it, so every row admits.
            tier.admit_batch(0, &rows, 2.0, 48).unwrap();
        }

        let mut lat = Summary::new();
        let pub0 = tier.publishes();
        let touched0 = tier.publish_touched_nodes();
        for b in 0..batches {
            // Random unit vectors in 64-dim sit near similarity 0 to
            // everything stored, so the fresh row always misses the 0.9
            // dedup floor and forces the clone + publish; the repeats
            // dedup at similarity 1.0.
            let fresh = unit_vec(&mut rng, cfg.embed_dim);
            let mut rows: Vec<(&[f32], &[f32])> =
                vec![(fresh.as_slice(), apm.as_slice())];
            for j in 0..7 {
                rows.push((stored[(b * 7 + j) % n].as_slice(),
                           apm.as_slice()));
            }
            let t0 = std::time::Instant::now();
            tier.admit_batch(0, &rows, 0.9, 48).unwrap();
            lat.record(t0.elapsed().as_nanos() as f64);
        }
        let pubs = tier.publishes() - pub0;
        assert!(
            pubs >= batches as u64,
            "every mixed batch must publish (1 fresh row): {pubs} \
             publishes over {batches} batches"
        );
        let touched = (tier.publish_touched_nodes() - touched0) as f64
            / pubs as f64;
        (lat, touched)
    };

    // Per arm: (p99_ns, touched/publish).
    let mut arms = Vec::new();
    for (name, full_clone, n) in [
        ("generational", false, small),
        ("generational", false, large),
        ("full-clone", true, small),
        ("full-clone", true, large),
    ] {
        let (mut lat, touched) = run_arm(full_clone, n);
        table.row(&[
            name.to_string(),
            n.to_string(),
            batches.to_string(),
            format!("{:.0}", lat.p50()),
            format!("{:.0}", lat.p99()),
            format!("{touched:.0}"),
        ]);
        arms.push((lat.p99(), touched));
    }
    let (gen_small_touched, gen_large_touched) = (arms[0].1, arms[1].1);
    let (gen_large_p99, full_large_p99) = (arms[1].0, arms[3].0);
    println!(
        "generational publish: touched/publish {gen_small_touched:.0} @ \
         {small} → {gen_large_touched:.0} @ {large} entries \
         (full-clone baseline {:.0} → {:.0}); mixed admit p99 \
         {gen_large_p99:.0}ns vs {full_large_p99:.0}ns full-clone @ \
         {large}",
        arms[2].1, arms[3].1,
    );
    // Flatness margin: 3× absorbs graph-degree noise, the additive term
    // absorbs the tail vector chunk — an insert recopies the partially
    // filled tail (up to one chunk of rows, a prefill-size-mod-chunk
    // artefact, not O(n) growth).
    let flat_bound =
        3.0 * gen_small_touched + 2.0 * Hnsw::node_chunk() as f64;
    assert!(
        gen_large_touched <= flat_bound,
        "generational publish must stay O(touched) across 10× growth: \
         {gen_small_touched:.0} touched/publish @ {small} entries vs \
         {gen_large_touched:.0} @ {large} (bound {flat_bound:.0})"
    );
    assert!(
        arms[3].1 > 2.0 * flat_bound,
        "the full-clone baseline must scale with index size (else the \
         A/B proves nothing): {:.0} touched/publish vs generational \
         {gen_large_touched:.0}",
        arms[3].1
    );
    assert!(
        gen_large_p99 < full_large_p99,
        "generational mixed-batch admit p99 must beat the full-clone \
         baseline at {large} entries: {gen_large_p99:.0}ns vs \
         {full_large_p99:.0}ns"
    );
    (gen_large_p99, gen_large_touched)
}

/// Outcome of one affinity A/B arm over the full run.
struct AbOutcome {
    /// dedup_skips / rows offered to admission (the affinity payoff).
    dedup_yield: f64,
    /// Hit rate over the warm epochs (every epoch after the first).
    steady_hit_rate: f64,
    offered: u64,
    dedup: u64,
    steals: u64,
}

/// One A/B arm: a clustered token+embedding workload pushed through a
/// real `AffinityRouter` (`buckets = 1` ⇒ the no-affinity baseline),
/// drained by two alternating replica batchers via `form_batch`, each
/// batch looked up against — and its misses admitted into — one shared
/// `MemoTier` with intra-batch dedup on.
///
/// Two workload shapes share the machinery:
/// * `paraphrase = false` — every cluster has one fixed token prefix;
///   requests edit the tail token (the near-duplicate workload the
///   min-hash was built for);
/// * `paraphrase = true` — every cluster is a *bag* of tokens and each
///   request is a fresh permutation of it (same meaning, new word
///   order): the workload where only a feature-space signature keeps a
///   cluster in one bucket.
fn run_affinity_arm(label: &str, signer: &Signer, buckets: usize,
                    paraphrase: bool, table: &mut TableWriter) -> AbOutcome {
    use attmemo::config::MemoConfig;
    use attmemo::serving::affinity::AffinityRouter;
    use attmemo::serving::batcher::form_batch;
    use std::time::Duration;

    const CLUSTERS: usize = 8;
    const REPLICAS: usize = 2;
    const MAX_BATCH: usize = 16;
    const THRESHOLD: f32 = 0.8;
    // Tight jitter so every same-cluster pair clears THRESHOLD: one stored
    // row per cluster serves the whole cluster, making the steady state
    // identical across arms — the A/B then isolates the dedup yield.
    const NOISE: f32 = 0.005;
    let per_cluster = smoke::iters(16, 8); // requests per cluster per epoch
    let epochs = smoke::iters(4, 2);

    let cfg = sim_cfg();
    let seq = 32usize;
    let elems = cfg.apm_elems(seq);
    let memo = MemoConfig {
        online_admission: true,
        max_db_entries: 0,
        admission_min_attempts: 0,
        intra_batch_dedup: true,
        ..MemoConfig::default()
    };
    let tier = MemoTier::new(&cfg, seq, Default::default(), &memo);
    let router: AffinityRouter<(usize, Vec<f32>)> =
        AffinityRouter::new(buckets, REPLICAS, 8192);

    let mut rng = Pcg32::seeded(61);
    let centres: Vec<Vec<f32>> =
        (0..CLUSTERS).map(|_| unit_vec(&mut rng, cfg.embed_dim)).collect();
    // Each cluster's tokens: a fixed prefix (tail-edit workload) or a
    // disjoint 24-token bag (paraphrase workload; the bags stay inside
    // the 256-token vocab the semantic sketcher is built for).
    let prefixes: Vec<Vec<i32>> = (0..CLUSTERS)
        .map(|c| {
            if paraphrase {
                (0..24).map(|j| 4 + (c as i32) * 24 + j).collect()
            } else {
                (0..seq)
                    .map(|_| 4 + (rng.next_u32() % 250) as i32)
                    .collect()
            }
        })
        .collect();

    let apm = vec![1.0f32; elems];
    let (mut offered, mut dedup) = (0u64, 0u64);
    let (mut steady_hits, mut steady_attempts) = (0u64, 0u64);
    for epoch in 0..epochs {
        // Arrival order interleaves the clusters, so the no-affinity
        // baseline forms mixed batches (the scatter the router fixes).
        for _wave in 0..per_cluster {
            for c in 0..CLUSTERS {
                let mut ids = prefixes[c].clone();
                if paraphrase {
                    rng.shuffle(&mut ids); // same words, new order
                } else {
                    let last = ids.len() - 1;
                    ids[last] = 4 + (rng.next_u32() % 250) as i32;
                }
                let mut f = centres[c].clone();
                for x in f.iter_mut() {
                    *x += NOISE * rng.next_gaussian();
                }
                normalize(&mut f);
                router.push(signer.sign(&ids), (c, f)).unwrap();
            }
        }
        let (mut ep_hits, mut ep_attempts) = (0u64, 0u64);
        let (mut ep_offered, mut ep_dedup) = (0u64, 0u64);
        while !router.is_empty() {
            for replica in 0..REPLICAS {
                let batch = form_batch(&router, replica, MAX_BATCH,
                                       Duration::from_millis(1),
                                       Duration::from_millis(1));
                if batch.is_empty() {
                    continue;
                }
                let mut buf = vec![0.0f32; elems];
                let mut miss: Vec<usize> = Vec::new();
                for (k, (_, f)) in batch.iter().enumerate() {
                    ep_attempts += 1;
                    if tier.lookup_fetch(0, f, 48, THRESHOLD, &mut buf)
                        .is_some()
                    {
                        ep_hits += 1;
                    } else {
                        miss.push(k);
                    }
                }
                if !miss.is_empty() {
                    ep_offered += miss.len() as u64;
                    let rows: Vec<(&[f32], &[f32])> = miss
                        .iter()
                        .map(|&k| (batch[k].1.as_slice(), apm.as_slice()))
                        .collect();
                    let out =
                        tier.admit_batch(0, &rows, THRESHOLD, 48).unwrap();
                    ep_dedup += out.deduped;
                }
            }
        }
        offered += ep_offered;
        dedup += ep_dedup;
        if epoch > 0 {
            steady_hits += ep_hits;
            steady_attempts += ep_attempts;
        }
        table.row(&[
            label.to_string(),
            buckets.to_string(),
            epoch.to_string(),
            format!("{:.3}", ep_hits as f64 / ep_attempts.max(1) as f64),
            ep_offered.to_string(),
            ep_dedup.to_string(),
            format!("{:.3}",
                    ep_dedup as f64 / ep_offered.max(1) as f64),
            router.steals().to_string(),
        ]);
    }
    AbOutcome {
        dedup_yield: dedup as f64 / offered.max(1) as f64,
        steady_hit_rate: steady_hits as f64 / steady_attempts.max(1) as f64,
        offered,
        dedup,
        steals: router.steals(),
    }
}

/// A/B: affinity routing on (8 buckets) vs off (1 bucket) over the same
/// clustered workload. With affinity, a cluster's requests ride in one
/// batch, so nearly every offered miss row dedups against its same-batch
/// twin; the scattered baseline spends admissions on every batch instead.
/// Steady-state hit rate must not regress — one stored row per cluster
/// serves either arm.
fn affinity_ab_section(table: &mut TableWriter) -> (AbOutcome, AbOutcome) {
    let signer = Signer::prefix(32);
    let on = run_affinity_arm("on", &signer, 8, false, table);
    let off = run_affinity_arm("off", &signer, 1, false, table);
    println!(
        "affinity A/B: yield on={:.3} ({}/{} rows, steals={}) \
         off={:.3} ({}/{} rows, steals={}); steady hit rate on={:.3} \
         off={:.3}",
        on.dedup_yield, on.dedup, on.offered, on.steals,
        off.dedup_yield, off.dedup, off.offered, off.steals,
        on.steady_hit_rate, off.steady_hit_rate,
    );
    assert!(
        on.dedup_yield > off.dedup_yield,
        "affinity must raise the intra-batch dedup yield: \
         on {:.3} vs off {:.3}",
        on.dedup_yield, off.dedup_yield
    );
    assert!(
        on.steady_hit_rate >= off.steady_hit_rate,
        "affinity must not lower the warm hit rate: on {:.3} vs off {:.3}",
        on.steady_hit_rate, off.steady_hit_rate
    );
    (on, off)
}

/// A/B: semantic vs prefix signatures, same 8-bucket router, over the
/// *paraphrase* workload (every request permutes its cluster's token
/// bag). The min-hash sketches word order, so paraphrases scatter across
/// buckets and batches come out mixed; the semantic SimHash sketches the
/// bag through the embedding table, so a cluster stays in one bucket —
/// strictly more of the offered miss rows dedup against a same-batch
/// twin, with no warm hit-rate regression (the tier serves both arms
/// from one stored row per cluster either way).
fn signature_ab_section(table: &mut TableWriter) -> (AbOutcome, AbOutcome) {
    // A synthetic embedding table standing in for the model's `tok_emb`
    // (the bench runs hermetically, with no artifacts).
    let mut rng = Pcg32::seeded(97);
    let (vocab, dim) = (256usize, 32usize);
    let emb: Vec<f32> =
        (0..vocab * dim).map(|_| rng.next_gaussian()).collect();
    let semantic = Signer::semantic(
        SemanticSketcher::new(&emb, vocab, dim, 32).unwrap());
    let prefix = Signer::prefix(32);

    let sem = run_affinity_arm("semantic", &semantic, 8, true, table);
    let pre = run_affinity_arm("prefix", &prefix, 8, true, table);
    println!(
        "signature A/B (paraphrase workload): yield semantic={:.3} \
         ({}/{} rows) prefix={:.3} ({}/{} rows); steady hit rate \
         semantic={:.3} prefix={:.3}",
        sem.dedup_yield, sem.dedup, sem.offered,
        pre.dedup_yield, pre.dedup, pre.offered,
        sem.steady_hit_rate, pre.steady_hit_rate,
    );
    assert!(
        sem.dedup_yield > pre.dedup_yield,
        "semantic signatures must raise the paraphrase dedup yield: \
         semantic {:.3} vs prefix {:.3}",
        sem.dedup_yield, pre.dedup_yield
    );
    assert!(
        sem.steady_hit_rate >= pre.steady_hit_rate,
        "semantic signatures must not lower the warm hit rate: \
         semantic {:.3} vs prefix {:.3}",
        sem.steady_hit_rate, pre.steady_hit_rate
    );
    (sem, pre)
}

/// Tallies shared out of [`CbSimEngine`] — the scheduler owns the engine
/// outright, so the A/B reads its counters through this handle after the
/// run.
#[derive(Default)]
struct CbCounters {
    steps: std::sync::atomic::AtomicU64,
    attempts: std::sync::atomic::AtomicU64,
    hits: std::sync::atomic::AtomicU64,
    offered: std::sync::atomic::AtomicU64,
    dedup: std::sync::atomic::AtomicU64,
}

/// Synthetic `StepEngine` for the continuous-vs-fixed A/B: each step runs
/// the real memo-tier lookup + admission per packed row (cluster index
/// and a per-request jitter nonce ride in the first two tokens), then
/// spin-waits a deterministic compute cost — a fixed per-step overhead
/// plus a per-row term. The overhead is what the fixed arm pays for every
/// straggler step of a mixed-length batch and what the continuous arm
/// saves by refilling freed slots.
struct CbSimEngine {
    tier: MemoTier,
    centres: Vec<Vec<f32>>,
    counters: Arc<CbCounters>,
    seq: usize,
    elems: usize,
    threshold: f32,
    base: std::time::Duration,
    per_row: std::time::Duration,
}

impl attmemo::serving::StepEngine for CbSimEngine {
    fn seq_len(&self) -> usize {
        self.seq
    }

    fn step(&mut self, ids: &attmemo::tensor::tensor::IdTensor)
        -> attmemo::Result<attmemo::serving::BatchResult> {
        use std::sync::atomic::Ordering::Relaxed;

        let t0 = std::time::Instant::now();
        let n = ids.shape[0];
        let mut buf = vec![0.0f32; self.elems];
        let mut memo_hits = vec![0u32; n];
        let mut miss: Vec<Vec<f32>> = Vec::new();
        for (row, toks) in ids.data.chunks_exact(self.seq).enumerate() {
            let c = (toks[0] - 4) as usize % self.centres.len();
            let mut f = self.centres[c].clone();
            let mut jitter = Pcg32::seeded(toks[1] as u64);
            for x in f.iter_mut() {
                *x += 0.005 * jitter.next_gaussian();
            }
            normalize(&mut f);
            self.counters.attempts.fetch_add(1, Relaxed);
            if self
                .tier
                .lookup_fetch(0, &f, 48, self.threshold, &mut buf)
                .is_some()
            {
                self.counters.hits.fetch_add(1, Relaxed);
                memo_hits[row] = 1;
            } else {
                miss.push(f);
            }
        }
        if !miss.is_empty() {
            let apm = vec![1.0f32; self.elems];
            let rows: Vec<(&[f32], &[f32])> = miss
                .iter()
                .map(|f| (f.as_slice(), apm.as_slice()))
                .collect();
            let out =
                self.tier.admit_batch(0, &rows, self.threshold, 48)?;
            self.counters.offered.fetch_add(rows.len() as u64, Relaxed);
            self.counters.dedup.fetch_add(out.deduped, Relaxed);
        }
        let cost = self.base + self.per_row * n as u32;
        while t0.elapsed() < cost {
            std::hint::spin_loop();
        }
        self.counters.steps.fetch_add(1, Relaxed);
        Ok(attmemo::serving::BatchResult {
            logits: attmemo::tensor::tensor::Tensor::new(
                vec![n, 2], vec![0.0; n * 2])?,
            labels: vec![1; n],
            memo_hits,
            seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

/// Outcome of one continuous-vs-fixed arm.
struct CbOutcome {
    /// Request p99 over the warm mixed-length phase (ms, arrival→final
    /// chunk).
    p99_ms: f64,
    /// Hits / lookup attempts over the whole run (cold wave included).
    hit_rate: f64,
    /// dedup_skips / rows offered to admission (cold wave).
    dedup_yield: f64,
    /// Engine steps executed across both phases.
    steps: u64,
}

/// One arm of the continuous-vs-fixed A/B. Two phases through the arm's
/// own serving machinery:
///
/// 1. a **cold wave** — per cluster, exactly one slot-sized cohort of
///    single-step requests. Single-step cohorts behave identically under
///    both schedulers (the whole cohort joins, steps once, and leaves
///    together), so admission order, hit pattern, and dedup yield are
///    deterministic and *equal across arms* — the A/B isolates
///    scheduling, not admission luck;
/// 2. a **warm mixed-length phase** — interleaved clusters, 1..=4 steps
///    per request, every lookup a hit. Here the arms genuinely differ:
///    the fixed arm freezes each batch until its longest member drains
///    (paying the per-step overhead for ever-emptier batches), while the
///    continuous arm refills freed slots at every step boundary. Request
///    p99 is measured over this phase only.
fn run_cb_arm(continuous: bool, table: &mut TableWriter) -> CbOutcome {
    use attmemo::config::MemoConfig;
    use attmemo::serving::affinity::AffinityRouter;
    use attmemo::serving::batcher::form_batch;
    use attmemo::serving::{run_fixed_batch, ContinuousScheduler, Request};
    use attmemo::util::stats::Summary;
    use std::sync::atomic::Ordering::Relaxed;
    use std::time::Duration;

    const CLUSTERS: usize = 8;
    const SLOTS: usize = 16;
    const THRESHOLD: f32 = 0.8;
    let waves = smoke::iters(48, 12);

    let cfg = sim_cfg();
    let seq = 32usize;
    let elems = cfg.apm_elems(seq);
    let memo = MemoConfig {
        online_admission: true,
        max_db_entries: 0,
        admission_min_attempts: 0,
        intra_batch_dedup: true,
        ..MemoConfig::default()
    };
    let mut rng = Pcg32::seeded(113);
    let centres: Vec<Vec<f32>> =
        (0..CLUSTERS).map(|_| unit_vec(&mut rng, cfg.embed_dim)).collect();
    let counters = Arc::new(CbCounters::default());
    let engine = CbSimEngine {
        tier: MemoTier::new(&cfg, seq, Default::default(), &memo),
        centres,
        counters: counters.clone(),
        seq,
        elems,
        threshold: THRESHOLD,
        base: Duration::from_micros(500),
        per_row: Duration::from_micros(15),
    };
    let router: AffinityRouter<Request> =
        AffinityRouter::new(CLUSTERS, 1, 8192);

    // Per-request channel capacity == step count, so chunk sends never
    // block in either arm: the A/B measures scheduling, not backpressure
    // (the stall path has its own e2e tests).
    let mut next_id = 0u64;
    let mut push = |c: usize, steps: usize| {
        let ids = vec![4 + c as i32, 1 + next_id as i32];
        let (req, rx) =
            Request::streaming(next_id, ids, c as u64, steps, steps);
        next_id += 1;
        router.push(c as u64, req).unwrap();
        rx
    };

    let (mut sched, mut fixed_engine) = if continuous {
        (Some(ContinuousScheduler::new(engine, SLOTS,
                                       Duration::from_millis(50))),
         None)
    } else {
        (None, Some(engine))
    };
    let mut drive = |n: usize, lat: &mut Summary| {
        let mut done = 0usize;
        while done < n {
            if let Some(s) = sched.as_mut() {
                let r = s.poll(&router, 0, Duration::from_millis(1))
                    .unwrap();
                for f in &r.finished {
                    lat.record(f.request_ms);
                }
                done += r.finished.len();
            } else {
                let batch = form_batch(&router, 0, SLOTS,
                                       Duration::from_millis(1),
                                       Duration::from_millis(1));
                if batch.is_empty() {
                    continue;
                }
                let d = run_fixed_batch(fixed_engine.as_mut().unwrap(),
                                        batch)
                    .unwrap();
                for f in &d {
                    lat.record(f.request_ms);
                }
                done += d.len();
            }
        }
    };

    // Phase 1: the cold wave, cluster-blocked so every cohort is pure.
    let mut cold_rxs = Vec::with_capacity(CLUSTERS * SLOTS);
    for c in 0..CLUSTERS {
        for _ in 0..SLOTS {
            cold_rxs.push(push(c, 1));
        }
    }
    let mut cold_lat = Summary::new();
    drive(CLUSTERS * SLOTS, &mut cold_lat);

    // Phase 2: warm mixed-length traffic, interleaved arrival order.
    let mut expect = Vec::new();
    let mut warm_rxs = Vec::new();
    for w in 0..waves {
        for c in 0..CLUSTERS {
            let steps = 1 + (w + c) % 4;
            expect.push(steps);
            warm_rxs.push(push(c, steps));
        }
    }
    let mut lat = Summary::new();
    drive(waves * CLUSTERS, &mut lat);

    // Every streamed response arrived complete, in order, ending with a
    // final chunk — in both arms.
    for (i, (rx, steps)) in
        cold_rxs.iter().map(|rx| (rx, &1usize))
            .chain(warm_rxs.iter().zip(&expect))
            .enumerate()
    {
        let chunks: Vec<_> = rx.try_iter().collect();
        assert_eq!(chunks.len(), *steps, "request {i} chunk count");
        assert!(chunks.last().unwrap().last, "request {i} final chunk");
    }

    let attempts = counters.attempts.load(Relaxed);
    let hits = counters.hits.load(Relaxed);
    let offered = counters.offered.load(Relaxed);
    let dedup = counters.dedup.load(Relaxed);
    let out = CbOutcome {
        p99_ms: lat.p99(),
        hit_rate: hits as f64 / attempts.max(1) as f64,
        dedup_yield: dedup as f64 / offered.max(1) as f64,
        steps: counters.steps.load(Relaxed),
    };
    table.row(&[
        if continuous { "continuous" } else { "fixed" }.to_string(),
        (CLUSTERS * SLOTS + waves * CLUSTERS).to_string(),
        out.steps.to_string(),
        format!("{:.2}", out.p99_ms),
        format!("{:.3}", out.hit_rate),
        format!("{:.3}", out.dedup_yield),
    ]);
    out
}

/// A/B: iteration-level vs fixed-membership batching over the same
/// workload and engine cost model. Continuous must execute strictly
/// fewer engine steps (the mechanism: freed slots refill instead of
/// riding out stragglers) and cut request p99, with hit rate and dedup
/// yield within 0.05 of the fixed arm.
fn continuous_batching_section(table: &mut TableWriter)
    -> (CbOutcome, CbOutcome) {
    let fixed = run_cb_arm(false, table);
    let cont = run_cb_arm(true, table);
    println!(
        "continuous batching A/B: p99 continuous={:.2}ms fixed={:.2}ms; \
         steps {} vs {}; hit rate {:.3} vs {:.3}; dedup yield {:.3} vs \
         {:.3}",
        cont.p99_ms, fixed.p99_ms, cont.steps, fixed.steps,
        cont.hit_rate, fixed.hit_rate, cont.dedup_yield,
        fixed.dedup_yield,
    );
    assert!(
        cont.steps < fixed.steps,
        "continuous batching must execute fewer engine steps on a \
         mixed-length workload: {} vs {}",
        cont.steps, fixed.steps
    );
    assert!(
        cont.p99_ms < fixed.p99_ms,
        "continuous batching must cut request p99: {:.2}ms vs {:.2}ms \
         fixed",
        cont.p99_ms, fixed.p99_ms
    );
    assert!(
        (cont.hit_rate - fixed.hit_rate).abs() <= 0.05,
        "warm hit rate must match across arms: continuous {:.3} vs \
         fixed {:.3}",
        cont.hit_rate, fixed.hit_rate
    );
    assert!(
        (cont.dedup_yield - fixed.dedup_yield).abs() <= 0.05,
        "dedup yield must survive continuous batching: continuous {:.3} \
         vs fixed {:.3}",
        cont.dedup_yield, fixed.dedup_yield
    );
    (cont, fixed)
}

fn main() {
    attmemo::util::logger::init();
    let mut summary = SmokeSummary::new();

    let mut table = TableWriter::new(
        "Online memoization warm-up — memo-layer simulation \
         (8-cluster traffic, threshold 0.8)",
        &["capacity", "epoch", "hit_rate", "occupancy", "evictions",
          "lookup_ms", "admit_ms"],
    );
    let epochs = smoke::iters(5, 2);
    let queries = smoke::iters(256, 64);
    // Comfortable budget: warms to ~100% hits, no churn.
    let (warm_rate, lookup_ms) =
        simulate(64, 8, epochs, queries, 0.8, &mut table);
    // Tight budget (below the working set): bounded occupancy, eviction
    // churn, degraded steady state — the knob's failure mode, quantified.
    simulate(4, 8, epochs, queries, 0.8, &mut table);
    table.emit(Some(std::path::Path::new(
        "bench_results/online_memo_sim.csv")));
    summary.push("sim_warm_hit_rate", warm_rate);
    summary.push("sim_lookup_ms_mean", lookup_ms);

    let mut shared = TableWriter::new(
        "Shared memo tier — concurrent readers on one warmed tier \
         (256 entries, exact-match queries; admitter arms exercise the \
         seqlock write path)",
        &["threads", "admitter", "lookups", "hit_rate", "wall_ms",
          "lookups_per_s"],
    );
    let (lookups_per_s, admit_ratio) = shared_tier_section(&mut shared);
    shared.emit(Some(std::path::Path::new(
        "bench_results/online_memo_shared_tier.csv")));
    summary.push("shared_tier_lookups_per_s_4t", lookups_per_s);
    summary.push("shared_tier_admit_ratio", admit_ratio);

    let mut wp = TableWriter::new(
        "Write path A/B — dedup prepass vs full publish on a steady-state \
         all-dedup workload (8-row batches, 256 stored entries)",
        &["arm", "batches", "admit_p50_ns", "admit_p99_ns", "publishes",
          "publish_skips"],
    );
    let (admit_p50, admit_p99, publish_skips) = write_path_section(&mut wp);
    wp.emit(Some(std::path::Path::new(
        "bench_results/online_memo_write_path.csv")));
    summary.push("admit_p50_ns", admit_p50);
    summary.push("admit_p99_ns", admit_p99);
    summary.push("publish_skips", publish_skips);

    let mut gp = TableWriter::new(
        "Generational publish — mixed batches (1 fresh + 7 dedup rows) \
         vs the full-index-clone baseline at 1× and 10× prefill",
        &["arm", "entries", "batches", "admit_p50_ns", "admit_p99_ns",
          "touched_per_publish"],
    );
    let (mixed_p99, touched_per_publish) =
        generational_publish_section(&mut gp);
    gp.emit(Some(std::path::Path::new(
        "bench_results/online_memo_generational_publish.csv")));
    summary.push("mixed_admit_p99_ns", mixed_p99);
    summary.push("publish_touched_nodes", touched_per_publish);

    let mut ab = TableWriter::new(
        "Affinity routing A/B — clustered workload, 2 replicas, \
         shared tier (dedup on)",
        &["arm", "buckets", "epoch", "hit_rate", "offered",
          "dedup_skips", "dedup_yield", "steals"],
    );
    let (aff_on, aff_off) = affinity_ab_section(&mut ab);
    ab.emit(Some(std::path::Path::new(
        "bench_results/online_memo_affinity_ab.csv")));
    summary.push("dedup_yield_affinity_on", aff_on.dedup_yield);
    summary.push("dedup_yield_affinity_off", aff_off.dedup_yield);

    let mut sig_ab = TableWriter::new(
        "Signature A/B — semantic vs prefix on the paraphrase-clustered \
         workload (8 buckets, 2 replicas, dedup on)",
        &["arm", "buckets", "epoch", "hit_rate", "offered",
          "dedup_skips", "dedup_yield", "steals"],
    );
    let (sem, pre) = signature_ab_section(&mut sig_ab);
    sig_ab.emit(Some(std::path::Path::new(
        "bench_results/online_memo_signature_ab.csv")));
    summary.push("dedup_yield_semantic", sem.dedup_yield);
    summary.push("dedup_yield_prefix", pre.dedup_yield);
    summary.push("steady_hit_rate_semantic", sem.steady_hit_rate);
    summary.push("steady_hit_rate_prefix", pre.steady_hit_rate);

    let mut cb = TableWriter::new(
        "Continuous vs fixed batching A/B — mixed-length warm workload \
         after an identical cold wave (16 slots, 8 clusters, shared tier)",
        &["arm", "requests", "engine_steps", "p99_ms", "hit_rate",
          "dedup_yield"],
    );
    let (cb_cont, cb_fixed) = continuous_batching_section(&mut cb);
    cb.emit(Some(std::path::Path::new(
        "bench_results/online_memo_continuous_ab.csv")));
    summary.push("cb_p99_ms", cb_cont.p99_ms);
    summary.push("cb_dedup_yield", cb_cont.dedup_yield);
    summary.push("fixed_p99_ms", cb_fixed.p99_ms);

    // Merged, not overwritten: bench_db_scaling's cold-tier arm records
    // its own keys into the same file.
    summary.emit_merged(std::path::Path::new("BENCH_smoke.json"));
    // CI trend (BENCH_HISTORY=1): gate the warm hit rate, the continuous
    // arm's dedup yield (floor — the refactor must not erode it) and p99
    // (ceiling, with generous headroom for runner variance) against the
    // last committed history entries, then append this run's summary as
    // one new JSON line — the cross-PR perf trajectory the artifacts
    // alone never gave us. The check-only gates run first; the single
    // appending call carries every key into the history.
    if std::env::var("BENCH_HISTORY").map(|v| v == "1").unwrap_or(false) {
        let path = std::path::Path::new("BENCH_history.jsonl");
        let gates = summary
            .check_history(path, "cb_dedup_yield", 0.05)
            .and_then(|()| {
                summary.check_history_ceiling(path, "cb_p99_ms", 2.5)
            })
            .and_then(|()| {
                summary.check_history_ceiling(
                    path, "mixed_admit_p99_ns", 2.5)
            })
            .and_then(|()| {
                summary.check_and_append_history(
                    path, "sim_warm_hit_rate", 0.05)
            });
        match gates {
            Ok(()) => println!("history → BENCH_history.jsonl"),
            Err(e) => {
                eprintln!("BENCH history gate failed: {e}");
                std::process::exit(1);
            }
        }
    }

    match run_engine_section() {
        Ok(()) => {}
        Err(e) => eprintln!("SKIP engine section (no artifacts): {e}"),
    }
}
