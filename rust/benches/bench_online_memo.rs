//! Online-memoization warm-up — the serve-time admission extension.
//!
//! AttMEMO's database is built offline, so a cold or drifting workload is
//! stuck at 0% hits forever; with serve-time admission (AttnCache-style,
//! arXiv 2510.25979) the engine admits miss APMs under a capacity budget
//! and warms to a steady-state hit rate. This bench demonstrates the
//! trajectory:
//!
//! * a **memo-layer simulation** over clustered embedding traffic — always
//!   runs, no artifacts needed: per-epoch hit rate from 0% to steady
//!   state, occupancy vs the budget, eviction churn, and lookup+admit
//!   latency;
//! * an **end-to-end cold engine** over the real test workload when
//!   artifacts are present (skipped otherwise, like every runtime bench).

use attmemo::bench_support::harness::time_ms;
use attmemo::bench_support::TableWriter;
use attmemo::config::{MemoLevel, ModelConfig};
use attmemo::memo::index::HnswParams;
use attmemo::memo::policy::AdmissionPolicy;
use attmemo::memo::AttentionDb;
use attmemo::util::Pcg32;

fn sim_cfg() -> ModelConfig {
    ModelConfig {
        family: "bert".into(),
        vocab_size: 256,
        hidden: 64,
        layers: 1,
        heads: 4,
        ffn: 128,
        max_len: 32,
        num_classes: 2,
        rel_pos_buckets: 8,
        embed_dim: 64,
        embed_hidden: 128,
        embed_segments: 4,
        causal: false,
    }
}

fn normalize(v: &mut [f32]) {
    let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
    v.iter_mut().for_each(|x| *x /= n);
}

fn unit_vec(rng: &mut Pcg32, dim: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
    normalize(&mut v);
    v
}

/// Simulated serve loop at the memoization layer: clustered queries, a
/// threshold, admission with a per-layer budget.
fn simulate(capacity: usize, clusters: usize, epochs: usize,
            queries: usize, threshold: f32, table: &mut TableWriter) {
    let cfg = sim_cfg();
    let seq = 32usize;
    let elems = cfg.apm_elems(seq);
    let mut db = AttentionDb::new(&cfg, seq, HnswParams::default());
    let gate = AdmissionPolicy::new(true, 0);
    let mut rng = Pcg32::seeded(7);
    let centres: Vec<Vec<f32>> =
        (0..clusters).map(|_| unit_vec(&mut rng, cfg.embed_dim)).collect();

    let mut attempts = 0u64;
    let mut evictions = 0u64;
    for epoch in 0..epochs {
        let mut hits = 0usize;
        let mut lookup_ms = 0.0f64;
        let mut admit_ms = 0.0f64;
        for q in 0..queries {
            let mut query = centres[q % clusters].clone();
            for x in query.iter_mut() {
                *x += 0.02 * rng.next_gaussian();
            }
            normalize(&mut query);
            attempts += 1;
            let (hit, ms) =
                time_ms(|| db.layer(0).lookup(&query, 48)
                    .filter(|h| h.similarity >= threshold));
            lookup_ms += ms;
            match hit {
                Some(h) => {
                    hits += 1;
                    db.layer(0).mark_reused(h.id);
                }
                None if gate.should_admit(None, attempts, seq as u64) => {
                    let apm = vec![1.0 / seq as f32; elems];
                    let (out, ms) = time_ms(|| {
                        db.layer_mut(0).admit(&query, &apm, capacity).unwrap()
                    });
                    admit_ms += ms;
                    evictions += out.evicted.len() as u64;
                }
                None => {}
            }
            assert!(capacity == 0 || db.layer(0).len() <= capacity,
                    "occupancy exceeded the budget");
        }
        table.row(&[
            capacity.to_string(),
            epoch.to_string(),
            format!("{:.3}", hits as f64 / queries as f64),
            db.layer(0).len().to_string(),
            evictions.to_string(),
            format!("{:.4}", lookup_ms / queries as f64),
            format!("{:.4}", admit_ms / queries.max(1) as f64),
        ]);
    }
}

fn run_engine_section() -> attmemo::Result<()> {
    use attmemo::bench_support::workload;
    use attmemo::eval::evaluate;

    let rt = workload::open_runtime()?;
    let seq_len = rt.artifacts().serving_seq_len;
    let (ids, labels) = workload::test_workload(&rt, "bert", seq_len, 32)?;

    let mut table = TableWriter::new(
        "Cold engine warm-up — per-epoch hit rate (empty DB, admission on)",
        &["epoch", "memo_rate", "admitted", "evicted", "online_entries"],
    );
    let capacity = 128;
    let mut engine = workload::cold_engine(
        &rt, "bert", seq_len, MemoLevel::Aggressive, capacity, 0)?;
    for epoch in 0..4 {
        let r = evaluate(&mut engine, &ids, &labels, 8, false)?;
        table.row(&[
            epoch.to_string(),
            format!("{:.3}", r.memo_rate),
            engine.stats.total_admitted().to_string(),
            engine.stats.total_evicted().to_string(),
            engine
                .online()
                .map_or(0, |t| t.total_entries())
                .to_string(),
        ]);
    }
    table.emit(Some(std::path::Path::new(
        "bench_results/online_memo_engine.csv")));
    if let Some(tier) = engine.online() {
        for li in 0..tier.num_layers() {
            assert!(tier.layer_len(li) <= capacity,
                    "layer {li} over capacity");
        }
    }
    Ok(())
}

/// Shared-tier read scaling: one warmed `MemoTier`, 1..=4 reader threads
/// doing lookup+fetch concurrently. Under the old engine-mutex design
/// these lookups serialized; on the shard `RwLock` they run in parallel,
/// so aggregate lookups/sec should grow with the thread count.
fn shared_tier_section(table: &mut TableWriter) {
    use attmemo::config::MemoConfig;
    use attmemo::memo::MemoTier;
    use std::sync::Arc;

    let cfg = sim_cfg();
    let seq = 32usize;
    let elems = cfg.apm_elems(seq);
    let memo = MemoConfig {
        online_admission: true,
        max_db_entries: 0,
        admission_min_attempts: 0,
        intra_batch_dedup: false, // fill the tier, duplicates welcome
        ..MemoConfig::default()
    };
    let tier = Arc::new(MemoTier::new(&cfg, seq, Default::default(), &memo));
    let mut rng = Pcg32::seeded(21);
    let entries: Vec<Vec<f32>> =
        (0..256).map(|_| unit_vec(&mut rng, cfg.embed_dim)).collect();
    let apm = vec![1.0f32; elems];
    let rows: Vec<(&[f32], &[f32])> = entries
        .iter()
        .map(|f| (f.as_slice(), apm.as_slice()))
        .collect();
    tier.admit_batch(0, &rows, 2.0, 48).unwrap();

    const LOOKUPS_PER_THREAD: usize = 2000;
    for threads in [1usize, 2, 4] {
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for t in 0..threads {
            let tier = tier.clone();
            let entries = entries.clone();
            handles.push(std::thread::spawn(move || {
                let mut dst = vec![0.0f32; elems];
                let mut hits = 0usize;
                for i in 0..LOOKUPS_PER_THREAD {
                    let q = &entries[(i * (t + 1)) % entries.len()];
                    if tier.lookup_fetch(0, q, 48, 0.9, &mut dst).is_some()
                    {
                        hits += 1;
                    }
                }
                hits
            }));
        }
        let hits: usize =
            handles.into_iter().map(|h| h.join().unwrap()).sum();
        let secs = t0.elapsed().as_secs_f64();
        let total = threads * LOOKUPS_PER_THREAD;
        table.row(&[
            threads.to_string(),
            total.to_string(),
            format!("{:.3}", hits as f64 / total as f64),
            format!("{:.1}", secs * 1e3),
            format!("{:.0}", total as f64 / secs),
        ]);
    }
}

/// Outcome of one affinity A/B arm over the full run.
struct AbOutcome {
    /// dedup_skips / rows offered to admission (the affinity payoff).
    dedup_yield: f64,
    /// Hit rate over the warm epochs (every epoch after the first).
    steady_hit_rate: f64,
    offered: u64,
    dedup: u64,
    steals: u64,
}

/// One A/B arm: a clustered token+embedding workload pushed through a
/// real `AffinityRouter` (`buckets = 1` ⇒ the no-affinity baseline),
/// drained by two alternating replica batchers via `form_batch`, each
/// batch looked up against — and its misses admitted into — one shared
/// `MemoTier` with intra-batch dedup on.
fn run_affinity_arm(buckets: usize, table: &mut TableWriter) -> AbOutcome {
    use attmemo::config::MemoConfig;
    use attmemo::memo::MemoTier;
    use attmemo::serving::affinity::{bucket_for, AffinityRouter};
    use attmemo::serving::batcher::form_batch;
    use std::time::Duration;

    const CLUSTERS: usize = 8;
    const PER_CLUSTER: usize = 16; // requests per cluster per epoch
    const EPOCHS: usize = 4;
    const REPLICAS: usize = 2;
    const MAX_BATCH: usize = 16;
    const THRESHOLD: f32 = 0.8;
    // Tight jitter so every same-cluster pair clears THRESHOLD: one stored
    // row per cluster serves the whole cluster, making the steady state
    // identical across arms — the A/B then isolates the dedup yield.
    const NOISE: f32 = 0.005;

    let cfg = sim_cfg();
    let seq = 32usize;
    let elems = cfg.apm_elems(seq);
    let memo = MemoConfig {
        online_admission: true,
        max_db_entries: 0,
        admission_min_attempts: 0,
        intra_batch_dedup: true,
        ..MemoConfig::default()
    };
    let tier = MemoTier::new(&cfg, seq, Default::default(), &memo);
    let router: AffinityRouter<(usize, Vec<f32>)> =
        AffinityRouter::new(buckets, REPLICAS, 8192);

    let mut rng = Pcg32::seeded(61);
    let centres: Vec<Vec<f32>> =
        (0..CLUSTERS).map(|_| unit_vec(&mut rng, cfg.embed_dim)).collect();
    // Each cluster's token prefix: what the signature sketches on.
    let prefixes: Vec<Vec<i32>> = (0..CLUSTERS)
        .map(|_| (0..seq).map(|_| 4 + (rng.next_u32() % 250) as i32).collect())
        .collect();

    let apm = vec![1.0f32; elems];
    let (mut offered, mut dedup) = (0u64, 0u64);
    let (mut steady_hits, mut steady_attempts) = (0u64, 0u64);
    for epoch in 0..EPOCHS {
        // Arrival order interleaves the clusters, so the no-affinity
        // baseline forms mixed batches (the scatter the router fixes).
        for _wave in 0..PER_CLUSTER {
            for c in 0..CLUSTERS {
                let mut ids = prefixes[c].clone();
                let last = ids.len() - 1;
                ids[last] = 4 + (rng.next_u32() % 250) as i32; // tail edit
                let mut f = centres[c].clone();
                for x in f.iter_mut() {
                    *x += NOISE * rng.next_gaussian();
                }
                normalize(&mut f);
                router.push(bucket_for(&ids, buckets), (c, f)).unwrap();
            }
        }
        let (mut ep_hits, mut ep_attempts) = (0u64, 0u64);
        let (mut ep_offered, mut ep_dedup) = (0u64, 0u64);
        while !router.is_empty() {
            for replica in 0..REPLICAS {
                let batch = form_batch(&router, replica, MAX_BATCH,
                                       Duration::from_millis(1),
                                       Duration::from_millis(1));
                if batch.is_empty() {
                    continue;
                }
                let mut buf = vec![0.0f32; elems];
                let mut miss: Vec<usize> = Vec::new();
                for (k, (_, f)) in batch.iter().enumerate() {
                    ep_attempts += 1;
                    if tier.lookup_fetch(0, f, 48, THRESHOLD, &mut buf)
                        .is_some()
                    {
                        ep_hits += 1;
                    } else {
                        miss.push(k);
                    }
                }
                if !miss.is_empty() {
                    ep_offered += miss.len() as u64;
                    let rows: Vec<(&[f32], &[f32])> = miss
                        .iter()
                        .map(|&k| (batch[k].1.as_slice(), apm.as_slice()))
                        .collect();
                    let out =
                        tier.admit_batch(0, &rows, THRESHOLD, 48).unwrap();
                    ep_dedup += out.deduped;
                }
            }
        }
        offered += ep_offered;
        dedup += ep_dedup;
        if epoch > 0 {
            steady_hits += ep_hits;
            steady_attempts += ep_attempts;
        }
        table.row(&[
            if buckets > 1 { "on" } else { "off" }.to_string(),
            buckets.to_string(),
            epoch.to_string(),
            format!("{:.3}", ep_hits as f64 / ep_attempts.max(1) as f64),
            ep_offered.to_string(),
            ep_dedup.to_string(),
            format!("{:.3}",
                    ep_dedup as f64 / ep_offered.max(1) as f64),
            router.steals().to_string(),
        ]);
    }
    AbOutcome {
        dedup_yield: dedup as f64 / offered.max(1) as f64,
        steady_hit_rate: steady_hits as f64 / steady_attempts.max(1) as f64,
        offered,
        dedup,
        steals: router.steals(),
    }
}

/// A/B: affinity routing on (8 buckets) vs off (1 bucket) over the same
/// clustered workload. With affinity, a cluster's requests ride in one
/// batch, so nearly every offered miss row dedups against its same-batch
/// twin; the scattered baseline spends admissions on every batch instead.
/// Steady-state hit rate must not regress — one stored row per cluster
/// serves either arm.
fn affinity_ab_section(table: &mut TableWriter) {
    let on = run_affinity_arm(8, table);
    let off = run_affinity_arm(1, table);
    println!(
        "affinity A/B: yield on={:.3} ({}/{} rows, steals={}) \
         off={:.3} ({}/{} rows, steals={}); steady hit rate on={:.3} \
         off={:.3}",
        on.dedup_yield, on.dedup, on.offered, on.steals,
        off.dedup_yield, off.dedup, off.offered, off.steals,
        on.steady_hit_rate, off.steady_hit_rate,
    );
    assert!(
        on.dedup_yield > off.dedup_yield,
        "affinity must raise the intra-batch dedup yield: \
         on {:.3} vs off {:.3}",
        on.dedup_yield, off.dedup_yield
    );
    assert!(
        on.steady_hit_rate >= off.steady_hit_rate,
        "affinity must not lower the warm hit rate: on {:.3} vs off {:.3}",
        on.steady_hit_rate, off.steady_hit_rate
    );
}

fn main() {
    attmemo::util::logger::init();

    let mut table = TableWriter::new(
        "Online memoization warm-up — memo-layer simulation \
         (8-cluster traffic, threshold 0.8)",
        &["capacity", "epoch", "hit_rate", "occupancy", "evictions",
          "lookup_ms", "admit_ms"],
    );
    // Comfortable budget: warms to ~100% hits, no churn.
    simulate(64, 8, 5, 256, 0.8, &mut table);
    // Tight budget (below the working set): bounded occupancy, eviction
    // churn, degraded steady state — the knob's failure mode, quantified.
    simulate(4, 8, 5, 256, 0.8, &mut table);
    table.emit(Some(std::path::Path::new(
        "bench_results/online_memo_sim.csv")));

    let mut shared = TableWriter::new(
        "Shared memo tier — concurrent readers on one warmed tier \
         (256 entries, exact-match queries)",
        &["threads", "lookups", "hit_rate", "wall_ms", "lookups_per_s"],
    );
    shared_tier_section(&mut shared);
    shared.emit(Some(std::path::Path::new(
        "bench_results/online_memo_shared_tier.csv")));

    let mut ab = TableWriter::new(
        "Affinity routing A/B — clustered workload, 2 replicas, \
         shared tier (dedup on)",
        &["affinity", "buckets", "epoch", "hit_rate", "offered",
          "dedup_skips", "dedup_yield", "steals"],
    );
    affinity_ab_section(&mut ab);
    ab.emit(Some(std::path::Path::new(
        "bench_results/online_memo_affinity_ab.csv")));

    match run_engine_section() {
        Ok(()) => {}
        Err(e) => eprintln!("SKIP engine section (no artifacts): {e}"),
    }
}
