//! Fig. 1 + Table 4 reproduction.
//!
//! Fig. 1: inference-time breakdown (attention scores vs everything else)
//! for the three encoder families at two sequence lengths — attention must
//! dominate and its share must grow with L.
//!
//! Table 4: per-stage breakdown of a memoized self-attention layer
//! (embedding / search / mapping / apply) vs the non-memoized layer.

use attmemo::bench_support::harness::time_ms;
use attmemo::bench_support::{workload, TableWriter};
use attmemo::config::MemoLevel;
use attmemo::model::ModelRunner;

fn main() -> attmemo::Result<()> {
    attmemo::util::logger::init();
    let rt = workload::open_runtime()?;

    // ---- Fig. 1 ----------------------------------------------------------
    let mut fig1 = TableWriter::new(
        "Fig. 1 reproduction — attention share of inference time",
        &["model", "seq_len", "attention_ms", "other_ms", "attention_share"],
    );
    for family in ["bert", "roberta", "deberta"] {
        for seq_len in [64usize, 128] {
            let runner = ModelRunner::load(rt.clone(), family)?;
            let (ids, _) = workload::test_workload(&rt, family, seq_len, 8)?;
            // Warmup (compile).
            let h0 = runner.embed(&ids)?;
            let _ = runner.attn_scores(&h0, 0)?;
            let _ = runner.attn_apply(&h0, &runner.attn_scores(&h0, 0)?, 0)?;
            let _ = runner.head(&h0)?;

            let (h, embed_ms) = time_ms(|| runner.embed(&ids).unwrap());
            let mut attn_ms = 0.0;
            let mut other_ms = embed_ms;
            let mut hh = h;
            for li in 0..runner.config().layers {
                let (apm, s_ms) =
                    time_ms(|| runner.attn_scores(&hh, li).unwrap());
                let (next, a_ms) =
                    time_ms(|| runner.attn_apply(&hh, &apm, li).unwrap());
                attn_ms += s_ms;
                other_ms += a_ms;
                hh = next;
            }
            let (_, head_ms) = time_ms(|| runner.head(&hh).unwrap());
            other_ms += head_ms;
            let share = attn_ms / (attn_ms + other_ms);
            fig1.row(&[
                family.into(),
                seq_len.to_string(),
                format!("{attn_ms:.1}"),
                format!("{other_ms:.1}"),
                format!("{:.1}%", share * 100.0),
            ]);
        }
    }
    fig1.emit(Some(std::path::Path::new("bench_results/fig1_breakdown.csv")));

    // ---- Table 4 ---------------------------------------------------------
    let seq_len = rt.artifacts().serving_seq_len;
    let mut engine = workload::engine_with_db(
        &rt, "bert", seq_len, MemoLevel::Aggressive, 128, false)?;
    let (ids, _) = workload::test_workload(&rt, "bert", seq_len, 32)?;
    // Warm + run several batches to fill the stage summaries.
    for start in (0..32).step_by(8) {
        let chunk = ids.slice0(start, 8)?;
        engine.infer(&chunk)?;
    }
    let st = &mut engine.stats.stages;
    let mut t4 = TableWriter::new(
        "Table 4 reproduction — memoized self-attention stage breakdown \
         (ms per batch, bert)",
        &["stage", "with memoization", "without memoization"],
    );
    let scores_full = {
        // Reference: full-batch score computation time.
        let runner = ModelRunner::load(rt.clone(), "bert")?;
        let chunk = ids.slice0(0, 8)?;
        let h = runner.embed(&chunk)?;
        let _ = runner.attn_scores(&h, 0)?; // warm
        let (_, ms) = time_ms(|| runner.attn_scores(&h, 0).unwrap());
        ms
    };
    t4.row(&["embedding".into(), format!("{:.2}", st.embedding_ms.mean()),
             "N/A".into()]);
    t4.row(&["index search".into(), format!("{:.2}", st.search_ms.mean()),
             "N/A".into()]);
    t4.row(&["APM mapping".into(), format!("{:.2}", st.mapping_ms.mean()),
             "N/A".into()]);
    t4.row(&["score computation (misses only)".into(),
             format!("{:.2}", st.scores_ms.mean()),
             format!("{scores_full:.2}")]);
    t4.row(&["APM·V + FFN (attn_apply)".into(),
             format!("{:.2}", st.apply_ms.mean()),
             format!("{:.2}", st.apply_ms.mean())]);
    t4.emit(Some(std::path::Path::new("bench_results/table4_stages.csv")));
    println!("memoization rate during Table 4 run: {:.2}",
             engine.stats.memoization_rate());
    Ok(())
}
