//! Table 5 + Fig. 4 reproduction — inference accuracy at each memoization
//! level vs the baseline, and the threshold sweep showing memoization rate
//! rising as the threshold drops while accuracy degrades slowly.

use std::sync::Arc;

use attmemo::bench_support::{workload, TableWriter};
use attmemo::config::{MemoConfig, MemoLevel};
use attmemo::eval::evaluate;
use attmemo::model::ModelRunner;
use attmemo::serving::engine::{Engine, EngineOptions};

fn main() -> attmemo::Result<()> {
    attmemo::util::logger::init();
    let rt = workload::open_runtime()?;
    let seq_len = rt.artifacts().serving_seq_len;
    let n_test = 48usize;
    let batch = 8usize;

    // ---- Table 5 ----------------------------------------------------------
    let mut t5 = TableWriter::new(
        "Table 5 reproduction — accuracy at each memoization level (batch 8)",
        &["model", "baseline", "conservative", "moderate", "aggressive",
          "memo_rate@aggr"],
    );
    for family in ["bert", "roberta", "deberta"] {
        let (ids, labels) =
            workload::test_workload(&rt, family, seq_len, n_test)?;
        let built = Arc::new(
            workload::build_db(&rt, family, seq_len, 192)?);
        let mut base = workload::engine_with_shared_db(
            &rt, family, seq_len, MemoLevel::Off, None, false)?;
        let b = evaluate(&mut base, &ids, &labels, batch, true)?;
        let mut cells = vec![family.to_string(),
                             format!("{:.3}", b.accuracy())];
        let mut aggr_rate = 0.0;
        for level in MemoLevel::ALL_ON {
            let mut e = workload::engine_with_shared_db(
                &rt, family, seq_len, level, Some(built.clone()), false)?;
            let r = evaluate(&mut e, &ids, &labels, batch, false)?;
            cells.push(format!("{:.3}", r.accuracy()));
            if level == MemoLevel::Aggressive {
                aggr_rate = r.memo_rate;
            }
        }
        cells.push(format!("{aggr_rate:.2}"));
        t5.row(&cells);
    }
    t5.emit(Some(std::path::Path::new("bench_results/table5_accuracy.csv")));

    // ---- Fig. 4 -----------------------------------------------------------
    let family = "bert";
    let (ids, labels) = workload::test_workload(&rt, family, seq_len, n_test)?;
    let built = Arc::new(workload::build_db(&rt, family, seq_len, 192)?);
    let hi = built.thresholds.conservative;
    let lo = built.thresholds.aggressive;
    let mut fig4 = TableWriter::new(
        "Fig. 4 reproduction — threshold vs memoization rate vs accuracy \
         (bert)",
        &["threshold", "memo_rate", "accuracy"],
    );
    let mut points = vec![2.0f32]; // above any similarity ⇒ no memoization
    for i in 0..=4 {
        points.push(hi + (lo - hi) * i as f32 / 4.0);
    }
    points.push(-1.0); // accept everything ⇒ all memoization
    for thr in points {
        let runner = ModelRunner::load(rt.clone(), family)?;
        let memo = MemoConfig {
            level: MemoLevel::Moderate,
            threshold_override: Some(thr as f64),
            selective: false,
            ..MemoConfig::default()
        };
        let mut e = Engine::new(runner, Some(built.clone()),
                                EngineOptions { memo, seq_len })?;
        let r = evaluate(&mut e, &ids, &labels, batch, false)?;
        fig4.row(&[
            format!("{thr:.3}"),
            format!("{:.3}", r.memo_rate),
            format!("{:.3}", r.accuracy()),
        ]);
    }
    fig4.emit(Some(std::path::Path::new("bench_results/fig4_threshold.csv")));
    Ok(())
}
