//! Ablation bench (DESIGN.md §7) — HNSW construction/search parameters:
//! recall and latency vs `ef_search` and `m`, against the brute-force
//! oracle, on embedding-like unit vectors. Supports the §5.3 claim that
//! index search is never the bottleneck.

use attmemo::bench_support::harness::bench_fn;
use attmemo::bench_support::TableWriter;
use attmemo::memo::index::{BruteForceIndex, Hnsw, HnswParams, VectorIndex};
use attmemo::util::Pcg32;

fn unit_vecs(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|_| {
            let mut v: Vec<f32> =
                (0..dim).map(|_| rng.next_gaussian()).collect();
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            v.iter_mut().for_each(|x| *x /= norm);
            v
        })
        .collect()
}

fn main() {
    attmemo::util::logger::init();
    let dim = 128;
    let n = 2000;
    let queries = 64;
    let vecs = unit_vecs(n, dim, 1);
    let qs = unit_vecs(queries, dim, 2);

    let mut bf = BruteForceIndex::new(dim);
    for v in &vecs {
        bf.add(v);
    }
    let exact: Vec<Vec<u32>> = qs
        .iter()
        .map(|q| bf.search(q, 10).into_iter().map(|h| h.id).collect())
        .collect();

    let mut table = TableWriter::new(
        "Ablation — HNSW recall@10 and latency vs parameters (n=2000, d=128)",
        &["m", "ef_search", "recall@10", "search_ms_p50", "brute_ms_p50"],
    );
    let brute = bench_fn("bf", 2, 60.0, || {
        std::hint::black_box(bf.search(&qs[0], 10));
    });
    for m in [8usize, 16, 32] {
        let params = HnswParams { m, ..HnswParams::default() };
        let mut idx = Hnsw::new(dim, params);
        for v in &vecs {
            idx.add(v);
        }
        for ef in [16usize, 48, 128] {
            let mut found = 0usize;
            for (q, ex) in qs.iter().zip(&exact) {
                let got: Vec<u32> = idx
                    .search_ef(q, 10, ef)
                    .into_iter()
                    .map(|h| h.id)
                    .collect();
                found += ex.iter().filter(|e| got.contains(e)).count();
            }
            let recall = found as f64 / (queries * 10) as f64;
            let lat = bench_fn("h", 2, 40.0, || {
                std::hint::black_box(idx.search_ef(&qs[0], 10, ef));
            });
            table.row(&[
                m.to_string(),
                ef.to_string(),
                format!("{recall:.3}"),
                format!("{:.4}", lat.p50_ms),
                format!("{:.4}", brute.p50_ms),
            ]);
        }
    }
    table.emit(Some(std::path::Path::new("bench_results/hnsw_ablation.csv")));
}
