//! Ablation bench (DESIGN.md §7) — HNSW construction/search parameters:
//! recall and latency vs `ef_search` and `m`, against the brute-force
//! oracle, on embedding-like unit vectors. Supports the §5.3 claim that
//! index search is never the bottleneck.
//!
//! Kernel A/B section: the distance primitive every probe routes through
//! (`kernels::simd`), vectorized vs `--scalar-kernels` forced, at the
//! index's working dimensionality. Emits `simd_dot_speedup` into
//! `BENCH_smoke.json` and floor-gates it against `BENCH_history.jsonl`
//! under `BENCH_HISTORY=1`.

use attmemo::bench_support::harness::bench_fn;
use attmemo::bench_support::{SmokeSummary, TableWriter};
use attmemo::kernels::{self, simd};
use attmemo::memo::index::{BruteForceIndex, Hnsw, HnswParams, VectorIndex};
use attmemo::util::Pcg32;

/// A/B the SIMD primitives (and a whole search on top of them) against
/// the scalar-forced baseline; record the dot-product speedup.
fn kernel_ab_section(
    idx: &Hnsw, qs: &[Vec<f32>], dim: usize, summary: &mut SmokeSummary,
) {
    let a = &qs[0];
    let b = &qs[1];
    // Many calls per timed closure: one 128-dim dot is nanoseconds,
    // below timer resolution.
    let reps = 512usize;
    let prior = kernels::scalar_forced();

    let mut arms = [0.0f64; 2]; // [scalar, vectorized] dot p50 ms
    let mut search = [0.0f64; 2];
    for (i, force) in [true, false].into_iter().enumerate() {
        kernels::set_scalar_kernels(force);
        arms[i] = bench_fn("dot", 2, 40.0, || {
            let mut acc = 0.0f32;
            for _ in 0..reps {
                acc += simd::dot(
                    std::hint::black_box(a),
                    std::hint::black_box(b),
                );
            }
            std::hint::black_box(acc);
        })
        .p50_ms;
        search[i] = bench_fn("search", 2, 40.0, || {
            std::hint::black_box(idx.search_ef(&qs[0], 10, 48));
        })
        .p50_ms;
    }
    kernels::set_scalar_kernels(prior);

    let dot_speedup = arms[0] / arms[1].max(1e-12);
    let search_speedup = search[0] / search[1].max(1e-12);
    let mut table = TableWriter::new(
        "Kernel A/B — simd::dot and HNSW search, scalar vs vectorized",
        &["op", "scalar_ms_p50", "vectorized_ms_p50", "speedup"],
    );
    table.row(&[
        format!("dot (d={dim}, {reps} reps)"),
        format!("{:.4}", arms[0]),
        format!("{:.4}", arms[1]),
        format!("{dot_speedup:.2}x"),
    ]);
    table.row(&[
        "search_ef(k=10, ef=48)".into(),
        format!("{:.4}", search[0]),
        format!("{:.4}", search[1]),
        format!("{search_speedup:.2}x"),
    ]);
    table.emit(Some(std::path::Path::new(
        "bench_results/hnsw_kernel_ab.csv")));

    summary.push("simd_dot_speedup", dot_speedup);
}

fn unit_vecs(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|_| {
            let mut v: Vec<f32> =
                (0..dim).map(|_| rng.next_gaussian()).collect();
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            v.iter_mut().for_each(|x| *x /= norm);
            v
        })
        .collect()
}

fn main() {
    attmemo::util::logger::init();
    let dim = 128;
    let n = 2000;
    let queries = 64;
    let vecs = unit_vecs(n, dim, 1);
    let qs = unit_vecs(queries, dim, 2);

    let mut bf = BruteForceIndex::new(dim);
    for v in &vecs {
        bf.add(v);
    }
    let exact: Vec<Vec<u32>> = qs
        .iter()
        .map(|q| bf.search(q, 10).into_iter().map(|h| h.id).collect())
        .collect();

    let mut table = TableWriter::new(
        "Ablation — HNSW recall@10 and latency vs parameters (n=2000, d=128)",
        &["m", "ef_search", "recall@10", "search_ms_p50", "brute_ms_p50"],
    );
    let brute = bench_fn("bf", 2, 60.0, || {
        std::hint::black_box(bf.search(&qs[0], 10));
    });
    for m in [8usize, 16, 32] {
        let params = HnswParams { m, ..HnswParams::default() };
        let mut idx = Hnsw::new(dim, params);
        for v in &vecs {
            idx.add(v);
        }
        for ef in [16usize, 48, 128] {
            let mut found = 0usize;
            for (q, ex) in qs.iter().zip(&exact) {
                let got: Vec<u32> = idx
                    .search_ef(q, 10, ef)
                    .into_iter()
                    .map(|h| h.id)
                    .collect();
                found += ex.iter().filter(|e| got.contains(e)).count();
            }
            let recall = found as f64 / (queries * 10) as f64;
            let lat = bench_fn("h", 2, 40.0, || {
                std::hint::black_box(idx.search_ef(&qs[0], 10, ef));
            });
            table.row(&[
                m.to_string(),
                ef.to_string(),
                format!("{recall:.3}"),
                format!("{:.4}", lat.p50_ms),
                format!("{:.4}", brute.p50_ms),
            ]);
        }
    }
    table.emit(Some(std::path::Path::new("bench_results/hnsw_ablation.csv")));

    // Kernel A/B over a default-parameter index on the same vectors.
    let mut idx = Hnsw::new(dim, HnswParams::default());
    for v in &vecs {
        idx.add(v);
    }
    let mut summary = SmokeSummary::new();
    kernel_ab_section(&idx, &qs, dim, &mut summary);
    summary.emit_merged(std::path::Path::new("BENCH_smoke.json"));
    if std::env::var("BENCH_HISTORY").map(|v| v == "1").unwrap_or(false) {
        // Floor gate: the distance primitive's vectorized speedup must
        // not collapse (generous margin for shared-runner noise).
        match summary.check_and_append_history(
            std::path::Path::new("BENCH_history.jsonl"),
            "simd_dot_speedup",
            2.0,
        ) {
            Ok(()) => println!("history → BENCH_history.jsonl"),
            Err(e) => {
                eprintln!("BENCH history gate failed: {e}");
                std::process::exit(1);
            }
        }
    }
}
