//! Table 7 reproduction — impact of selective memoization (Eq. 3):
//! inference-time reduction and memoization-rate delta of the
//! performance-model policy vs always-attempt, per family and batch size.

use std::sync::Arc;

use attmemo::bench_support::{workload, TableWriter};
use attmemo::config::MemoLevel;
use attmemo::eval::evaluate;

fn main() -> attmemo::Result<()> {
    attmemo::util::logger::init();
    let rt = workload::open_runtime()?;
    let seq_len = rt.artifacts().serving_seq_len;
    let n_test = 24usize;

    let mut table = TableWriter::new(
        "Table 7 reproduction — selective memoization (Eq. 3) impact",
        &["model", "batch", "always_s", "selective_s", "time_reduction",
          "memo_rate_always", "memo_rate_selective", "active_layers"],
    );
    for family in ["bert", "roberta", "deberta", "gpt"] {
        let (ids, labels) =
            workload::test_workload(&rt, family, seq_len, n_test)?;
        let built = Arc::new(
            workload::build_db(&rt, family, seq_len, 160)?);
        for batch in [1usize, 8] {
            let mut always = workload::engine_with_shared_db(
                &rt, family, seq_len, MemoLevel::Moderate,
                Some(built.clone()), false)?;
            evaluate(&mut always, &ids.slice0(0, batch)?, &labels[..batch],
                     batch, false)?; // warm
            let a = evaluate(&mut always, &ids, &labels, batch, false)?;

            let mut sel = workload::engine_with_shared_db(
                &rt, family, seq_len, MemoLevel::Moderate,
                Some(built.clone()), true)?;
            evaluate(&mut sel, &ids.slice0(0, batch)?, &labels[..batch],
                     batch, false)?;
            let s = evaluate(&mut sel, &ids, &labels, batch, false)?;

            let active = built
                .policy(built.thresholds.moderate, true)
                .active_layers((batch * seq_len) as u64)
                .len();
            table.row(&[
                family.into(),
                batch.to_string(),
                format!("{:.2}", a.seconds),
                format!("{:.2}", s.seconds),
                format!("{:+.1}%",
                        (a.seconds - s.seconds) / a.seconds * 100.0),
                format!("{:.2}", a.memo_rate),
                format!("{:.2}", s.memo_rate),
                format!("{active}/{}",
                        built.profiles.len()),
            ]);
        }
    }
    table.emit(Some(std::path::Path::new(
        "bench_results/table7_selective.csv")));
    Ok(())
}
