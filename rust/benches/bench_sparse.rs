//! Fig. 14 + Table 8 reproduction — AttMemo composed with sparsity-pruned
//! models (§6.8): speedup and accuracy for the pruned bert variants at each
//! memoization level.

use std::sync::Arc;

use attmemo::bench_support::{workload, TableWriter};
use attmemo::config::{MemoConfig, MemoLevel};
use attmemo::eval::evaluate;
use attmemo::memo::builder::DbBuilder;
use attmemo::model::ModelRunner;
use attmemo::serving::engine::{Engine, EngineOptions};

fn main() -> attmemo::Result<()> {
    attmemo::util::logger::init();
    let rt = workload::open_runtime()?;
    let seq_len = rt.artifacts().serving_seq_len;
    let family = "bert";
    let info = rt.artifacts().family(family)?;
    if info.sparse_variants.is_empty() {
        println!("no sparse variants in the artifacts — rebuild with the \
                  full (non-fast) pipeline");
        return Ok(());
    }
    let tags: Vec<String> =
        info.sparse_variants.iter().map(|v| v.tag.clone()).collect();
    let (ids, labels) = workload::test_workload(&rt, family, seq_len, 32)?;

    let mut table = TableWriter::new(
        "Fig. 14 / Table 8 reproduction — AttMemo on pruned models",
        &["variant", "level", "baseline_s", "memo_s", "speedup", "accuracy",
          "memo_rate"],
    );
    let ds = workload::dataset_for(&rt, family, seq_len, true)?;
    let (train_ids, _) = rt.artifacts().load_dataset(&ds)?;
    let db_ids = train_ids.slice0(0, 160)?;

    for tag in &tags {
        // DB must be built with the *same* (pruned) model that serves.
        let runner = ModelRunner::load_sparse(rt.clone(), family, tag)?;
        let built = Arc::new(DbBuilder::new(&runner).build(&db_ids)?);

        let base_runner = ModelRunner::load_sparse(rt.clone(), family, tag)?;
        let memo_off = MemoConfig { level: MemoLevel::Off,
                                    ..MemoConfig::default() };
        let mut base = Engine::new(base_runner, None,
                                   EngineOptions { memo: memo_off, seq_len })?;
        evaluate(&mut base, &ids.slice0(0, 8)?, &labels[..8], 8, true)?;
        let b = evaluate(&mut base, &ids, &labels, 8, true)?;

        for level in MemoLevel::ALL_ON {
            let r2 = ModelRunner::load_sparse(rt.clone(), family, tag)?;
            let memo = MemoConfig { level, selective: false,
                                    ..MemoConfig::default() };
            let mut e = Engine::new(r2, Some(built.clone()),
                                    EngineOptions { memo, seq_len })?;
            evaluate(&mut e, &ids.slice0(0, 8)?, &labels[..8], 8, false)?;
            let r = evaluate(&mut e, &ids, &labels, 8, false)?;
            table.row(&[
                tag.clone(),
                level.name().into(),
                format!("{:.2}", b.seconds),
                format!("{:.2}", r.seconds),
                format!("{:.2}x", b.seconds / r.seconds),
                format!("{:.3}", r.accuracy()),
                format!("{:.2}", r.memo_rate),
            ]);
        }
    }
    table.emit(Some(std::path::Path::new("bench_results/table8_sparse.csv")));
    println!("dense-model baseline accuracy (manifest): {:.3}",
             info.accuracy);
    for v in &info.sparse_variants {
        println!("  {}: python-side accuracy {:.3} (sparsity {:.0}%)",
                 v.tag, v.accuracy, v.sparsity * 100.0);
    }
    Ok(())
}
