//! MemoTier concurrency + persistence tests.
//!
//! The hermetic tests exercise the shared tier directly (no artifacts, no
//! PJRT): reader threads look up concurrently with an admitter per layer,
//! proving the seqlock snapshot scheme loses no hits, never serves a
//! reused slot's stale bytes (payloads are tagged per cluster and every
//! fetched payload must match its match), and never overflows the
//! capacity budget — including under eviction churn, tombstone
//! compactions and a concurrent `save_warm`. A save→load round trip
//! proves the warm hit rate survives a "restart". The final tests drive
//! real engine replicas and skip without artifacts, like every
//! runtime-gated test.

use std::sync::Arc;

use attmemo::config::{MemoConfig, MemoLevel, ModelConfig};
use attmemo::memo::index::HnswParams;
use attmemo::memo::MemoTier;
use attmemo::util::Pcg32;

const LAYERS: usize = 2;
const SEQ: usize = 16;

fn cfg() -> ModelConfig {
    ModelConfig {
        family: "bert".into(),
        vocab_size: 256,
        hidden: 32,
        layers: LAYERS,
        heads: 2,
        ffn: 64,
        max_len: 16,
        num_classes: 2,
        rel_pos_buckets: 8,
        embed_dim: 16,
        embed_hidden: 32,
        embed_segments: 4,
        causal: false,
    }
}

fn memo(capacity: usize) -> MemoConfig {
    MemoConfig {
        level: MemoLevel::Aggressive,
        online_admission: true,
        max_db_entries: capacity,
        admission_min_attempts: 0,
        ..MemoConfig::default()
    }
}

/// [`memo`] plus a file-backed cold spill tier rooted at `dir`.
fn two_tier_memo(hot: usize, cold: usize,
                 dir: &std::path::Path) -> MemoConfig {
    MemoConfig {
        cold_tier_dir: Some(dir.to_path_buf()),
        cold_capacity: cold,
        ..memo(hot)
    }
}

fn normalize(v: &mut [f32]) {
    let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
    v.iter_mut().for_each(|x| *x /= n);
}

/// `k` unit-vector cluster centres.
fn centres(seed: u64, k: usize, dim: usize) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..k)
        .map(|_| {
            let mut v: Vec<f32> =
                (0..dim).map(|_| rng.next_gaussian()).collect();
            normalize(&mut v);
            v
        })
        .collect()
}

fn near(rng: &mut Pcg32, centre: &[f32], noise: f32) -> Vec<f32> {
    let mut v: Vec<f32> = centre
        .iter()
        .map(|&c| c + noise * rng.next_gaussian())
        .collect();
    normalize(&mut v);
    v
}

/// N reader threads + 1 admitter thread per layer, all against one tier:
/// readers run lock-free against published snapshots while admissions
/// publish new ones. Afterwards, every cluster the admitters warmed must
/// be a hit (no lost hits) and occupancy must respect the budget
/// throughout.
#[test]
fn concurrent_readers_and_admitters_lose_no_hits() {
    const CLUSTERS: usize = 16;
    const CAPACITY: usize = 32; // comfortably above the working set
    const READERS_PER_LAYER: usize = 3;
    const THRESHOLD: f32 = 0.8;

    let c = cfg();
    let elems = c.apm_elems(SEQ);
    let tier = Arc::new(MemoTier::new(&c, SEQ, HnswParams::default(),
                                      &memo(CAPACITY)));
    let cents = Arc::new(centres(42, CLUSTERS, c.embed_dim));

    let mut threads = Vec::new();
    for li in 0..LAYERS {
        // One admitter per layer: feeds clustered rows in small batches.
        {
            let tier = tier.clone();
            let cents = cents.clone();
            threads.push(std::thread::spawn(move || {
                let mut rng = Pcg32::seeded(100 + li as u64);
                for round in 0..12 {
                    let feats: Vec<Vec<f32>> = (0..CLUSTERS)
                        .map(|k| near(&mut rng, &cents[k], 0.02))
                        .collect();
                    let apm = vec![round as f32; elems];
                    let rows: Vec<(&[f32], &[f32])> = feats
                        .iter()
                        .map(|f| (f.as_slice(), apm.as_slice()))
                        .collect();
                    tier.admit_batch(li, &rows, THRESHOLD, 48).unwrap();
                    assert!(tier.layer_len(li) <= CAPACITY,
                            "occupancy exceeded budget mid-run");
                }
            }));
        }
        // Reader threads: concurrent lookups + fetches on the same shard.
        for r in 0..READERS_PER_LAYER {
            let tier = tier.clone();
            let cents = cents.clone();
            threads.push(std::thread::spawn(move || {
                let mut rng = Pcg32::seeded(1000 + (li * 10 + r) as u64);
                let mut dst = vec![0.0f32; elems];
                for i in 0..400 {
                    let q = near(&mut rng, &cents[i % CLUSTERS], 0.02);
                    // Hit or miss both fine mid-churn; what matters is
                    // that fetched payloads are always internally
                    // consistent (epoch-checked against one snapshot).
                    let _ = tier.lookup_fetch(li, &q, 48, THRESHOLD,
                                              &mut dst);
                }
            }));
        }
    }
    for t in threads {
        t.join().expect("worker thread");
    }

    assert!(tier.admissions() > 0, "admitters must have stored entries");
    let mut dst = vec![0.0f32; elems];
    for li in 0..LAYERS {
        assert!(tier.layer_len(li) <= CAPACITY,
                "layer {li} over capacity");
        assert!(tier.layer_len(li) > 0, "layer {li} never warmed");
        // No lost hits: with capacity above the working set, every centre
        // the admitter fed must now resolve on a fresh lookup.
        let mut rng = Pcg32::seeded(7);
        for (k, centre) in cents.iter().enumerate() {
            let q = near(&mut rng, centre, 0.01);
            let hit = tier.lookup_fetch(li, &q, 64, THRESHOLD, &mut dst);
            assert!(hit.is_some(),
                    "layer {li} lost cluster {k} despite spare capacity");
        }
        // Every live entry is self-consistent after the churn.
        tier.read_layer(li, |layer| {
            for id in layer.live_ids() {
                layer.arena().get(id).unwrap();
                let v = layer.index_vector(id).to_vec();
                let hit = layer.lookup(&v, 64).unwrap();
                assert_eq!(hit.id, id, "layer {li} index/arena misaligned");
            }
        });
    }
}

/// Seqlock stress (tentpole): N reader threads race one admitter per
/// layer through heavy eviction churn and tombstone compactions — the
/// tight capacity plus a stream of throwaway "junk" admissions forces
/// both. Every cluster's payload is a constant tag, so any fetched
/// payload that does not match its matched cluster would prove a
/// stale-slot (torn) read; the epoch-checked snapshot path must make
/// that impossible while occupancy respects the budget throughout.
#[test]
fn seqlock_readers_race_admit_evict_compact() {
    const CLUSTERS: usize = 8;
    const CAPACITY: usize = 12; // tight: junk churn forces evictions
    const READERS_PER_LAYER: usize = 3;
    const ROUNDS: usize = 30;
    const THRESHOLD: f32 = 0.9;

    let c = cfg();
    let elems = c.apm_elems(SEQ);
    let dim = c.embed_dim;
    let tier = Arc::new(MemoTier::new(&c, SEQ, HnswParams::default(),
                                      &memo(CAPACITY)));
    let cents = Arc::new(centres(71, CLUSTERS, dim));

    let mut threads = Vec::new();
    let mut reader_hits = Vec::new();
    for li in 0..LAYERS {
        // Admitter: alternates a wave of tagged cluster rows (payload =
        // cluster index everywhere) with a wave of far-away junk rows
        // (payload ≥ 1000) — the junk keeps the clock evicting and the
        // id space compacting while readers fly.
        {
            let tier = tier.clone();
            let cents = cents.clone();
            threads.push(std::thread::spawn(move || {
                let mut rng = Pcg32::seeded(500 + li as u64);
                for round in 0..ROUNDS {
                    let feats: Vec<Vec<f32>> = (0..CLUSTERS)
                        .map(|k| near(&mut rng, &cents[k], 0.02))
                        .collect();
                    let apms: Vec<Vec<f32>> = (0..CLUSTERS)
                        .map(|k| vec![k as f32; elems])
                        .collect();
                    let rows: Vec<(&[f32], &[f32])> = feats
                        .iter()
                        .zip(&apms)
                        .map(|(f, a)| (f.as_slice(), a.as_slice()))
                        .collect();
                    tier.admit_batch(li, &rows, THRESHOLD, 48).unwrap();
                    assert!(tier.layer_len(li) <= CAPACITY,
                            "occupancy exceeded budget mid-run");

                    let junk: Vec<Vec<f32>> = (0..CLUSTERS)
                        .map(|_| {
                            let mut v: Vec<f32> = (0..dim)
                                .map(|_| rng.next_gaussian())
                                .collect();
                            normalize(&mut v);
                            v
                        })
                        .collect();
                    let japm = vec![1000.0 + round as f32; elems];
                    let rows: Vec<(&[f32], &[f32])> = junk
                        .iter()
                        .map(|f| (f.as_slice(), japm.as_slice()))
                        .collect();
                    tier.admit_batch(li, &rows, THRESHOLD, 48).unwrap();
                    assert!(tier.layer_len(li) <= CAPACITY,
                            "junk wave pushed occupancy over budget");
                }
            }));
        }
        // Readers: every fetched payload must tag-match the queried
        // cluster — a mismatch means a reused slot's bytes leaked
        // through the snapshot discipline.
        for r in 0..READERS_PER_LAYER {
            let tier = tier.clone();
            let cents = cents.clone();
            let handle = std::thread::spawn(move || {
                let mut rng = Pcg32::seeded(9000 + (li * 10 + r) as u64);
                let mut dst = vec![0.0f32; elems];
                let mut hits = 0usize;
                for i in 0..600 {
                    let k = i % CLUSTERS;
                    let q = near(&mut rng, &cents[k], 0.02);
                    if tier
                        .lookup_fetch(li, &q, 48, THRESHOLD, &mut dst)
                        .is_some()
                    {
                        hits += 1;
                        let want = k as f32;
                        assert!(
                            dst[0] == want
                                && dst[elems / 2] == want
                                && dst[elems - 1] == want,
                            "layer {li} cluster {k}: fetched payload \
                             tagged {} — stale/foreign bytes",
                            dst[0]
                        );
                    }
                }
                hits
            });
            reader_hits.push(handle);
        }
    }
    for t in threads {
        t.join().expect("admitter thread");
    }
    let total_hits: usize = reader_hits
        .into_iter()
        .map(|h| h.join().expect("reader thread"))
        .sum();
    assert!(total_hits > 0, "readers never hit a warm entry");
    assert!(tier.admissions() > 0);
    assert!(tier.evictions() > 0, "the tight budget must have churned");

    // No lost hits: one final cluster wave per layer (dedup or admit),
    // then every cluster must resolve — same-batch admissions are never
    // evicted by their own batch and capacity exceeds the cluster count.
    let mut rng = Pcg32::seeded(13);
    let mut dst = vec![0.0f32; elems];
    for li in 0..LAYERS {
        let feats: Vec<Vec<f32>> = (0..CLUSTERS)
            .map(|k| near(&mut rng, &cents[k], 0.01))
            .collect();
        let apms: Vec<Vec<f32>> =
            (0..CLUSTERS).map(|k| vec![k as f32; elems]).collect();
        let rows: Vec<(&[f32], &[f32])> = feats
            .iter()
            .zip(&apms)
            .map(|(f, a)| (f.as_slice(), a.as_slice()))
            .collect();
        tier.admit_batch(li, &rows, THRESHOLD, 48).unwrap();
        assert!(tier.layer_len(li) <= CAPACITY);
        for (k, centre) in cents.iter().enumerate() {
            let q = near(&mut rng, centre, 0.01);
            // Probe floor 0.8, not 0.9: the final wave may have deduped
            // against an older (noisier) twin, and 0.8 still cleanly
            // excludes every other cluster and all junk.
            let hit = tier.lookup_fetch(li, &q, 64, 0.8, &mut dst);
            assert!(hit.is_some(), "layer {li} lost cluster {k}");
            assert_eq!(dst[0], k as f32, "layer {li} cluster {k} payload");
        }
        // Post-churn self-consistency of the published snapshot.
        tier.read_layer(li, |layer| {
            for id in layer.live_ids() {
                layer.arena().get(id).unwrap();
                let v = layer.index_vector(id).to_vec();
                let hit = layer.lookup(&v, 64).unwrap();
                assert_eq!(hit.id, id, "layer {li} index/arena misaligned");
            }
        });
    }
}

/// Retire-list bounding (satellite): a stalled reader pinning one old
/// snapshot while an admitter churns single-row batches must (a) trip
/// the high-water warning counter, (b) keep the retire list within the
/// generation cap via forced epoch-stamp reclaim, and (c) never observe
/// foreign bytes through its pinned view — a probe against the stalled
/// snapshot either re-validates (payload tag intact) or fails cleanly
/// as a miss once its slot has been recycled under it.
#[test]
fn stalled_reader_never_pins_unbounded_generations() {
    const CLUSTERS: usize = 8;
    const CAPACITY: usize = 32;
    const ROUNDS: usize = 64; // single-row churn batches
    const THRESHOLD: f32 = 0.9;

    let c = cfg();
    let elems = c.apm_elems(SEQ);
    let dim = c.embed_dim;
    let tier = MemoTier::new(&c, SEQ, HnswParams::default(),
                             &memo(CAPACITY));
    let cents = centres(91, CLUSTERS, dim);

    // Warm layer 0 with tagged cluster payloads (payload = cluster id).
    let mut rng = Pcg32::seeded(53);
    let feats: Vec<Vec<f32>> = (0..CLUSTERS)
        .map(|k| near(&mut rng, &cents[k], 0.01))
        .collect();
    let apms: Vec<Vec<f32>> =
        (0..CLUSTERS).map(|k| vec![k as f32; elems]).collect();
    let rows: Vec<(&[f32], &[f32])> = feats
        .iter()
        .zip(&apms)
        .map(|(f, a)| (f.as_slice(), a.as_slice()))
        .collect();
    tier.admit_batch(0, &rows, THRESHOLD, 48).unwrap();

    // Pin the warm snapshot: this reader never advances past it.
    let stalled = tier.reader(0);
    assert_eq!(stalled.len(), CLUSTERS, "pinned view missed the warm-up");
    let mut dst = vec![0.0f32; elems];
    let q0 = near(&mut rng, &cents[0], 0.01);
    assert!(stalled.lookup_fetch(&q0, 48, THRESHOLD, &mut dst).is_some());
    assert_eq!(dst[0], 0.0, "pinned view served the wrong payload");

    // Churn: one junk row per batch, each far from every cluster, so
    // every batch misses the dedup prepass, publishes a fresh snapshot
    // and (once full) evicts. The pinned generation blocks in-order
    // reclamation, so the retire list must climb to the cap and then be
    // force-reclaimed — recycling slots the stalled reader still cites.
    let mut stalled_hits = 0usize;
    let mut stalled_misses = 0usize;
    for round in 0..ROUNDS {
        let mut junk: Vec<f32> =
            (0..dim).map(|_| rng.next_gaussian()).collect();
        normalize(&mut junk);
        let japm = vec![1000.0 + round as f32; elems];
        tier.admit_batch(0, &[(junk.as_slice(), japm.as_slice())],
                         THRESHOLD, 48)
            .unwrap();
        assert!(tier.layer_len(0) <= CAPACITY, "budget broken mid-churn");
        assert!(
            tier.retired_generations(0) <= MemoTier::retire_cap(),
            "round {round}: retire list exceeded the generation cap"
        );

        // Probe the pinned view every round: a hit must carry the
        // original cluster tag end to end; a recycled slot must surface
        // as a clean miss (torn read), never as junk payload bytes.
        let k = round % CLUSTERS;
        let q = near(&mut rng, &cents[k], 0.01);
        match stalled.lookup_fetch(&q, 48, THRESHOLD, &mut dst) {
            Some(_) => {
                stalled_hits += 1;
                let want = k as f32;
                assert!(
                    dst[0] == want
                        && dst[elems / 2] == want
                        && dst[elems - 1] == want,
                    "round {round}: pinned view served payload tagged {} \
                     for cluster {k} — foreign bytes leaked through a \
                     forced reclaim",
                    dst[0]
                );
            }
            None => stalled_misses += 1,
        }
    }
    assert_eq!(stalled_hits + stalled_misses, ROUNDS);

    // The stall must have tripped the high-water warning and forced
    // epoch-stamp reclaims past the cap — one slow reader cannot pin an
    // unbounded number of displaced generations.
    assert!(tier.retire_high_water() > 0,
            "retire list never reached high water despite the stall");
    assert!(tier.forced_reclaims() > 0,
            "cap overflow never forced a reclaim");
    assert!(tier.retired_generations(0) <= MemoTier::retire_cap());
    assert!(tier.evictions() > 0, "junk churn never evicted");

    // The pinned view is frozen regardless of everything above.
    assert_eq!(stalled.len(), CLUSTERS);

    // Dropping the stalled reader unblocks in-order reclamation: after a
    // few more publishes the backlog drains to O(1) generations.
    drop(stalled);
    for round in 0..MemoTier::retire_cap() {
        let mut junk: Vec<f32> =
            (0..dim).map(|_| rng.next_gaussian()).collect();
        normalize(&mut junk);
        let japm = vec![5000.0 + round as f32; elems];
        tier.admit_batch(0, &[(junk.as_slice(), japm.as_slice())],
                         THRESHOLD, 48)
            .unwrap();
    }
    assert!(
        tier.retired_generations(0) <= 1,
        "backlog failed to drain after the stalled reader released"
    );

    // The live tier stayed self-consistent through the forced reclaims.
    tier.read_layer(0, |layer| {
        for id in layer.live_ids() {
            layer.arena().get(id).unwrap();
            let v = layer.index_vector(id).to_vec();
            let hit = layer.lookup(&v, 64).unwrap();
            assert_eq!(hit.id, id, "index/arena misaligned after churn");
        }
    });
}

/// Seqlock + persistence (satellite): `save_warm` runs while readers
/// hammer the same shards and an admitter keeps churning — the save
/// quiesces *writers only*, so readers observe no interruption (their
/// payload-tag invariant keeps holding), and the snapshot round-trips
/// into a warm tier that still serves every cluster.
#[test]
fn warm_save_during_active_reads_and_admissions_roundtrips() {
    use std::sync::atomic::{AtomicBool, Ordering};

    const CLUSTERS: usize = 8;
    const THRESHOLD: f32 = 0.9;
    let c = cfg();
    let elems = c.apm_elems(SEQ);
    let dim = c.embed_dim;
    // Unbounded capacity: no eviction can ever touch the warm set, so
    // the post-load assertions are deterministic regardless of how much
    // the concurrent admitter churns before the save lands.
    let m = memo(0);
    let tier = Arc::new(MemoTier::new(&c, SEQ, HnswParams::default(), &m));
    let cents = Arc::new(centres(83, CLUSTERS, dim));

    // Warm every layer with tagged cluster payloads.
    let mut rng = Pcg32::seeded(29);
    for li in 0..LAYERS {
        let feats: Vec<Vec<f32>> = (0..CLUSTERS)
            .map(|k| near(&mut rng, &cents[k], 0.01))
            .collect();
        let apms: Vec<Vec<f32>> =
            (0..CLUSTERS).map(|k| vec![k as f32; elems]).collect();
        let rows: Vec<(&[f32], &[f32])> = feats
            .iter()
            .zip(&apms)
            .map(|(f, a)| (f.as_slice(), a.as_slice()))
            .collect();
        tier.admit_batch(li, &rows, THRESHOLD, 48).unwrap();
    }

    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    // Readers on every layer, asserting the payload-tag invariant.
    for li in 0..LAYERS {
        for r in 0..2 {
            let (tier, cents, stop) =
                (tier.clone(), cents.clone(), stop.clone());
            workers.push(std::thread::spawn(move || {
                let mut rng = Pcg32::seeded(7000 + (li * 10 + r) as u64);
                let mut dst = vec![0.0f32; elems];
                let mut i = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let k = i % CLUSTERS;
                    let q = near(&mut rng, &cents[k], 0.02);
                    if tier
                        .lookup_fetch(li, &q, 48, THRESHOLD, &mut dst)
                        .is_some()
                    {
                        assert_eq!(dst[0], k as f32,
                                   "stale payload during concurrent save");
                    }
                    i += 1;
                }
            }));
        }
    }
    // One admitter churning junk into layer 0 (admissions must interleave
    // with the save's writer-quiesced sections, never deadlock).
    {
        let (tier, stop) = (tier.clone(), stop.clone());
        workers.push(std::thread::spawn(move || {
            let mut rng = Pcg32::seeded(311);
            let mut round = 0f32;
            while !stop.load(Ordering::Relaxed) {
                let mut v: Vec<f32> = (0..dim)
                    .map(|_| rng.next_gaussian())
                    .collect();
                normalize(&mut v);
                let apm = vec![2000.0 + round; elems];
                tier.admit_batch(0, &[(v.as_slice(), apm.as_slice())],
                                 THRESHOLD, 48)
                    .unwrap();
                round += 1.0;
            }
        }));
    }

    // Save mid-flight: the first snapshot serializes every fresh entry.
    let dir = std::env::temp_dir().join("attmemo_memo_tier_live_save");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("live.atwm");
    attmemo::memo::persist::save_warm(&tier, THRESHOLD, &path).unwrap();

    stop.store(true, Ordering::Relaxed);
    for w in workers {
        w.join().expect("worker thread");
    }

    let (loaded, thr) = attmemo::memo::persist::load_warm(
        &path, &c, &m, HnswParams::default())
        .unwrap();
    assert_eq!(thr, THRESHOLD);
    assert!(loaded.total_entries() >= LAYERS * CLUSTERS,
            "snapshot lost warm entries");
    let mut rng = Pcg32::seeded(37);
    let mut dst = vec![0.0f32; elems];
    for li in 0..LAYERS {
        for (k, centre) in cents.iter().enumerate() {
            let q = near(&mut rng, centre, 0.01);
            let hit = loaded.lookup_fetch(li, &q, 64, THRESHOLD, &mut dst);
            assert!(hit.is_some(),
                    "layer {li} cluster {k} cold after the live save");
            assert_eq!(dst[0], k as f32,
                       "layer {li} cluster {k} payload corrupted");
        }
    }
}

/// Warm-state persistence: hit rate immediately after a load must be at
/// least the hit rate at save time (the acceptance criterion's
/// save→restart→load run starting warm instead of at 0%).
#[test]
fn warm_state_survives_restart_at_full_hit_rate() {
    const CLUSTERS: usize = 8;
    const THRESHOLD: f32 = 0.8;
    let c = cfg();
    let elems = c.apm_elems(SEQ);
    let m = memo(64);
    let tier = MemoTier::new(&c, SEQ, HnswParams::default(), &m);
    let cents = centres(5, CLUSTERS, c.embed_dim);

    // Warm from clustered traffic (the serve loop at the memo layer).
    let mut rng = Pcg32::seeded(11);
    let mut dst = vec![0.0f32; elems];
    for li in 0..LAYERS {
        for i in 0..128 {
            let q = near(&mut rng, &cents[i % CLUSTERS], 0.02);
            if tier.lookup_fetch(li, &q, 48, THRESHOLD, &mut dst).is_none() {
                let apm = vec![i as f32; elems];
                tier.admit_batch(li, &[(q.as_slice(), apm.as_slice())],
                                 THRESHOLD, 48)
                    .unwrap();
            }
        }
    }

    // Deterministic probe set → hit rate at save time.
    let probes: Vec<(usize, Vec<f32>)> = {
        let mut rng = Pcg32::seeded(99);
        (0..64)
            .map(|i| (i % LAYERS, near(&mut rng, &cents[i % CLUSTERS], 0.02)))
            .collect()
    };
    let rate = |t: &MemoTier| {
        let mut dst = vec![0.0f32; elems];
        let hits = probes
            .iter()
            .filter(|(li, q)| {
                t.lookup_fetch(*li, q, 48, THRESHOLD, &mut dst).is_some()
            })
            .count();
        hits as f64 / probes.len() as f64
    };
    let rate_at_save = rate(&tier);
    assert!(rate_at_save > 0.9, "tier failed to warm: {rate_at_save}");

    let dir = std::env::temp_dir().join("attmemo_memo_tier");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tier.atwm");
    attmemo::memo::persist::save_warm(&tier, THRESHOLD, &path).unwrap();
    drop(tier); // the "restart"

    let (reloaded, thr) =
        attmemo::memo::persist::load_warm(&path, &c, &m,
                                          HnswParams::default())
            .unwrap();
    assert_eq!(thr, THRESHOLD);
    let rate_after_load = rate(&reloaded);
    assert!(
        rate_after_load >= rate_at_save,
        "reload lost warmth: {rate_after_load} < {rate_at_save}"
    );
}

/// Satellite (snapshot compat): a version-1 ATWM file — written by the
/// pre-compaction producer — must still restore a warm tier byte-exactly,
/// and the warm hit rate must survive the "restart" exactly as with the
/// current version.
#[test]
fn warm_snapshot_version_one_still_restores() {
    const CLUSTERS: usize = 4;
    const THRESHOLD: f32 = 0.8;
    let c = cfg();
    let elems = c.apm_elems(SEQ);
    let m = memo(32);
    let tier = MemoTier::new(&c, SEQ, HnswParams::default(), &m);
    let cents = centres(21, CLUSTERS, c.embed_dim);
    let mut rng = Pcg32::seeded(23);
    let mut dst = vec![0.0f32; elems];
    for li in 0..LAYERS {
        for i in 0..32 {
            let q = near(&mut rng, &cents[i % CLUSTERS], 0.02);
            if tier.lookup_fetch(li, &q, 48, THRESHOLD, &mut dst).is_none() {
                let apm = vec![i as f32; elems];
                tier.admit_batch(li, &[(q.as_slice(), apm.as_slice())],
                                 THRESHOLD, 48)
                    .unwrap();
            }
        }
    }
    let entries_at_save = tier.total_entries();

    let dir = std::env::temp_dir().join("attmemo_memo_tier_v1");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("old.atwm");
    attmemo::memo::persist::save_warm(&tier, THRESHOLD, &path).unwrap();
    // Rewrite the header's version field to 1: v1 and v2 share a layout
    // (v2 only changed the producer's compaction policy), so the old
    // version must parse — per the PERSISTENCE.md compat policy.
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[4..8].copy_from_slice(&1u32.to_le_bytes());
    std::fs::write(&path, &bytes).unwrap();
    drop(tier); // the restart

    let (reloaded, thr) = attmemo::memo::persist::load_warm(
        &path, &c, &m, HnswParams::default())
        .unwrap();
    assert_eq!(thr, THRESHOLD);
    assert_eq!(reloaded.total_entries(), entries_at_save,
               "v1 snapshot lost entries through the restart");
    let mut rng = Pcg32::seeded(29);
    for li in 0..LAYERS {
        for (k, centre) in cents.iter().enumerate() {
            let q = near(&mut rng, centre, 0.01);
            assert!(
                reloaded.lookup_fetch(li, &q, 48, THRESHOLD, &mut dst)
                    .is_some(),
                "layer {li} cluster {k} cold after a v1 restore"
            );
        }
    }
}

/// Fault injection (satellite): crash mid-demotion — simulated by
/// truncating a cold shard's arena or index-log file at a random byte
/// boundary, or flipping a record byte — then reload. The recovery
/// contract: the tier always comes up, damaged records resolve as
/// *clean misses* (never a served torn payload), undamaged shards lose
/// nothing, and recovery never resurrects more entries than were live
/// at the crash.
#[test]
fn cold_tier_crash_truncation_recovers_to_clean_misses() {
    const ENTRIES: usize = 16;
    const HOT_CAP: usize = 4;
    const COLD_CAP: usize = 32;
    const THRESHOLD: f32 = 0.9;

    let c = cfg();
    let elems = c.apm_elems(SEQ);
    let cents = centres(171, ENTRIES, c.embed_dim);

    // Populate a master cold directory through real demotion churn
    // (payload tag = 10 + entry index, stamped across the whole APM),
    // then "crash" by dropping the tier with no shutdown ritual.
    let master = std::env::temp_dir().join("attmemo_cold_fault_master");
    let _ = std::fs::remove_dir_all(&master);
    let total_cold_at_crash;
    {
        let m = two_tier_memo(HOT_CAP, COLD_CAP, &master);
        let tier =
            MemoTier::with_cold_tier(&c, SEQ, HnswParams::default(), &m)
                .unwrap();
        for li in 0..LAYERS {
            for (k, centre) in cents.iter().enumerate() {
                let apm = vec![(10 + k) as f32; elems];
                tier.admit_batch(
                    li, &[(centre.as_slice(), apm.as_slice())],
                    THRESHOLD, 48,
                )
                .unwrap();
            }
        }
        assert!(tier.demotions() > 0, "populate never demoted");
        total_cold_at_crash = tier.cold_entries();
        assert!(total_cold_at_crash > 0);
    }

    let mut rng = Pcg32::seeded(0xfa017);
    for round in 0..8usize {
        let dir = std::env::temp_dir()
            .join(format!("attmemo_cold_fault_{round}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for li in 0..LAYERS {
            for ext in ["apm", "idx"] {
                let name = format!("cold-layer{li}.{ext}");
                std::fs::copy(master.join(&name), dir.join(&name))
                    .unwrap();
            }
        }
        // Damage layer 0 only: alternate victims across rounds, truncate
        // in the first six rounds, flip a record byte in the last two.
        let victim = if round % 2 == 0 { "apm" } else { "idx" };
        let path = dir.join(format!("cold-layer0.{victim}"));
        let len = std::fs::metadata(&path).unwrap().len() as usize;
        if round < 6 {
            let cut = rng.range_usize(0, len + 1) as u64;
            let f = std::fs::OpenOptions::new()
                .write(true)
                .open(&path)
                .unwrap();
            f.set_len(cut).unwrap();
        } else {
            let mut bytes = std::fs::read(&path).unwrap();
            // Keep the 16-byte ATCD header intact: header damage is a
            // loud configuration error by policy, not a recovery case.
            let floor = if victim == "idx" { 16 } else { 0 };
            let i = rng.range_usize(floor, bytes.len());
            bytes[i] ^= 0xff;
            std::fs::write(&path, &bytes).unwrap();
        }

        let m = two_tier_memo(HOT_CAP, COLD_CAP, &dir);
        let tier =
            MemoTier::with_cold_tier(&c, SEQ, HnswParams::default(), &m)
                .unwrap_or_else(|e| {
                    panic!("round {round}: recovery must survive torn \
                            {victim}: {e}")
                });
        assert_eq!(tier.total_entries(), 0,
                   "the hot tier is volatile — it restarts empty");
        assert!(
            tier.cold_entries() <= total_cold_at_crash,
            "round {round}: recovery resurrected entries ({} > {})",
            tier.cold_entries(), total_cold_at_crash
        );

        // Every lookup either serves an intact original payload or
        // misses cleanly; the undamaged layer 1 must lose nothing.
        let undamaged_live = tier.cold().unwrap().layer_len(1);
        let mut dst = vec![0.0f32; elems];
        let mut layer1_hits = 0usize;
        for li in 0..LAYERS {
            for (k, centre) in cents.iter().enumerate() {
                match tier.lookup_fetch(li, centre, 48, THRESHOLD,
                                        &mut dst) {
                    Some(h) => {
                        assert!(h.similarity > 0.99);
                        let want = (10 + k) as f32;
                        assert!(
                            dst[0] == want
                                && dst[elems / 2] == want
                                && dst[elems - 1] == want,
                            "round {round}: layer {li} entry {k} served \
                             a torn payload (tag {})",
                            dst[0]
                        );
                        if li == 1 {
                            layer1_hits += 1;
                        }
                    }
                    None => {} // torn or hot-at-crash: a clean miss
                }
            }
        }
        assert_eq!(
            layer1_hits, undamaged_live,
            "round {round}: the undamaged layer lost cold entries"
        );
        assert!(tier.cold_hits() > 0,
                "round {round}: the sweep never touched the cold tier");
    }
}

/// Stalled reader × two tiers (satellite): a reader pinning layer 0's
/// hot snapshot across 64 demotion rounds — junk admissions evicting
/// into the cold tier while promotions pull clusters back, recycling
/// arena slots the pinned view still cites. The pinned reader must
/// serve every hit with its original cluster tag or miss cleanly
/// (never cold-recycled or junk bytes), and the retire-list generation
/// cap must hold throughout the two-tier churn.
#[test]
fn stalled_reader_survives_two_tier_demotion_churn() {
    const CLUSTERS: usize = 8;
    const CAPACITY: usize = 8; // exactly the cluster set: junk evicts
    // Ample FIFO window: a cluster's cold entry refreshes every ≤ 8
    // rounds (~72 cold ids), far newer than the 256-id drop horizon, so
    // junk ages out of the cold tier but clusters never do.
    const COLD_CAP: usize = 256;
    const ROUNDS: usize = 64;
    const THRESHOLD: f32 = 0.9;

    let c = cfg();
    let elems = c.apm_elems(SEQ);
    let dim = c.embed_dim;
    let dir = std::env::temp_dir().join("attmemo_cold_stalled");
    let _ = std::fs::remove_dir_all(&dir);
    let m = two_tier_memo(CAPACITY, COLD_CAP, &dir);
    let tier = MemoTier::with_cold_tier(&c, SEQ, HnswParams::default(), &m)
        .unwrap();
    let cents = centres(191, CLUSTERS, dim);

    // Warm layer 0 (payload tag = cluster id), then pin the snapshot.
    let mut rng = Pcg32::seeded(61);
    let feats: Vec<Vec<f32>> = (0..CLUSTERS)
        .map(|k| near(&mut rng, &cents[k], 0.01))
        .collect();
    let apms: Vec<Vec<f32>> =
        (0..CLUSTERS).map(|k| vec![k as f32; elems]).collect();
    let rows: Vec<(&[f32], &[f32])> = feats
        .iter()
        .zip(&apms)
        .map(|(f, a)| (f.as_slice(), a.as_slice()))
        .collect();
    tier.admit_batch(0, &rows, THRESHOLD, 48).unwrap();
    let stalled = tier.reader(0);
    assert_eq!(stalled.len(), CLUSTERS, "pinned view missed the warm-up");

    let mut dst = vec![0.0f32; elems];
    let mut stalled_hits = 0usize;
    let mut stalled_misses = 0usize;
    for round in 0..ROUNDS {
        // A full-capacity junk wave: every live hot entry — clusters
        // included, whatever their reuse counters say — is evicted and
        // demoted into the cold tier while the pinned generation blocks
        // in-order reclaim. This makes the round's promotion below a
        // certainty, not a clock accident.
        let junk: Vec<Vec<f32>> = (0..CAPACITY)
            .map(|_| {
                let mut v: Vec<f32> =
                    (0..dim).map(|_| rng.next_gaussian()).collect();
                normalize(&mut v);
                v
            })
            .collect();
        let japm = vec![1000.0 + round as f32; elems];
        let rows: Vec<(&[f32], &[f32])> = junk
            .iter()
            .map(|f| (f.as_slice(), japm.as_slice()))
            .collect();
        tier.admit_batch(0, &rows, THRESHOLD, 48).unwrap();
        assert!(tier.layer_len(0) <= CAPACITY, "hot budget broken");
        assert!(tier.cold().unwrap().layer_len(0) <= COLD_CAP,
                "cold budget broken");
        assert!(
            tier.retired_generations(0) <= MemoTier::retire_cap(),
            "round {round}: retire list exceeded the cap under \
             two-tier churn"
        );

        // Pull the round's cluster back through the live path: a hot
        // miss promotes it from cold, recycling slots under the pinned
        // reader. The cluster set is never droppable, so this must hit.
        let k = round % CLUSTERS;
        let q = near(&mut rng, &cents[k], 0.01);
        tier.lookup_fetch(0, &q, 48, THRESHOLD, &mut dst)
            .unwrap_or_else(|| {
                panic!("round {round}: cluster {k} lost from both tiers")
            });
        assert_eq!(dst[0], k as f32,
                   "round {round}: live path served foreign bytes");

        // The pinned view: an original tag end to end, or a clean miss
        // — never bytes recycled through the cold tier's round trips.
        let q = near(&mut rng, &cents[k], 0.01);
        match stalled.lookup_fetch(&q, 48, THRESHOLD, &mut dst) {
            Some(_) => {
                stalled_hits += 1;
                let want = k as f32;
                assert!(
                    dst[0] == want
                        && dst[elems / 2] == want
                        && dst[elems - 1] == want,
                    "round {round}: pinned view served payload tagged \
                     {} for cluster {k} — cold-recycled bytes leaked",
                    dst[0]
                );
            }
            None => stalled_misses += 1,
        }
    }
    assert_eq!(stalled_hits + stalled_misses, ROUNDS);
    assert!(tier.evictions() > 0, "junk churn never evicted");
    assert!(tier.demotions() > 0, "eviction churn never demoted");
    assert!(tier.cold_hits() > 0, "promotion path never exercised");
    assert!(tier.promotions() > 0, "cold hits never promoted back");
    assert!(tier.retired_generations(0) <= MemoTier::retire_cap());
    assert_eq!(stalled.len(), CLUSTERS, "pinned view must stay frozen");
}

/// Satellite regression (skips without artifacts): a shape-mismatched
/// shared tier must not be rejected when `level = off` discards the tier
/// anyway — a baseline A/B run over a foreign warm snapshot has to come
/// up, it just must not consult (or mutate) the tier.
#[test]
fn off_level_accepts_mismatched_tier_with_artifacts() {
    use attmemo::bench_support::workload;

    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let memo_on = MemoConfig {
        level: MemoLevel::Aggressive,
        online_admission: true,
        ..MemoConfig::default()
    };
    // A tier built for a *different* model shape (our hermetic cfg(), not
    // the artifact family): wrong layer count, seq_len and embed dim.
    let foreign = Arc::new(MemoTier::new(&cfg(), SEQ, HnswParams::default(),
                                         &memo_on));

    // With memoization on, the mismatch must still be rejected loudly.
    let memo_live = MemoConfig { level: MemoLevel::Aggressive,
                                 ..MemoConfig::default() };
    assert!(
        workload::engine_with_tier(&rt, "bert", seq_len, memo_live, None,
                                   foreign.clone())
            .is_err(),
        "a used tier with the wrong shape must not be accepted"
    );

    // With level = off the tier is unused: construction must succeed and
    // inference must run the pure baseline.
    let memo_off = MemoConfig { level: MemoLevel::Off,
                                ..MemoConfig::default() };
    let mut engine = workload::engine_with_tier(
        &rt, "bert", seq_len, memo_off, None, foreign.clone())
        .expect("level=off must ignore the unused tier's shape");
    assert!(engine.online().is_none(), "off level must drop the tier");
    let (ids, _) = workload::test_workload(&rt, "bert", seq_len, 4).unwrap();
    let out = engine.infer(&ids).unwrap();
    assert!(out.memo_hits.iter().all(|&h| h == 0));
    assert_eq!(foreign.total_entries(), 0, "tier must stay untouched");
}

/// Two real engine replicas over one shared tier (skips without
/// artifacts): replica B must start hot from entries replica A admitted,
/// and both replicas must be able to infer concurrently.
#[test]
fn engine_replicas_share_warm_state_with_artifacts() {
    use attmemo::bench_support::workload;

    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let memo = MemoConfig {
        level: MemoLevel::Aggressive,
        selective: false,
        online_admission: true,
        max_db_entries: 64,
        admission_min_attempts: 0,
        ..MemoConfig::default()
    };
    let tier = workload::online_tier(&rt, "bert", seq_len, &memo).unwrap();
    let mut a = workload::engine_with_tier(&rt, "bert", seq_len,
                                           memo.clone(), None, tier.clone())
        .unwrap();
    let (ids, _) = workload::test_workload(&rt, "bert", seq_len, 8).unwrap();

    // Replica A warms the shared tier.
    let first = a.infer(&ids).unwrap();
    assert!(first.memo_hits.iter().all(|&h| h == 0), "cold start");
    assert!(tier.admissions() > 0, "replica A must admit");

    // A brand-new replica B hits immediately — the warmth lives in the
    // tier, not in any engine.
    let mut b = workload::engine_with_tier(&rt, "bert", seq_len,
                                           memo.clone(), None, tier.clone())
        .unwrap();
    let warm = b.infer(&ids).unwrap();
    let warm_hits: u32 = warm.memo_hits.iter().sum();
    assert!(warm_hits > 0, "replica B saw none of replica A's warm-up");

    // Both replicas infer concurrently against the shared tier: shard
    // read locks serve parallel lookups; no engine-level mutex involved.
    let ids2 = ids.clone();
    let ta = std::thread::spawn(move || {
        let mut hits = 0u32;
        for _ in 0..3 {
            hits += a.infer(&ids2).unwrap().memo_hits.iter().sum::<u32>();
        }
        hits
    });
    let ids3 = ids.clone();
    let tb = std::thread::spawn(move || {
        let mut hits = 0u32;
        for _ in 0..3 {
            hits += b.infer(&ids3).unwrap().memo_hits.iter().sum::<u32>();
        }
        hits
    });
    let ha = ta.join().expect("replica A thread");
    let hb = tb.join().expect("replica B thread");
    assert!(ha > 0 && hb > 0, "both replicas must hit concurrently");
    for li in 0..tier.num_layers() {
        assert!(tier.layer_len(li) <= 64, "layer {li} over budget");
    }
}

/// Real-engine warm restart (skips without artifacts): save the warmed
/// tier, rebuild everything from the snapshot, and verify the very first
/// batch hits at least as much as the pre-restart warm pass.
#[test]
fn engine_restart_starts_warm_with_artifacts() {
    use attmemo::bench_support::workload;

    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let memo = MemoConfig {
        level: MemoLevel::Aggressive,
        selective: false,
        online_admission: true,
        max_db_entries: 64,
        admission_min_attempts: 0,
        ..MemoConfig::default()
    };
    let tier = workload::online_tier(&rt, "bert", seq_len, &memo).unwrap();
    let mut engine = workload::engine_with_tier(
        &rt, "bert", seq_len, memo.clone(), None, tier.clone()).unwrap();
    let (ids, _) = workload::test_workload(&rt, "bert", seq_len, 8).unwrap();

    engine.infer(&ids).unwrap(); // cold pass: admit
    let warm = engine.infer(&ids).unwrap(); // warm pass: hit
    let warm_hits: u32 = warm.memo_hits.iter().sum();
    assert!(warm_hits > 0, "engine never warmed");

    let dir = std::env::temp_dir().join("attmemo_memo_tier_engine");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("engine.atwm");
    attmemo::memo::persist::save_warm(&tier, engine.threshold(), &path)
        .unwrap();
    drop(engine);
    drop(tier); // the restart: all serving state gone

    let fam_cfg = rt.artifacts().family("bert").unwrap().config.clone();
    let (reloaded, _) = attmemo::memo::persist::load_warm(
        &path, &fam_cfg, &memo, HnswParams::default()).unwrap();
    let reloaded = Arc::new(reloaded);
    let mut engine2 = workload::engine_with_tier(
        &rt, "bert", seq_len, memo, None, reloaded.clone()).unwrap();
    let restarted = engine2.infer(&ids).unwrap();
    let restart_hits: u32 = restarted.memo_hits.iter().sum();
    assert!(
        restart_hits >= warm_hits,
        "restart lost warmth: first batch hit {restart_hits} layers vs \
         {warm_hits} before the restart"
    );
}
