//! End-to-end server test: real TCP server + dynamic batcher + memoizing
//! engine, driven by concurrent clients. Skips without artifacts.

use std::sync::Arc;

use attmemo::bench_support::workload;
use attmemo::config::{MemoLevel, ServingConfig};
use attmemo::data::tokenizer::Vocab;
use attmemo::serving::server::{Client, Server};

#[test]
fn server_round_trip_with_concurrent_clients() {
    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let engine = workload::engine_with_db(
        &rt, "bert", seq_len, MemoLevel::Moderate, 48, false)
        .expect("engine");
    let vocab = Arc::new(
        Vocab::load(&rt.artifacts().root().join("vocab.json")).unwrap());

    let mut cfg = ServingConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.seq_len = seq_len;
    cfg.max_batch = 4;
    cfg.max_wait_ms = 10;
    let server = Server::start(engine, vocab, cfg).expect("server start");
    let addr = server.addr.to_string();

    let mut handles = Vec::new();
    for c in 0..3 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            for i in 0..4 {
                let text = if (c + i) % 2 == 0 {
                    "the film was wonderful and superb"
                } else {
                    "a dreadful boring lifeless plot"
                };
                let (label, _hits, ms) = client.infer(text).expect("infer");
                assert!((0..=1).contains(&label));
                assert!(ms > 0.0);
            }
            let stats = client.stats().expect("stats");
            assert!(stats.starts_with("STATS"), "{stats}");
            client.quit().expect("quit");
        }));
    }
    for h in handles {
        h.join().expect("client thread");
    }

    // Unknown command handling.
    let mut c = Client::connect(&addr).unwrap();
    assert!(c.infer("").is_ok() || true);
    c.quit().unwrap();

    server.shutdown();
}

#[test]
fn server_sheds_load_when_queue_full() {
    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let engine = workload::engine_with_db(
        &rt, "bert", seq_len, MemoLevel::Off, 0, false).unwrap();
    let vocab = Arc::new(
        Vocab::load(&rt.artifacts().root().join("vocab.json")).unwrap());
    let mut cfg = ServingConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.seq_len = seq_len;
    cfg.queue_depth = 2; // tiny queue: floods must be rejected, not hang
    cfg.max_batch = 2;
    let server = Server::start(engine, vocab, cfg).unwrap();
    let addr = server.addr.to_string();

    // Sequential requests always succeed (queue never overflows).
    let mut client = Client::connect(&addr).unwrap();
    for _ in 0..3 {
        client.infer("the film was great").unwrap();
    }
    client.quit().unwrap();
    server.shutdown();
}
