//! End-to-end server test: real TCP server + dynamic batcher + memoizing
//! engine(s), driven by concurrent clients. Skips without artifacts.

use std::sync::Arc;

use attmemo::bench_support::workload;
use attmemo::config::{MemoConfig, MemoLevel, ServingConfig, SignatureMode};
use attmemo::data::tokenizer::Vocab;
use attmemo::serving::affinity::bucket_for;
use attmemo::serving::server::{Client, Server};

#[test]
fn server_round_trip_with_concurrent_clients() {
    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let engine = workload::engine_with_db(
        &rt, "bert", seq_len, MemoLevel::Moderate, 48, false)
        .expect("engine");
    let vocab = Arc::new(
        Vocab::load(&rt.artifacts().root().join("vocab.json")).unwrap());

    let cfg = ServingConfig {
        bind: "127.0.0.1:0".into(),
        seq_len,
        max_batch: 4,
        max_wait_ms: 10,
        ..ServingConfig::default()
    };
    let server =
        Server::start(vec![engine], vocab, cfg).expect("server start");
    let addr = server.addr.to_string();

    let mut handles = Vec::new();
    for c in 0..3 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            for i in 0..4 {
                let text = if (c + i) % 2 == 0 {
                    "the film was wonderful and superb"
                } else {
                    "a dreadful boring lifeless plot"
                };
                let (label, _hits, ms) = client.infer(text).expect("infer");
                assert!((0..=1).contains(&label));
                assert!(ms > 0.0);
            }
            let stats = client.stats().expect("stats");
            assert!(stats.starts_with("STATS"), "{stats}");
            client.quit().expect("quit");
        }));
    }
    for h in handles {
        h.join().expect("client thread");
    }

    // Unknown command handling: the server answers (with OK or ERR)
    // instead of dropping the connection.
    let mut c = Client::connect(&addr).unwrap();
    let _ = c.infer("");
    c.quit().unwrap();

    server.shutdown();
}

#[test]
fn server_sheds_load_when_queue_full() {
    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let engine = workload::engine_with_db(
        &rt, "bert", seq_len, MemoLevel::Off, 0, false).unwrap();
    let vocab = Arc::new(
        Vocab::load(&rt.artifacts().root().join("vocab.json")).unwrap());
    let cfg = ServingConfig {
        bind: "127.0.0.1:0".into(),
        seq_len,
        queue_depth: 2, // tiny queue: floods must be rejected, not hang
        max_batch: 2,
        ..ServingConfig::default()
    };
    let server = Server::start(vec![engine], vocab, cfg).unwrap();
    let addr = server.addr.to_string();

    // Sequential requests always succeed (queue never overflows).
    let mut client = Client::connect(&addr).unwrap();
    for _ in 0..3 {
        client.infer("the film was great").unwrap();
    }
    client.quit().unwrap();
    server.shutdown();
}

/// Affinity routing end-to-end: two replicas behind a 4-bucket router,
/// driven with texts that provably span ≥ 2 buckets plus a skewed
/// single-bucket burst that forces the non-home replica to steal. Every
/// request must be answered (work stealing means no bucket starves), and
/// the fleet STATS line must report the affinity gauges.
#[test]
fn affinity_routing_spans_buckets_and_steals() {
    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let vocab = Arc::new(
        Vocab::load(&rt.artifacts().root().join("vocab.json")).unwrap());

    // Pick texts landing in distinct buckets under the serving config —
    // chosen from candidates, so the test does not bet on hash values.
    let buckets = 4usize;
    let candidates = [
        "the film was wonderful and superb",
        "a dreadful boring lifeless plot",
        "an astonishing triumph of craft and heart",
        "utterly tedious and forgettable direction",
        "the cast carries a thin script with charm",
        "a bleak joyless slog from start to finish",
    ];
    let mut by_bucket: std::collections::HashMap<usize, &str> =
        std::collections::HashMap::new();
    for t in candidates {
        by_bucket
            .entry(bucket_for(&vocab.encode(t, seq_len), buckets))
            .or_insert(t);
    }
    assert!(by_bucket.len() >= 2,
            "candidate texts must span at least two buckets");
    let spread: Vec<&str> = by_bucket.values().copied().collect();

    let memo = MemoConfig {
        level: MemoLevel::Aggressive,
        selective: false,
        online_admission: true,
        max_db_entries: 128,
        admission_min_attempts: 0,
        ..MemoConfig::default()
    };
    let tier = workload::online_tier(&rt, "bert", seq_len, &memo).unwrap();
    let engines = (0..2)
        .map(|_| {
            workload::engine_with_tier(&rt, "bert", seq_len, memo.clone(),
                                       None, tier.clone())
                .expect("replica engine")
        })
        .collect::<Vec<_>>();
    let cfg = ServingConfig {
        bind: "127.0.0.1:0".into(),
        seq_len,
        max_batch: 4,
        max_wait_ms: 5,
        replicas: 2,
        affinity_buckets: buckets,
        ..ServingConfig::default()
    };
    let server = Server::start(engines, vocab, cfg).expect("server start");
    let addr = server.addr.to_string();

    // Phase 1 — spread: concurrent clients cycling texts from different
    // buckets; every request must come back.
    let mut handles = Vec::new();
    for c in 0..3usize {
        let addr = addr.clone();
        let texts: Vec<String> =
            spread.iter().map(|t| t.to_string()).collect();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            for i in 0..8 {
                let text = &texts[(c + i) % texts.len()];
                let (label, _, ms) = client.infer(text).expect("infer");
                assert!((0..=1).contains(&label));
                assert!(ms > 0.0);
            }
            client.quit().expect("quit");
        }));
    }
    for h in handles {
        h.join().expect("spread client");
    }

    // Phase 2 — skew: everyone hammers one text (one bucket). While the
    // home replica computes a batch, arrivals are only reachable by the
    // other replica stealing — no request may starve.
    let hot = spread[0].to_string();
    let mut handles = Vec::new();
    for _ in 0..2 {
        let addr = addr.clone();
        let hot = hot.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            for _ in 0..16 {
                client.infer(&hot).expect("skewed infer answered");
            }
            client.quit().expect("quit");
        }));
    }
    for h in handles {
        h.join().expect("skew client");
    }

    let mut c = Client::connect(&addr).unwrap();
    let stats = c.stats().unwrap();
    assert!(stats.contains(&format!("affinity(buckets={buckets}")),
            "STATS must report the router gauges: {stats}");
    assert!(stats.contains("requests=56"),
            "all 24 + 32 requests served: {stats}");
    c.quit().unwrap();
    server.shutdown();
}

/// Semantic signature mode end-to-end (skips without artifacts): the
/// server builds its signer from the model's embedding table (falling
/// back to the min-hash only if the table were missing), serves
/// paraphrase pairs — same words, different order — and keeps reporting
/// the affinity gauges. Adaptive re-bucketing is enabled to exercise the
/// resize plumbing under real traffic.
#[test]
fn semantic_signatures_serve_end_to_end() {
    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let engine = workload::engine_with_db(
        &rt, "bert", seq_len, MemoLevel::Moderate, 48, false)
        .expect("engine");
    let vocab = Arc::new(
        Vocab::load(&rt.artifacts().root().join("vocab.json")).unwrap());
    let cfg = ServingConfig {
        bind: "127.0.0.1:0".into(),
        seq_len,
        max_batch: 4,
        max_wait_ms: 5,
        signature_mode: SignatureMode::Semantic,
        affinity_buckets: 4,
        affinity_adaptive: true,
        ..ServingConfig::default()
    };
    let server = Server::start(vec![engine], vocab, cfg)
        .expect("server start");
    let addr = server.addr.to_string();

    // Paraphrase pairs: the semantic signer buckets each pair together
    // (identical token bags); every request must be answered either way.
    let pairs = [
        ("the film was wonderful and superb",
         "superb and wonderful was the film"),
        ("a dreadful boring lifeless plot",
         "lifeless boring a dreadful plot"),
    ];
    let mut handles = Vec::new();
    for c in 0..2usize {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            for i in 0..8 {
                let (a, b) = pairs[(c + i) % pairs.len()];
                let text = if i % 2 == 0 { a } else { b };
                let (label, _, ms) = client.infer(text).expect("infer");
                assert!((0..=1).contains(&label));
                assert!(ms > 0.0);
            }
            client.quit().expect("quit");
        }));
    }
    for h in handles {
        h.join().expect("paraphrase client");
    }

    let mut c = Client::connect(&addr).unwrap();
    let stats = c.stats().unwrap();
    assert!(stats.contains("affinity("),
            "STATS must report the router gauges: {stats}");
    assert!(stats.contains("requests=16"), "all requests served: {stats}");
    c.quit().unwrap();
    server.shutdown();
}

/// Two engine replicas behind one server, sharing one online `MemoTier`:
/// both batcher threads serve from the shared queue, lookups run in
/// parallel on the tier's lock-free shard snapshots (no global engine
/// mutex on the lookup path), and warm-ups made by either replica count
/// for both.
#[test]
fn two_replicas_share_one_memo_tier() {
    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let memo = MemoConfig {
        level: MemoLevel::Aggressive,
        selective: false,
        online_admission: true,
        max_db_entries: 128,
        admission_min_attempts: 0,
        ..MemoConfig::default()
    };
    let tier = workload::online_tier(&rt, "bert", seq_len, &memo).unwrap();
    let engines = (0..2)
        .map(|_| {
            workload::engine_with_tier(&rt, "bert", seq_len, memo.clone(),
                                       None, tier.clone())
                .expect("replica engine")
        })
        .collect::<Vec<_>>();
    let vocab = Arc::new(
        Vocab::load(&rt.artifacts().root().join("vocab.json")).unwrap());
    let cfg = ServingConfig {
        bind: "127.0.0.1:0".into(),
        seq_len,
        max_batch: 2,
        max_wait_ms: 5,
        replicas: 2,
        ..ServingConfig::default()
    };
    let server = Server::start(engines, vocab, cfg).expect("server start");
    let addr = server.addr.to_string();

    // Concurrent clients repeating a tiny phrase set: the first pass
    // misses and admits; repeats must hit the tier regardless of which
    // replica serves them.
    let mut handles = Vec::new();
    for c in 0..4 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            let mut hits = 0u64;
            for i in 0..8 {
                let text = if (c + i) % 2 == 0 {
                    "the film was wonderful and superb"
                } else {
                    "a dreadful boring lifeless plot"
                };
                let (_, memo_hits, _) = client.infer(text).expect("infer");
                hits += memo_hits as u64;
            }
            client.quit().expect("quit");
            hits
        }));
    }
    let total_hits: u64 =
        handles.into_iter().map(|h| h.join().expect("client")).sum();
    assert!(total_hits > 0,
            "replicas sharing one tier must hit after warm-up");
    assert!(tier.total_entries() > 0, "tier warmed from traffic");
    assert!(tier.admissions() > 0);
    for li in 0..tier.num_layers() {
        assert!(tier.layer_len(li) <= 128, "layer {li} over budget");
    }

    // The aggregate STATS line reports the fleet.
    let mut c = Client::connect(&addr).unwrap();
    let stats = c.stats().unwrap();
    assert!(stats.starts_with("STATS"), "{stats}");
    assert!(stats.contains("requests=32"),
            "fleet STATS must sum both replicas: {stats}");
    c.quit().unwrap();
    server.shutdown();
}
