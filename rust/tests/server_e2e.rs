//! End-to-end server test: real TCP server + dynamic batcher + memoizing
//! engine(s), driven by concurrent clients. The TCP tests skip without
//! artifacts; the continuous-batching tests at the bottom run hermetically
//! against a synthetic `StepEngine`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use attmemo::bench_support::workload;
use attmemo::config::{MemoConfig, MemoLevel, ServingConfig, SignatureMode};
use attmemo::data::tokenizer::Vocab;
use attmemo::serving::affinity::{bucket_for, AffinityRouter};
use attmemo::serving::server::{Client, Server};
use attmemo::serving::{
    BatchResult, ContinuousScheduler, Request, StepEngine,
};
use attmemo::tensor::tensor::{IdTensor, Tensor};

#[test]
fn server_round_trip_with_concurrent_clients() {
    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let engine = workload::engine_with_db(
        &rt, "bert", seq_len, MemoLevel::Moderate, 48, false)
        .expect("engine");
    let vocab = Arc::new(
        Vocab::load(&rt.artifacts().root().join("vocab.json")).unwrap());

    let cfg = ServingConfig {
        bind: "127.0.0.1:0".into(),
        seq_len,
        max_batch: 4,
        max_wait_ms: 10,
        ..ServingConfig::default()
    };
    let server =
        Server::start(vec![engine], vocab, cfg).expect("server start");
    let addr = server.addr.to_string();

    let mut handles = Vec::new();
    for c in 0..3 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            for i in 0..4 {
                let text = if (c + i) % 2 == 0 {
                    "the film was wonderful and superb"
                } else {
                    "a dreadful boring lifeless plot"
                };
                let (label, _hits, ms) = client.infer(text).expect("infer");
                assert!((0..=1).contains(&label));
                assert!(ms > 0.0);
            }
            let stats = client.stats().expect("stats");
            assert!(stats.starts_with("STATS"), "{stats}");
            client.quit().expect("quit");
        }));
    }
    for h in handles {
        h.join().expect("client thread");
    }

    // Unknown command handling: the server answers (with OK or ERR)
    // instead of dropping the connection.
    let mut c = Client::connect(&addr).unwrap();
    let _ = c.infer("");
    c.quit().unwrap();

    server.shutdown();
}

#[test]
fn server_sheds_load_when_queue_full() {
    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let engine = workload::engine_with_db(
        &rt, "bert", seq_len, MemoLevel::Off, 0, false).unwrap();
    let vocab = Arc::new(
        Vocab::load(&rt.artifacts().root().join("vocab.json")).unwrap());
    let cfg = ServingConfig {
        bind: "127.0.0.1:0".into(),
        seq_len,
        queue_depth: 2, // tiny queue: floods must be rejected, not hang
        max_batch: 2,
        ..ServingConfig::default()
    };
    let server = Server::start(vec![engine], vocab, cfg).unwrap();
    let addr = server.addr.to_string();

    // Sequential requests always succeed (queue never overflows).
    let mut client = Client::connect(&addr).unwrap();
    for _ in 0..3 {
        client.infer("the film was great").unwrap();
    }
    client.quit().unwrap();
    server.shutdown();
}

/// Affinity routing end-to-end: two replicas behind a 4-bucket router,
/// driven with texts that provably span ≥ 2 buckets plus a skewed
/// single-bucket burst that forces the non-home replica to steal. Every
/// request must be answered (work stealing means no bucket starves), and
/// the fleet STATS line must report the affinity gauges.
#[test]
fn affinity_routing_spans_buckets_and_steals() {
    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let vocab = Arc::new(
        Vocab::load(&rt.artifacts().root().join("vocab.json")).unwrap());

    // Pick texts landing in distinct buckets under the serving config —
    // chosen from candidates, so the test does not bet on hash values.
    let buckets = 4usize;
    let candidates = [
        "the film was wonderful and superb",
        "a dreadful boring lifeless plot",
        "an astonishing triumph of craft and heart",
        "utterly tedious and forgettable direction",
        "the cast carries a thin script with charm",
        "a bleak joyless slog from start to finish",
    ];
    let mut by_bucket: std::collections::HashMap<usize, &str> =
        std::collections::HashMap::new();
    for t in candidates {
        by_bucket
            .entry(bucket_for(&vocab.encode(t, seq_len), buckets))
            .or_insert(t);
    }
    assert!(by_bucket.len() >= 2,
            "candidate texts must span at least two buckets");
    let spread: Vec<&str> = by_bucket.values().copied().collect();

    let memo = MemoConfig {
        level: MemoLevel::Aggressive,
        selective: false,
        online_admission: true,
        max_db_entries: 128,
        admission_min_attempts: 0,
        ..MemoConfig::default()
    };
    let tier = workload::online_tier(&rt, "bert", seq_len, &memo).unwrap();
    let engines = (0..2)
        .map(|_| {
            workload::engine_with_tier(&rt, "bert", seq_len, memo.clone(),
                                       None, tier.clone())
                .expect("replica engine")
        })
        .collect::<Vec<_>>();
    let cfg = ServingConfig {
        bind: "127.0.0.1:0".into(),
        seq_len,
        max_batch: 4,
        max_wait_ms: 5,
        replicas: 2,
        affinity_buckets: buckets,
        ..ServingConfig::default()
    };
    let server = Server::start(engines, vocab, cfg).expect("server start");
    let addr = server.addr.to_string();

    // Phase 1 — spread: concurrent clients cycling texts from different
    // buckets; every request must come back.
    let mut handles = Vec::new();
    for c in 0..3usize {
        let addr = addr.clone();
        let texts: Vec<String> =
            spread.iter().map(|t| t.to_string()).collect();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            for i in 0..8 {
                let text = &texts[(c + i) % texts.len()];
                let (label, _, ms) = client.infer(text).expect("infer");
                assert!((0..=1).contains(&label));
                assert!(ms > 0.0);
            }
            client.quit().expect("quit");
        }));
    }
    for h in handles {
        h.join().expect("spread client");
    }

    // Phase 2 — skew: everyone hammers one text (one bucket). While the
    // home replica computes a batch, arrivals are only reachable by the
    // other replica stealing — no request may starve.
    let hot = spread[0].to_string();
    let mut handles = Vec::new();
    for _ in 0..2 {
        let addr = addr.clone();
        let hot = hot.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            for _ in 0..16 {
                client.infer(&hot).expect("skewed infer answered");
            }
            client.quit().expect("quit");
        }));
    }
    for h in handles {
        h.join().expect("skew client");
    }

    let mut c = Client::connect(&addr).unwrap();
    let stats = c.stats().unwrap();
    assert!(stats.contains(&format!("affinity(buckets={buckets}")),
            "STATS must report the router gauges: {stats}");
    assert!(stats.contains("requests=56"),
            "all 24 + 32 requests served: {stats}");
    c.quit().unwrap();
    server.shutdown();
}

/// Semantic signature mode end-to-end (skips without artifacts): the
/// server builds its signer from the model's embedding table (falling
/// back to the min-hash only if the table were missing), serves
/// paraphrase pairs — same words, different order — and keeps reporting
/// the affinity gauges. Adaptive re-bucketing is enabled to exercise the
/// resize plumbing under real traffic.
#[test]
fn semantic_signatures_serve_end_to_end() {
    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let engine = workload::engine_with_db(
        &rt, "bert", seq_len, MemoLevel::Moderate, 48, false)
        .expect("engine");
    let vocab = Arc::new(
        Vocab::load(&rt.artifacts().root().join("vocab.json")).unwrap());
    let cfg = ServingConfig {
        bind: "127.0.0.1:0".into(),
        seq_len,
        max_batch: 4,
        max_wait_ms: 5,
        signature_mode: SignatureMode::Semantic,
        affinity_buckets: 4,
        affinity_adaptive: true,
        ..ServingConfig::default()
    };
    let server = Server::start(vec![engine], vocab, cfg)
        .expect("server start");
    let addr = server.addr.to_string();

    // Paraphrase pairs: the semantic signer buckets each pair together
    // (identical token bags); every request must be answered either way.
    let pairs = [
        ("the film was wonderful and superb",
         "superb and wonderful was the film"),
        ("a dreadful boring lifeless plot",
         "lifeless boring a dreadful plot"),
    ];
    let mut handles = Vec::new();
    for c in 0..2usize {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            for i in 0..8 {
                let (a, b) = pairs[(c + i) % pairs.len()];
                let text = if i % 2 == 0 { a } else { b };
                let (label, _, ms) = client.infer(text).expect("infer");
                assert!((0..=1).contains(&label));
                assert!(ms > 0.0);
            }
            client.quit().expect("quit");
        }));
    }
    for h in handles {
        h.join().expect("paraphrase client");
    }

    let mut c = Client::connect(&addr).unwrap();
    let stats = c.stats().unwrap();
    assert!(stats.contains("affinity("),
            "STATS must report the router gauges: {stats}");
    assert!(stats.contains("requests=16"), "all requests served: {stats}");
    c.quit().unwrap();
    server.shutdown();
}

/// Two engine replicas behind one server, sharing one online `MemoTier`:
/// both batcher threads serve from the shared queue, lookups run in
/// parallel on the tier's lock-free shard snapshots (no global engine
/// mutex on the lookup path), and warm-ups made by either replica count
/// for both.
#[test]
fn two_replicas_share_one_memo_tier() {
    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let memo = MemoConfig {
        level: MemoLevel::Aggressive,
        selective: false,
        online_admission: true,
        max_db_entries: 128,
        admission_min_attempts: 0,
        ..MemoConfig::default()
    };
    let tier = workload::online_tier(&rt, "bert", seq_len, &memo).unwrap();
    let engines = (0..2)
        .map(|_| {
            workload::engine_with_tier(&rt, "bert", seq_len, memo.clone(),
                                       None, tier.clone())
                .expect("replica engine")
        })
        .collect::<Vec<_>>();
    let vocab = Arc::new(
        Vocab::load(&rt.artifacts().root().join("vocab.json")).unwrap());
    let cfg = ServingConfig {
        bind: "127.0.0.1:0".into(),
        seq_len,
        max_batch: 2,
        max_wait_ms: 5,
        replicas: 2,
        ..ServingConfig::default()
    };
    let server = Server::start(engines, vocab, cfg).expect("server start");
    let addr = server.addr.to_string();

    // Concurrent clients repeating a tiny phrase set: the first pass
    // misses and admits; repeats must hit the tier regardless of which
    // replica serves them.
    let mut handles = Vec::new();
    for c in 0..4 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            let mut hits = 0u64;
            for i in 0..8 {
                let text = if (c + i) % 2 == 0 {
                    "the film was wonderful and superb"
                } else {
                    "a dreadful boring lifeless plot"
                };
                let (_, memo_hits, _) = client.infer(text).expect("infer");
                hits += memo_hits as u64;
            }
            client.quit().expect("quit");
            hits
        }));
    }
    let total_hits: u64 =
        handles.into_iter().map(|h| h.join().expect("client")).sum();
    assert!(total_hits > 0,
            "replicas sharing one tier must hit after warm-up");
    assert!(tier.total_entries() > 0, "tier warmed from traffic");
    assert!(tier.admissions() > 0);
    for li in 0..tier.num_layers() {
        assert!(tier.layer_len(li) <= 128, "layer {li} over budget");
    }

    // The aggregate STATS line reports the fleet.
    let mut c = Client::connect(&addr).unwrap();
    let stats = c.stats().unwrap();
    assert!(stats.starts_with("STATS"), "{stats}");
    assert!(stats.contains("requests=32"),
            "fleet STATS must sum both replicas: {stats}");
    c.quit().unwrap();
    server.shutdown();
}

/// Zero-cost engine for the hermetic continuous-batching tests: every
/// row gets label 1 immediately, so test timing is dominated entirely by
/// scheduling and consumer behaviour.
struct NullEngine {
    seq: usize,
}

impl StepEngine for NullEngine {
    fn seq_len(&self) -> usize {
        self.seq
    }

    fn step(&mut self, ids: &IdTensor) -> attmemo::Result<BatchResult> {
        let n = ids.shape[0];
        Ok(BatchResult {
            logits: Tensor::new(vec![n, 2], vec![0.0; n * 2])?,
            labels: vec![1; n],
            memo_hits: vec![0; n],
            seconds: 0.0,
        })
    }
}

/// Per-client backpressure end-to-end (hermetic): one slow consumer
/// (depth-1 channel, 25 ms per chunk) shares the scheduler with 32 fast
/// clients. The slow consumer must stall only its own slot — parked
/// after the 2 ms budget — so the fast cohort finishes orders of
/// magnitude sooner than the slow stream's own drain time; and the slow
/// client still receives every one of its chunks.
#[test]
fn slow_consumer_stalls_only_its_own_slot() {
    const SLOW_STEPS: usize = 8;
    const SLOW_DRAIN: Duration = Duration::from_millis(25);

    let q: Arc<AffinityRouter<Request>> =
        Arc::new(AffinityRouter::new(4, 1, 1024));
    let q2 = q.clone();
    let sched_thread = std::thread::spawn(move || {
        let mut sched = ContinuousScheduler::new(
            NullEngine { seq: 8 }, 8, Duration::from_millis(2));
        loop {
            sched.poll(&q2, 0, Duration::from_millis(5)).unwrap();
            if sched.is_idle() && q2.is_closed() && q2.is_empty() {
                return;
            }
        }
    });

    // The slow client first, so it holds a slot before the fast cohort
    // arrives: 8 chunks through a depth-1 channel, 25 ms between reads.
    let (sreq, srx) =
        Request::streaming(999, vec![9, 9], 3, SLOW_STEPS, 1);
    q.try_push(3, sreq).unwrap();
    let slow = std::thread::spawn(move || {
        let t0 = Instant::now();
        let mut got = 0usize;
        loop {
            let ch = srx
                .recv_timeout(Duration::from_secs(20))
                .expect("slow chunk");
            got += 1;
            if ch.last {
                return (got, t0.elapsed());
            }
            std::thread::sleep(SLOW_DRAIN);
        }
    });

    let mut fast = Vec::new();
    for i in 0..32u64 {
        let (req, rx) = Request::streaming(i, vec![1, 2], i % 4, 2, 4);
        let t0 = Instant::now();
        q.try_push(i % 4, req).unwrap();
        fast.push(std::thread::spawn(move || {
            loop {
                let ch = rx
                    .recv_timeout(Duration::from_secs(10))
                    .expect("fast chunk");
                if ch.last {
                    return t0.elapsed();
                }
            }
        }));
    }

    let fast_max = fast
        .into_iter()
        .map(|h| h.join().expect("fast client"))
        .max()
        .unwrap();
    let (slow_chunks, slow_took) = slow.join().expect("slow client");
    q.close();
    sched_thread.join().expect("scheduler thread");

    assert_eq!(slow_chunks, SLOW_STEPS,
               "the slow client still gets its whole stream");
    assert!(slow_took >= SLOW_DRAIN * (SLOW_STEPS as u32 - 1),
            "slow stream is paced by its own drain rate: {slow_took:?}");
    // The structural claim: the fast cohort never waits behind the slow
    // consumer. Its slowest member beats the slow stream's *minimum*
    // possible duration with a wide margin for CI scheduling noise.
    assert!(fast_max < Duration::from_millis(150),
            "a slow consumer delayed the fast cohort: {fast_max:?}");
}

/// Join/leave interleaving (hermetic, deterministically driven): mixed
/// request lengths through a 4-slot scheduler polled by hand. Every
/// request must emit exactly one chunk per poll from its join, finishing
/// at poll `join + steps - 1` — no response is delayed past its own
/// completion step, early finishers free their slots at step boundaries,
/// and mid-flight joins start stepping immediately.
#[test]
fn joins_and_leaves_happen_at_step_boundaries() {
    let q: AffinityRouter<Request> = AffinityRouter::new(1, 1, 64);
    let mut sched = ContinuousScheduler::new(
        NullEngine { seq: 4 }, 4, Duration::from_secs(1));
    let mk = |id: u64, steps: usize| {
        Request::streaming(id, vec![1], 0, steps, steps)
    };

    // Wave A: three requests of lengths 3, 1, 2 (one slot stays free).
    let (a1, a1_rx) = mk(1, 3);
    let (a2, a2_rx) = mk(2, 1);
    let (a3, a3_rx) = mk(3, 2);
    for r in [a1, a2, a3] {
        q.try_push(0, r).unwrap();
    }
    let r = sched.poll(&q, 0, Duration::ZERO).unwrap();
    assert_eq!(r.joins, 3);
    assert_eq!(r.stepped, 3);
    assert_eq!(r.finished.len(), 1, "a2 (1 step) leaves at poll 1");
    assert_eq!(r.finished[0].id.0, 2);

    // Wave B joins mid-flight, into a2's freed slot plus the spare one.
    let (b1, b1_rx) = mk(4, 2);
    let (b2, b2_rx) = mk(5, 1);
    q.try_push(0, b1).unwrap();
    q.try_push(0, b2).unwrap();
    let r = sched.poll(&q, 0, Duration::ZERO).unwrap();
    assert_eq!(r.joins, 2, "mid-flight joins fill freed slots");
    assert_eq!(r.stepped, 4, "a1, a3, b1, b2 all step together");
    let mut done: Vec<u64> =
        r.finished.iter().map(|f| f.id.0).collect();
    done.sort_unstable();
    assert_eq!(done, vec![3, 5], "a3 and b2 leave at their own ends");

    let r = sched.poll(&q, 0, Duration::ZERO).unwrap();
    assert_eq!(r.stepped, 2, "only a1 and b1 remain");
    let mut done: Vec<u64> =
        r.finished.iter().map(|f| f.id.0).collect();
    done.sort_unstable();
    assert_eq!(done, vec![1, 4]);
    assert!(sched.is_idle());

    // Every stream: one chunk per poll from its join, final chunk at
    // join_poll + steps - 1, steps numbered 0..steps.
    for (rx, steps) in [
        (a1_rx, 3usize),
        (a2_rx, 1),
        (a3_rx, 2),
        (b1_rx, 2),
        (b2_rx, 1),
    ] {
        let chunks: Vec<_> = rx.try_iter().collect();
        assert_eq!(chunks.len(), steps);
        for (s, ch) in chunks.iter().enumerate() {
            assert_eq!(ch.step as usize, s);
            assert_eq!(ch.last, s + 1 == steps);
        }
    }
}
