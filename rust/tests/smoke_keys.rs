//! CI gate: after the bench-smoke suite runs, `BENCH_smoke.json` must
//! carry every headline key in `REQUIRED_SMOKE_KEYS` — the key list
//! lives in `bench_support::smoke` next to the emitters, not in a
//! workflow shell loop. Gated behind `SMOKE_KEYS_FILE` (the path CI
//! points at the freshly produced summary) so plain `cargo test` runs,
//! which have no bench output to inspect, skip it.

use attmemo::bench_support::smoke::{SmokeSummary, REQUIRED_SMOKE_KEYS};

#[test]
fn bench_smoke_json_carries_required_keys() {
    let Ok(path) = std::env::var("SMOKE_KEYS_FILE") else {
        eprintln!("SMOKE_KEYS_FILE not set; skipping smoke-key gate");
        return;
    };
    SmokeSummary::require_keys(std::path::Path::new(&path),
                               REQUIRED_SMOKE_KEYS)
        .unwrap_or_else(|e| panic!("required smoke keys gate: {e}"));
}
