//! Property-based tests (hand-rolled driver — proptest is not in the
//! offline registry): randomized inputs from seeded PCG streams, many
//! cases per property, failures reported with their case seed so they
//! replay deterministically.

use attmemo::config::json::{self, Json};
use attmemo::kernels::{attention, simd};
use attmemo::memo::arena::{page_size, ApmArena, ApmId};
use attmemo::memo::builder::alpha_at;
use attmemo::memo::gather::{copy_gather, GatherWindow};
use attmemo::memo::index::{BruteForceIndex, Hnsw, HnswParams, VectorIndex};
use attmemo::memo::thresholds::Thresholds;
use attmemo::tensor::ops;
use attmemo::util::Pcg32;

/// Run `f` for `cases` seeds, panicking with the failing seed.
fn forall(cases: u64, f: impl Fn(&mut Pcg32)) {
    for seed in 0..cases {
        let mut rng = Pcg32::seeded(0xa77e30 ^ seed);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed for seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

#[test]
fn prop_similarity_score_bounds_and_identity() {
    forall(50, |rng| {
        let rows = rng.range_usize(1, 8);
        let cols = rng.range_usize(2, 32);
        let mut a: Vec<f32> =
            (0..rows * cols).map(|_| rng.next_f32() + 1e-3).collect();
        let mut b: Vec<f32> =
            (0..rows * cols).map(|_| rng.next_f32() + 1e-3).collect();
        ops::softmax_rows(&mut a, rows, cols);
        ops::softmax_rows(&mut b, rows, cols);
        let s = ops::similarity_score(&a, &b, rows, cols);
        assert!((-1e-5..=1.0 + 1e-5).contains(&s), "s={s}");
        let s_aa = ops::similarity_score(&a, &a, rows, cols);
        assert!((s_aa - 1.0).abs() < 1e-5);
        // Symmetry.
        let s_ba = ops::similarity_score(&b, &a, rows, cols);
        assert!((s - s_ba).abs() < 1e-5);
    });
}

#[test]
fn prop_hnsw_recall_vs_bruteforce() {
    forall(8, |rng| {
        let dim = rng.range_usize(4, 24);
        let n = rng.range_usize(50, 400);
        let mut hnsw = Hnsw::new(dim, HnswParams {
            seed: rng.next_u64(),
            ..HnswParams::default()
        });
        let mut bf = BruteForceIndex::new(dim);
        for _ in 0..n {
            let v: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
            hnsw.add(&v);
            bf.add(&v);
        }
        let mut found = 0;
        let mut total = 0;
        for _ in 0..20 {
            let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
            let k = rng.range_usize(1, 10);
            let exact: Vec<u32> =
                bf.search(&q, k).into_iter().map(|h| h.id).collect();
            let approx: Vec<u32> =
                hnsw.search_ef(&q, k, 64).into_iter().map(|h| h.id).collect();
            assert!(approx.len() <= k);
            total += exact.len();
            found += exact.iter().filter(|e| approx.contains(e)).count();
        }
        let recall = found as f64 / total as f64;
        assert!(recall > 0.85, "recall {recall} (n={n}, dim={dim})");
    });
}

/// Oracle agreement for one generational-HNSW state: top-k search vs
/// exact k-NN over the live set, plus hit integrity — only live ids,
/// only ids the state has issued, and stored vectors that still match
/// the caller's ground truth exactly (a frozen generation whose chunks
/// were since mutated through a newer clone must serve its own bytes).
fn check_hnsw_vs_oracle(idx: &Hnsw, vecs: &[Vec<f32>], alive: &[bool],
                        rng: &mut Pcg32, dim: usize) {
    assert_eq!(idx.len(), alive.len());
    let live: Vec<u32> = (0..alive.len())
        .filter(|&i| alive[i])
        .map(|i| i as u32)
        .collect();
    assert_eq!(idx.live_len(), live.len());
    let mut found = 0usize;
    let mut total = 0usize;
    for _ in 0..8 {
        let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
        let hits = idx.search_ef(&q, 5, 96);
        assert!(hits.len() <= 5);
        assert!(hits.len() <= live.len());
        for h in &hits {
            assert!((h.id as usize) < alive.len(),
                    "hit id {} outside this generation", h.id);
            assert!(alive[h.id as usize], "tombstoned id {} returned", h.id);
            let d = ops::l2_sq(&q, &vecs[h.id as usize]);
            assert!((h.dist_sq - d).abs() <= 1e-4 * d.max(1.0),
                    "stored vector for id {} drifted", h.id);
        }
        let mut exact: Vec<(f32, u32)> = live
            .iter()
            .map(|&i| (ops::l2_sq(&q, &vecs[i as usize]), i))
            .collect();
        exact.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let k = 5.min(exact.len());
        total += k;
        found += exact[..k]
            .iter()
            .filter(|(_, i)| hits.iter().any(|h| h.id == *i))
            .count();
    }
    if total > 0 {
        let recall = found as f64 / total as f64;
        assert!(recall > 0.75,
                "recall {recall} vs oracle ({} live)", live.len());
    }
}

/// PR 9 tentpole differential: the generational HNSW against the exact
/// oracle under random insert/tombstone/search interleavings, with
/// clone generations frozen mid-history. The writer keeps mutating the
/// shared chunks after each clone; every frozen generation must keep
/// answering from its own state — no post-clone inserts, no post-clone
/// tombstones, byte-identical vectors.
#[test]
fn prop_generational_hnsw_matches_oracle_across_generations() {
    forall(6, |rng| {
        let dim = rng.range_usize(4, 12);
        let mut idx = Hnsw::new(dim, HnswParams {
            seed: rng.next_u64(),
            ..HnswParams::default()
        });
        let mut vecs: Vec<Vec<f32>> = Vec::new();
        let mut alive: Vec<bool> = Vec::new();
        let mut gens: Vec<(Hnsw, Vec<bool>)> = Vec::new();

        for op in 0..140 {
            let r = rng.next_f32();
            if r < 0.55 || vecs.is_empty() {
                let v: Vec<f32> =
                    (0..dim).map(|_| rng.next_gaussian()).collect();
                let id = idx.add(&v);
                assert_eq!(id as usize, vecs.len(), "ids must stay dense");
                vecs.push(v);
                alive.push(true);
            } else if r < 0.8 {
                let i = rng.range_usize(0, vecs.len());
                assert_eq!(idx.remove(i as u32), alive[i],
                           "remove must report prior liveness");
                alive[i] = false;
            } else {
                check_hnsw_vs_oracle(&idx, &vecs, &alive, rng, dim);
            }
            // Freeze a generation a few times mid-history (as the
            // tier's cow_clone + publish does once per admitted batch).
            if op % 45 == 30 {
                gens.push((idx.clone(), alive.clone()));
            }
        }
        check_hnsw_vs_oracle(&idx, &vecs, &alive, rng, dim);
        for (snap, snap_alive) in &gens {
            check_hnsw_vs_oracle(snap, &vecs[..snap_alive.len()],
                                 snap_alive, rng, dim);
        }
    });
}

#[test]
fn prop_arena_roundtrips_random_batches() {
    forall(12, |rng| {
        let elems = rng.range_usize(1, 4) * page_size() / 4;
        let mut arena = ApmArena::new(elems).unwrap();
        let n = rng.range_usize(1, 40);
        let mut expected = Vec::new();
        for i in 0..n {
            let v: Vec<f32> =
                (0..elems).map(|j| (i * 31 + j) as f32).collect();
            arena.push(&v).unwrap();
            expected.push(v);
        }
        // Random probes.
        for _ in 0..10 {
            let i = rng.range_usize(0, n);
            assert_eq!(arena.get(ApmId(i as u32)).unwrap(), &expected[i][..]);
        }
        // Mapped gather == copy gather for random subsets.
        let k = rng.range_usize(1, n + 1);
        let picks: Vec<ApmId> = (0..k)
            .map(|_| ApmId(rng.range_usize(0, n) as u32))
            .collect();
        let copied = copy_gather(&arena, &picks).unwrap();
        let mut win = GatherWindow::new(elems, k).unwrap();
        let mapped = win.map_batch(&arena, &picks).unwrap();
        assert_eq!(mapped, &copied[..]);
    });
}

#[test]
fn prop_json_roundtrip() {
    fn gen_value(rng: &mut Pcg32, depth: usize) -> Json {
        match if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f32() < 0.5),
            2 => Json::Num((rng.next_f64() * 2e6).round() / 1e3 - 1e3),
            3 => {
                let n = rng.range_usize(0, 12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            let c = rng.gen_range(96) + 32;
                            if c == b'"' as u32 || c == b'\\' as u32 {
                                'x'
                            } else {
                                c as u8 as char
                            }
                        })
                        .collect(),
                )
            }
            4 => Json::Arr(
                (0..rng.range_usize(0, 5))
                    .map(|_| gen_value(rng, depth - 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..rng.range_usize(0, 5))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(60, |rng| {
        let v = gen_value(rng, 3);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap_or_else(|e| {
            panic!("reparse failed: {e}\n{s}");
        });
        assert_eq!(v, back, "{s}");
    });
}

#[test]
fn prop_threshold_monotonicity() {
    forall(40, |rng| {
        let n = rng.range_usize(1, 200);
        let sims: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        // Lower threshold ⇒ hit rate (alpha) never decreases.
        let t1 = rng.next_f32();
        let t2 = rng.next_f32();
        let (lo, hi) = if t1 < t2 { (t1, t2) } else { (t2, t1) };
        assert!(alpha_at(&sims, lo) >= alpha_at(&sims, hi));
        // Calibrated levels are ordered and within the sample range.
        let t = Thresholds::calibrate(sims.clone());
        assert!(t.conservative >= t.moderate && t.moderate >= t.aggressive);
        let min = sims.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = sims.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!(t.aggressive >= min - 1e-6 && t.conservative <= max + 1e-6);
    });
}

#[test]
fn prop_queue_preserves_order_and_items() {
    use attmemo::serving::queue::BoundedQueue;
    forall(20, |rng| {
        let depth = rng.range_usize(1, 16);
        let q = BoundedQueue::new(depth);
        let mut sent = Vec::new();
        let mut got = Vec::new();
        let mut next = 0u32;
        for _ in 0..200 {
            if rng.next_f32() < 0.6 {
                if q.try_push(next).is_ok() {
                    sent.push(next);
                    next += 1;
                }
            } else {
                got.extend(q.drain_up_to(rng.range_usize(1, 5)));
            }
            assert!(q.len() <= depth);
        }
        got.extend(q.drain_up_to(usize::MAX));
        assert_eq!(got, sent, "FIFO violated");
    });
}

// ---- Two-tier memo database (hot seqlock shards + cold spill) -----------
//
// The ops below cover the whole residency protocol: `admit` carries
// evict + demote (clock victims spill to the cold tier under the writer
// mutex) and, with enough churn, the cold index-log compaction; a hot-miss
// `lookup_fetch` carries the cold probe + promotion (which itself demotes
// a fresh victim). Features are ±eᵢ basis vectors, so distinct entries
// have similarity ≤ 0 under both the hot cosine and the cold
// 1−distance metric — only an exact match clears a 0.95 floor — and the
// payload's first element doubles as the entry's identity tag.

mod two_tier {
    use attmemo::config::{MemoConfig, ModelConfig};
    use attmemo::memo::index::HnswParams;
    use attmemo::memo::MemoTier;

    pub const DIM: usize = 8;
    /// ±eᵢ: 16 mutually non-confusable features.
    pub const FEATS: usize = 2 * DIM;

    pub fn feat(k: usize) -> [f32; DIM] {
        let mut f = [0.0f32; DIM];
        f[k % DIM] = if k < DIM { 1.0 } else { -1.0 };
        f
    }

    fn cfg() -> ModelConfig {
        ModelConfig {
            family: "bert".into(),
            vocab_size: 256,
            hidden: 32,
            layers: 1,
            heads: 2,
            ffn: 64,
            max_len: 16,
            num_classes: 2,
            rel_pos_buckets: 8,
            embed_dim: DIM,
            embed_hidden: 16,
            embed_segments: 4,
            causal: false,
        }
    }

    /// Fresh two-tier MemoTier over a wiped cold directory.
    pub fn tier(name: &str, hot_cap: usize,
                cold_cap: usize) -> (MemoTier, usize) {
        let dir = std::env::temp_dir().join(name);
        let _ = std::fs::remove_dir_all(&dir);
        let c = cfg();
        let elems = c.apm_elems(8);
        let memo = MemoConfig {
            online_admission: true,
            max_db_entries: hot_cap,
            admission_min_attempts: 0,
            cold_tier_dir: Some(dir),
            cold_capacity: cold_cap,
            ..MemoConfig::default()
        };
        let t = MemoTier::with_cold_tier(&c, 8, HnswParams::default(), &memo)
            .expect("cold tier open");
        (t, elems)
    }

    /// Exact-match hot residency, observed without mutating either tier.
    pub fn hot_has(t: &MemoTier, k: usize) -> bool {
        t.lookup(0, &feat(k), 32)
            .map_or(false, |h| h.similarity > 0.999)
    }

    /// Cold residency by payload tag; asserts each cold payload still
    /// matches the feature it was stored with.
    pub fn cold_tags(t: &MemoTier) -> Vec<usize> {
        t.cold()
            .expect("tier has a cold spill")
            .entries(0)
            .iter()
            .map(|(_, f, payload)| {
                let k = payload[0] as usize - 10;
                assert!(k < FEATS, "cold payload tag {} is foreign",
                        payload[0]);
                assert_eq!(f.as_slice(), &feat(k)[..],
                           "cold entry {k}'s feature was corrupted");
                k
            })
            .collect()
    }

    pub fn admit(t: &MemoTier, k: usize, elems: usize) {
        let apm = vec![(10 + k) as f32; elems];
        t.admit_batch(0, &[(&feat(k)[..], apm.as_slice())], 0.9, 32)
            .expect("admit");
    }
}

#[test]
fn prop_two_tier_budgets_and_disjointness() {
    use two_tier::*;
    // Tight budgets: constant demotion churn plus cold FIFO drops.
    let (hot_cap, cold_cap) = (3usize, 6usize);
    forall(12, |rng| {
        let (tier, elems) =
            tier("attmemo_prop_two_tier_tight", hot_cap, cold_cap);
        let mut dst = vec![0.0f32; elems];
        for _ in 0..40 {
            let k = rng.range_usize(0, FEATS);
            if rng.next_f32() < 0.5 {
                // Admit only what is not already resident somewhere, so
                // a tag can never legitimately exist in both tiers.
                if !hot_has(&tier, k) && !cold_tags(&tier).contains(&k) {
                    admit(&tier, k, elems);
                }
            } else if let Some(h) =
                tier.lookup_fetch(0, &feat(k), 32, 0.95, &mut dst)
            {
                assert!(h.similarity > 0.999,
                        "0.95 floor admits only exact matches");
                assert_eq!(dst[0], (10 + k) as f32,
                           "fetch served entry {k} a foreign payload");
            }
            // Budgets hold after every op...
            assert!(tier.layer_len(0) <= hot_cap);
            let cold = tier.cold().unwrap();
            assert!(cold.layer_len(0) <= cold_cap,
                    "cold occupancy {} over budget {}",
                    cold.layer_len(0), cold_cap);
            // ...and no tag is resident in both tiers at once.
            let ctags = cold_tags(&tier);
            for k in 0..FEATS {
                assert!(
                    !(hot_has(&tier, k) && ctags.contains(&k)),
                    "entry {k} resident in both tiers"
                );
            }
        }
    });
}

#[test]
fn prop_two_tier_conservation_under_ample_cold_budget() {
    use std::collections::BTreeSet;
    use two_tier::*;
    // A cold budget that can hold the whole feature universe: nothing is
    // ever FIFO-dropped, so every admitted entry must stay fetchable
    // with its original payload through any demote/promote history.
    let (hot_cap, cold_cap) = (3usize, FEATS);
    forall(8, |rng| {
        let (tier, elems) =
            tier("attmemo_prop_two_tier_ample", hot_cap, cold_cap);
        let mut admitted: BTreeSet<usize> = BTreeSet::new();
        let mut dst = vec![0.0f32; elems];
        for _ in 0..30 {
            let k = rng.range_usize(0, FEATS);
            if rng.next_f32() < 0.6 {
                if !hot_has(&tier, k) && !cold_tags(&tier).contains(&k) {
                    admit(&tier, k, elems);
                    admitted.insert(k);
                }
            } else {
                // Random promotions reshuffle residency mid-run.
                let _ = tier.lookup_fetch(0, &feat(k), 32, 0.95, &mut dst);
            }
        }
        for &k in &admitted {
            let h = tier
                .lookup_fetch(0, &feat(k), 32, 0.95, &mut dst)
                .unwrap_or_else(|| {
                    panic!("entry {k} was lost (admitted, never dropped)")
                });
            assert!(h.similarity > 0.999);
            assert_eq!(dst[0], (10 + k) as f32,
                       "entry {k} came back with a foreign payload");
        }
    });
}

#[test]
fn prop_summary_percentiles_are_order_statistics() {
    use attmemo::util::stats::Summary;
    forall(30, |rng| {
        let n = rng.range_usize(1, 500);
        let mut s = Summary::new();
        let mut xs: Vec<f64> = (0..n).map(|_| rng.next_f64() * 100.0).collect();
        for &x in &xs {
            s.record(x);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(s.percentile(0.0), xs[0]);
        assert_eq!(s.percentile(100.0), xs[n - 1]);
        let p50 = s.percentile(50.0);
        assert!(xs.contains(&p50));
        assert!(s.min() <= s.mean() && s.mean() <= s.max());
    });
}

// ------------------------------------------------- kernel layer pins --

/// Relative-tolerance check against an f64 reference: SIMD lanes and
/// the 4-way unrolled scalar paths reassociate the reduction, so the
/// comparison must absorb O(n·eps) drift without hiding real bugs.
fn close_to(got: f32, want: f64, n: usize) -> bool {
    let tol = 1e-4 * (1.0 + want.abs()) + 1e-6 * n as f64;
    (got as f64 - want).abs() <= tol
}

fn naive_dot(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

fn naive_l2_sq(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = *x as f64 - *y as f64;
            d * d
        })
        .sum()
}

fn naive_l1(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (*x as f64 - *y as f64).abs()).sum()
}

/// Every `kernels::simd` path — the dispatched front door, the scalar
/// fallback, and (where the host supports it) the explicit AVX2 probe —
/// agrees with an f64 naive reference across random lengths, including
/// the remainder lanes past the 16- and 8-wide main loops.
#[test]
fn prop_simd_primitives_match_f64_reference() {
    forall(60, |rng| {
        // Bias towards lengths straddling the vector widths so the
        // 16-wide, 8-wide, and scalar tail loops all get remainders.
        let n = rng.range_usize(0, 4) * 16 + rng.range_usize(0, 18);
        let a: Vec<f32> = (0..n).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.next_gaussian()).collect();

        let refs = [
            (simd::dot(&a, &b), simd::dot_scalar(&a, &b), naive_dot(&a, &b)),
            (
                simd::l2_sq(&a, &b),
                simd::l2_sq_scalar(&a, &b),
                naive_l2_sq(&a, &b),
            ),
            (
                simd::l1_distance(&a, &b),
                simd::l1_distance_scalar(&a, &b),
                naive_l1(&a, &b),
            ),
        ];
        for (dispatched, scalar, want) in refs {
            assert!(close_to(dispatched, want, n), "{dispatched} vs {want}");
            assert!(close_to(scalar, want, n), "{scalar} vs {want}");
        }

        // Reductions.
        let want_sum: f64 = a.iter().map(|x| *x as f64).sum();
        assert!(close_to(simd::sum_reduce(&a), want_sum, n));
        assert!(close_to(simd::sum_reduce_scalar(&a), want_sum, n));
        let want_max =
            a.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        assert_eq!(simd::max_reduce(&a), want_max);
        assert_eq!(simd::max_reduce_scalar(&a), want_max);

        // axpy: y += alpha * x, elementwise (no reduction drift).
        let alpha = rng.next_gaussian();
        let y0: Vec<f32> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mut y_fast = y0.clone();
        simd::axpy(alpha, &a, &mut y_fast);
        for i in 0..n {
            let want = y0[i] as f64 + alpha as f64 * a[i] as f64;
            assert!(close_to(y_fast[i], want, 1));
        }

        // Explicit AVX2 probes (None on non-AVX2 hosts is a pass: the
        // scalar leg of the CI matrix still pins the fallback).
        #[cfg(target_arch = "x86_64")]
        {
            if let Some(v) = simd::dot_avx2(&a, &b) {
                assert!(close_to(v, naive_dot(&a, &b), n));
            }
            if let Some(v) = simd::l2_sq_avx2(&a, &b) {
                assert!(close_to(v, naive_l2_sq(&a, &b), n));
            }
            if let Some(v) = simd::l1_distance_avx2(&a, &b) {
                assert!(close_to(v, naive_l1(&a, &b), n));
            }
            if let Some(v) = simd::sum_reduce_avx2(&a) {
                assert!(close_to(v, want_sum, n));
            }
            if let Some(v) = simd::max_reduce_avx2(&a) {
                assert_eq!(v, want_max);
            }
            let mut y_avx = y0.clone();
            if simd::axpy_avx2(alpha, &a, &mut y_avx) {
                for i in 0..n {
                    let want = y0[i] as f64 + alpha as f64 * a[i] as f64;
                    assert!(close_to(y_avx[i], want, 1));
                }
            }
        }

        // Mismatched lengths operate over the common prefix.
        if n >= 2 {
            let cut = rng.range_usize(1, n);
            let want = naive_dot(&a[..cut], &b);
            assert!(close_to(simd::dot(&a[..cut], &b), want, cut));
            assert!(close_to(simd::dot(&a, &b[..cut]), want, cut));
        }
    });
}

/// The blocked online-softmax attention kernels (APM and fused, packed
/// and strided) agree with the naive three-pass scalar reference across
/// random shapes, pitches, and scales.
#[test]
fn prop_blocked_attention_matches_reference() {
    forall(16, |rng| {
        let l = rng.range_usize(1, 150);
        let d = rng.range_usize(1, 33);
        let scale = 0.125 + rng.next_f32();
        let gauss = |rng: &mut Pcg32, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.next_gaussian()).collect()
        };
        let q = gauss(rng, l * d);
        let k = gauss(rng, l * d);
        let v = gauss(rng, l * d);

        let mut apm = vec![0.0f32; l * l];
        let mut apm_ref = vec![0.0f32; l * l];
        attention::apm_blocked(&q, &k, l, d, scale, &mut apm);
        attention::apm_reference(&q, &k, l, d, scale, &mut apm_ref);
        for i in 0..l * l {
            assert!(
                close_to(apm[i], apm_ref[i] as f64, d),
                "apm[{i}] {} vs {} (l={l}, d={d})",
                apm[i],
                apm_ref[i]
            );
        }
        for i in 0..l {
            let row_sum: f32 = apm[i * l..(i + 1) * l].iter().sum();
            assert!((row_sum - 1.0).abs() < 1e-3, "row {i} sums to {row_sum}");
        }

        let mut out = vec![0.0f32; l * d];
        let mut out_ref = vec![0.0f32; l * d];
        attention::attention_blocked(&q, &k, &v, l, d, scale, &mut out);
        attention::attention_reference(&q, &k, &v, l, d, scale, &mut out_ref);
        for i in 0..l * d {
            assert!(
                close_to(out[i], out_ref[i] as f64, l),
                "out[{i}] {} vs {} (l={l}, d={d})",
                out[i],
                out_ref[i]
            );
        }

        // Strided operands: embed each row at a random pitch > d with
        // garbage in the pad lanes; results must match the packed run.
        let pitch = d + rng.range_usize(1, 9);
        let embed = |rng: &mut Pcg32, m: &[f32]| -> Vec<f32> {
            let mut out = vec![0.0f32; l * pitch];
            for (i, chunk) in out.chunks_mut(pitch).enumerate() {
                chunk[..d].copy_from_slice(&m[i * d..(i + 1) * d]);
                for pad in &mut chunk[d..] {
                    *pad = 1e6 * rng.next_gaussian(); // poison
                }
            }
            out
        };
        let qs = embed(rng, &q);
        let ks = embed(rng, &k);
        let vs = embed(rng, &v);
        let mut apm_strided = vec![0.0f32; l * l];
        attention::apm_blocked_strided(
            &qs, pitch, &ks, pitch, l, d, scale, &mut apm_strided,
        );
        assert_eq!(apm, apm_strided, "strided APM diverged (pitch {pitch})");
        let mut out_strided = vec![0.0f32; l * d];
        attention::attention_blocked_strided(
            &qs, pitch, &ks, pitch, &vs, pitch, l, d, scale, &mut out_strided,
        );
        assert_eq!(out, out_strided, "strided fused diverged (pitch {pitch})");
    });
}
