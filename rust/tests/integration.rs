//! Cross-language integration tests over the real artifacts.
//!
//! These need `make artifacts` (or `ATTMEMO_ARTIFACTS`) — they verify the
//! full python→HLO→rust chain: manifest parsing, weight loading, PJRT
//! execution, numeric agreement with python-computed fixtures, and the
//! end-to-end memoizing engine. Without artifacts they skip (exit early)
//! so `cargo test` stays green on a fresh checkout.

use std::sync::Arc;

use attmemo::bench_support::workload;
use attmemo::config::{MemoConfig, MemoLevel};
use attmemo::memo::builder::DbBuilder;
use attmemo::model::ModelRunner;
use attmemo::runtime::Runtime;
use attmemo::serving::engine::{Engine, EngineOptions};
use attmemo::tensor::tensor::IdTensor;
use attmemo::tensor::{ops, Tensor};

fn runtime() -> Option<Arc<Runtime>> {
    match workload::open_runtime() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

/// Load a fixture tensor by name.
fn fixture(rt: &Runtime, family: &str, name: &str) -> (Vec<usize>, Vec<f32>) {
    let info = rt.artifacts().family(family).unwrap();
    let fx = info.fixtures.as_ref().expect("fixtures in manifest");
    let bytes = std::fs::read(rt.artifacts().root().join(&fx.path)).unwrap();
    let e = fx.tensors.iter().find(|t| t.name == name).unwrap();
    let mut data = Vec::with_capacity(e.len);
    for i in 0..e.len {
        let o = (e.offset + i) * 4;
        let raw: [u8; 4] = bytes[o..o + 4].try_into().unwrap();
        data.push(match e.dtype.as_str() {
            "i32" => i32::from_le_bytes(raw) as f32,
            _ => f32::from_le_bytes(raw),
        });
    }
    (e.shape.clone(), data)
}

fn fixture_ids(rt: &Runtime, family: &str) -> IdTensor {
    let (shape, data) = fixture(rt, family, "ids");
    IdTensor::new(shape, data.into_iter().map(|x| x as i32).collect()).unwrap()
}

fn max_diff(a: &Tensor, want_shape: &[usize], want: &[f32]) -> f32 {
    assert_eq!(a.shape(), want_shape, "shape mismatch");
    a.data()
        .iter()
        .zip(want)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[test]
fn fixtures_match_python_numerics() {
    let Some(rt) = runtime() else { return };
    for family in rt.artifacts().family_names() {
        let runner = ModelRunner::load(rt.clone(), family).unwrap();
        let ids = fixture_ids(&rt, family);

        // embed
        let hidden = runner.embed(&ids).unwrap();
        let (hs, hd) = fixture(&rt, family, "hidden0");
        let d = max_diff(&hidden, &hs, &hd);
        assert!(d < 1e-3, "{family} embed diff {d}");

        // layer-0 attention scores (the memoization subject)
        let apm = runner.attn_scores(&hidden, 0).unwrap();
        let (as_, ad) = fixture(&rt, family, "apm0");
        let d = max_diff(&apm, &as_, &ad);
        assert!(d < 1e-3, "{family} apm diff {d}");

        // embedding network
        let feat = runner.mlp_embed(&hidden).unwrap();
        let (fs, fd) = fixture(&rt, family, "feature0");
        let d = max_diff(&feat, &fs, &fd);
        assert!(d < 1e-3, "{family} feature diff {d}");

        // full forward logits
        let (ls, ld) = fixture(&rt, family, "logits");
        let logits = runner.forward_baseline(&ids).unwrap();
        let d = max_diff(&logits, &ls, &ld);
        assert!(d < 5e-3, "{family} logits diff {d}");
        eprintln!("{family}: fixtures OK");
    }
}

#[test]
fn split_and_fused_paths_agree() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::load(rt.clone(), "bert").unwrap();
    let ids = fixture_ids(&rt, "bert");
    let h = runner.embed(&ids).unwrap();
    let apm = runner.attn_scores(&h, 0).unwrap();
    let split = runner.attn_apply(&h, &apm, 0).unwrap();
    let fused = runner.layer_full(&h, 0).unwrap();
    let d = split.max_abs_diff(&fused).unwrap();
    assert!(d < 1e-3, "split vs fused diff {d}");
}

#[test]
fn apms_are_row_stochastic() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::load(rt.clone(), "bert").unwrap();
    let ids = fixture_ids(&rt, "bert");
    let h = runner.embed(&ids).unwrap();
    let apm = runner.attn_scores(&h, 1).unwrap();
    let l = *apm.shape().last().unwrap();
    let rows = apm.len() / l;
    assert!(ops::rows_stochastic(apm.data(), rows, l, 1e-3));
}

#[test]
fn engine_memoized_matches_baseline_labels_mostly() {
    let Some(rt) = runtime() else { return };
    let seq_len = rt.artifacts().serving_seq_len;
    let (ids, labels) = workload::test_workload(&rt, "bert", seq_len, 16)
        .unwrap();

    let mut base = workload::engine_with_db(
        &rt, "bert", seq_len, MemoLevel::Off, 0, false).unwrap();
    let b = attmemo::eval::evaluate(&mut base, &ids, &labels, 8, true)
        .unwrap();

    let mut memo = workload::engine_with_db(
        &rt, "bert", seq_len, MemoLevel::Conservative, 64, false).unwrap();
    let m = attmemo::eval::evaluate(&mut memo, &ids, &labels, 8, false)
        .unwrap();

    // Conservative memoization must not collapse accuracy (paper Table 5).
    assert!(m.accuracy() + 0.15 >= b.accuracy(),
            "baseline {} memo {}", b.accuracy(), m.accuracy());
    eprintln!("baseline acc {:.3} memo acc {:.3} rate {:.3}",
              b.accuracy(), m.accuracy(), m.memo_rate);
}

#[test]
fn db_builder_produces_consistent_state() {
    let Some(rt) = runtime() else { return };
    let runner = ModelRunner::load(rt.clone(), "bert").unwrap();
    let seq_len = rt.artifacts().serving_seq_len;
    let (ids, _) = workload::test_workload(&rt, "bert", seq_len, 24).unwrap();
    let built = DbBuilder::new(&runner).build(&ids).unwrap();
    let cfg = runner.config();
    assert_eq!(built.db.num_layers(), cfg.layers);
    for li in 0..cfg.layers {
        assert_eq!(built.db.layer(li).len(), 24);
    }
    assert!(built.thresholds.conservative >= built.thresholds.aggressive);
    assert_eq!(built.profiles.len(), cfg.layers);
    for p in &built.profiles {
        assert!(p.t_attn > 0.0 && p.t_overhead > 0.0);
        assert!((0.0..=1.0).contains(&p.alpha));
    }
    // Self-lookup: a feature just inserted must be found with sim ≈ 1.
    let h = runner.embed(&ids.slice0(0, 1).unwrap()).unwrap();
    let f = runner.mlp_embed(&h).unwrap();
    let hit = built.db.layer(0).lookup(f.row(0), 48).unwrap();
    assert!(hit.similarity > 0.99, "{}", hit.similarity);
}

#[test]
fn memo_engine_zero_db_falls_back() {
    let Some(rt) = runtime() else { return };
    let seq_len = rt.artifacts().serving_seq_len;
    let runner = ModelRunner::load(rt.clone(), "bert").unwrap();
    // Memoization on, but DB never populated → every layer takes the
    // fused path; inference must still work.
    let memo = MemoConfig { level: MemoLevel::Aggressive,
                            ..MemoConfig::default() };
    let mut engine = Engine::new(runner, None,
                                 EngineOptions { memo, seq_len }).unwrap();
    let (ids, _) = workload::test_workload(&rt, "bert", seq_len, 4).unwrap();
    let out = engine.infer(&ids).unwrap();
    assert_eq!(out.labels.len(), 4);
    assert!(out.memo_hits.iter().all(|&h| h == 0));
}

#[test]
fn sparse_variants_load_and_run() {
    let Some(rt) = runtime() else { return };
    let info = rt.artifacts().family("bert").unwrap();
    if info.sparse_variants.is_empty() {
        eprintln!("SKIP: no sparse variants");
        return;
    }
    let tag = info.sparse_variants[0].tag.clone();
    let runner = ModelRunner::load_sparse(rt.clone(), "bert", &tag).unwrap();
    let ids = fixture_ids(&rt, "bert");
    let logits = runner.forward_baseline(&ids).unwrap();
    assert_eq!(logits.shape()[0], ids.shape[0]);
    assert!(logits.data().iter().all(|x| x.is_finite()));
}
