//! Online-memoization tests: a cold database warms from live traffic to a
//! steady-state hit rate while occupancy respects the capacity budget.
//!
//! The serve loop is simulated at the memoization layer (embedding
//! vectors drawn from a clustered workload — repeated-similarity traffic,
//! exactly what AttMEMO exploits), so these tests are hermetic: no
//! artifacts, no PJRT. The final test drives the real engine end-to-end
//! and is skipped without artifacts, like every runtime-gated test.

use attmemo::config::{MemoLevel, ModelConfig};
use attmemo::memo::index::HnswParams;
use attmemo::memo::policy::AdmissionPolicy;
use attmemo::memo::AttentionDb;
use attmemo::util::Pcg32;

fn cfg() -> ModelConfig {
    ModelConfig {
        family: "bert".into(),
        vocab_size: 256,
        hidden: 32,
        layers: 1,
        heads: 2,
        ffn: 64,
        max_len: 16,
        num_classes: 2,
        rel_pos_buckets: 8,
        embed_dim: 16,
        embed_hidden: 32,
        embed_segments: 4,
        causal: false,
    }
}

fn normalize(v: &mut [f32]) {
    let n = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
    v.iter_mut().for_each(|x| *x /= n);
}

/// `k` unit-vector cluster centres.
fn centres(rng: &mut Pcg32, k: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..k)
        .map(|_| {
            let mut v: Vec<f32> =
                (0..dim).map(|_| rng.next_gaussian()).collect();
            normalize(&mut v);
            v
        })
        .collect()
}

/// A query near one centre (repeated-similarity traffic).
fn query_near(rng: &mut Pcg32, centre: &[f32], noise: f32) -> Vec<f32> {
    let mut v: Vec<f32> = centre
        .iter()
        .map(|&c| c + noise * rng.next_gaussian())
        .collect();
    normalize(&mut v);
    v
}

/// Run `epochs × queries_per_epoch` lookups against one layer with
/// admission on; returns (per-epoch hit rates, total evictions, max
/// occupancy seen).
fn simulate(db: &mut AttentionDb, capacity: usize, epochs: usize,
            queries_per_epoch: usize, threshold: f32)
    -> (Vec<f64>, u64, usize) {
    let c = cfg();
    let mut rng = Pcg32::seeded(42);
    let cents = centres(&mut rng, 8, c.embed_dim);
    let gate = AdmissionPolicy::new(true, 0);
    let elems = c.apm_elems(16);
    let mut rates = Vec::new();
    let mut evictions = 0u64;
    let mut max_occupancy = 0usize;
    let mut attempts = 0u64;
    for _ in 0..epochs {
        let mut hits = 0usize;
        for q in 0..queries_per_epoch {
            let centre = &cents[q % cents.len()];
            let query = query_near(&mut rng, centre, 0.02);
            attempts += 1;
            let hit = db
                .layer(0)
                .lookup(&query, 48)
                .filter(|h| h.similarity >= threshold);
            match hit {
                Some(h) => {
                    hits += 1;
                    db.layer(0).mark_reused(h.id);
                }
                None => {
                    if gate.should_admit(None, attempts, 128) {
                        // The miss path computed this APM anyway; admit it.
                        let apm = vec![q as f32; elems];
                        let out = db
                            .layer_mut(0)
                            .admit(&query, &apm, capacity)
                            .unwrap();
                        evictions += out.evicted.len() as u64;
                    }
                }
            }
            max_occupancy = max_occupancy.max(db.layer(0).len());
        }
        rates.push(hits as f64 / queries_per_epoch as f64);
    }
    (rates, evictions, max_occupancy)
}

#[test]
fn cold_db_warms_to_steady_state_within_capacity() {
    let c = cfg();
    let mut db = AttentionDb::new(&c, 16, HnswParams::default());
    assert_eq!(db.total_entries(), 0, "starts cold");
    let capacity = 32;
    let (rates, _evictions, max_occupancy) =
        simulate(&mut db, capacity, 5, 64, 0.8);

    // Cold start: the very first epoch cannot beat the warmed ones...
    assert!(rates[0] < *rates.last().unwrap(),
            "no warm-up visible: {rates:?}");
    // ...and after warm-up the repeated-similarity workload mostly hits.
    assert!(rates.last().unwrap() > &0.8, "steady state too low: {rates:?}");
    let warm_hits: f64 = rates[1..].iter().sum();
    assert!(warm_hits > 0.0, "hits after warm-up");
    // The capacity budget holds at every step.
    assert!(max_occupancy <= capacity,
            "occupancy {max_occupancy} > capacity {capacity}");
    assert!(db.layer(0).len() <= capacity);
    assert!(db.total_entries() > 0, "database actually warmed");
}

#[test]
fn capacity_pressure_evicts_but_never_overflows() {
    let c = cfg();
    let mut db = AttentionDb::new(&c, 16, HnswParams::default());
    // Budget below the working set (8 clusters): constant churn.
    let capacity = 4;
    let (_rates, evictions, max_occupancy) =
        simulate(&mut db, capacity, 4, 64, 0.8);
    assert!(evictions > 0, "under-provisioned cache must evict");
    assert!(max_occupancy <= capacity,
            "occupancy {max_occupancy} > capacity {capacity}");
    assert_eq!(db.layer(0).len(), capacity);
}

#[test]
fn disabled_gate_never_admits() {
    let c = cfg();
    let db = AttentionDb::new(&c, 16, HnswParams::default());
    let gate = AdmissionPolicy::new(false, 0);
    assert!(!gate.should_admit(None, 0, 128));
    assert_eq!(db.total_entries(), 0);
}

/// Real-engine cold start (skips without artifacts): an engine with no
/// built database and admission on must raise its hit rate over repeated
/// traffic, with occupancy within budget.
#[test]
fn engine_cold_start_warms_with_artifacts() {
    use attmemo::bench_support::workload;

    let Ok(rt) = workload::open_runtime() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let seq_len = rt.artifacts().serving_seq_len;
    let capacity = 64;
    let mut engine = workload::cold_engine(
        &rt, "bert", seq_len, MemoLevel::Aggressive, capacity, 0)
        .expect("cold engine");
    let (ids, _) = workload::test_workload(&rt, "bert", seq_len, 8).unwrap();

    // First pass: everything misses (cold), APMs get admitted.
    let first = engine.infer(&ids).unwrap();
    assert!(first.memo_hits.iter().all(|&h| h == 0),
            "cold engine cannot hit");
    assert!(engine.stats.total_admitted() > 0, "misses must be admitted");

    // Replay the same batch: the warmed database must hit now.
    let second = engine.infer(&ids).unwrap();
    let hits: u32 = second.memo_hits.iter().sum();
    assert!(hits > 0, "no hits after warm-up");
    let tier = engine.online().unwrap();
    for li in 0..tier.num_layers() {
        assert!(tier.layer_len(li) <= capacity,
                "layer {li} over capacity");
    }
}
