//! Minimal `log` facade for offline builds.
//!
//! API-compatible subset of the `log` crate (macros, `Level`,
//! `LevelFilter`, `Record`, `Metadata`, the `Log` trait, and the
//! `set_logger`/`set_max_level` pair) so the application code and its
//! backend (`attmemo::util::logger`) compile unchanged without crates.io.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of one log record, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// A verbosity ceiling: `Off` plus every `Level`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Record metadata visible to `Log::enabled`.
#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record.
#[derive(Debug, Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Install the global logger; fails if one is already set.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity ceiling.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

/// Current global verbosity ceiling.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        logger.log(&record);
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    struct Counter(AtomicUsize);

    impl Log for Counter {
        fn enabled(&self, _m: &Metadata<'_>) -> bool {
            true
        }
        fn log(&self, _r: &Record<'_>) {
            self.0.fetch_add(1, Ordering::SeqCst);
        }
        fn flush(&self) {}
    }

    static COUNTER: Counter = Counter(AtomicUsize::new(0));

    #[test]
    fn levels_compare_with_filters() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Trace);
    }

    #[test]
    fn dispatch_respects_max_level() {
        let _ = set_logger(&COUNTER);
        set_max_level(LevelFilter::Info);
        let before = COUNTER.0.load(Ordering::SeqCst);
        info!("hello {}", 1);
        debug!("filtered {}", 2);
        let after = COUNTER.0.load(Ordering::SeqCst);
        assert_eq!(after - before, 1);
    }
}
