//! Minimal libc shim for offline builds.
//!
//! The real `libc` crate is not available in this registry-free
//! environment, so this crate declares exactly the glibc symbols and
//! constants the attmemo arena/gather layer uses (`memfd_create`, `mmap`
//! and friends), for Linux. Types follow the LP64 ABI used by every Linux
//! target this project runs on (x86_64, aarch64).

#![allow(non_camel_case_types)]

pub use core::ffi::c_void;

pub type c_char = i8;
pub type c_int = i32;
pub type c_uint = u32;
pub type c_long = i64;
pub type size_t = usize;
pub type off_t = i64;

// --- mmap protection / flag constants (Linux) ------------------------------
pub const PROT_NONE: c_int = 0x0;
pub const PROT_READ: c_int = 0x1;
pub const PROT_WRITE: c_int = 0x2;

pub const MAP_SHARED: c_int = 0x01;
pub const MAP_PRIVATE: c_int = 0x02;
pub const MAP_FIXED: c_int = 0x10;
pub const MAP_ANONYMOUS: c_int = 0x20;

pub const MAP_FAILED: *mut c_void = !0 as *mut c_void;

/// `sysconf` name for the page size (Linux).
pub const _SC_PAGESIZE: c_int = 30;

extern "C" {
    pub fn memfd_create(name: *const c_char, flags: c_uint) -> c_int;
    pub fn ftruncate(fd: c_int, length: off_t) -> c_int;
    pub fn mmap(addr: *mut c_void, len: size_t, prot: c_int, flags: c_int,
                fd: c_int, offset: off_t) -> *mut c_void;
    pub fn munmap(addr: *mut c_void, len: size_t) -> c_int;
    pub fn close(fd: c_int) -> c_int;
    pub fn sysconf(name: c_int) -> c_long;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_size_queryable() {
        let p = unsafe { sysconf(_SC_PAGESIZE) };
        assert!(p >= 4096, "page size {p}");
    }

    #[test]
    fn memfd_mmap_roundtrip() {
        unsafe {
            let fd = memfd_create(b"libc-shim-test\0".as_ptr().cast(), 0);
            assert!(fd >= 0);
            let len = 4096usize;
            assert_eq!(ftruncate(fd, len as off_t), 0);
            let p = mmap(core::ptr::null_mut(), len, PROT_READ | PROT_WRITE,
                         MAP_SHARED, fd, 0);
            assert_ne!(p, MAP_FAILED);
            let bytes = p.cast::<u8>();
            bytes.write(42);
            assert_eq!(bytes.read(), 42);
            assert_eq!(munmap(p, len), 0);
            assert_eq!(close(fd), 0);
        }
    }
}
