//! Host-side stand-in for the `xla` (PJRT) bindings.
//!
//! The real crate links libxla/PJRT, which cannot be fetched in this
//! offline environment. This stand-in keeps the same API surface so the
//! whole coordinator compiles and its host-side tests run:
//!
//! * [`Literal`] is a **real** host tensor container (f32/i32/tuple) —
//!   weight loading, tensor round-trips and fixtures work unchanged;
//! * [`PjRtClient::cpu`] returns an error, so anything that would need a
//!   real PJRT runtime (graph compilation/execution) reports a clear
//!   "runtime unavailable" instead of wrong numbers. Artifact-gated tests
//!   and benches skip exactly as they do on a checkout without artifacts.

use std::fmt;

/// Stub error type mirroring `xla::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (built with the vendored \
         host-side xla stub; graph execution needs the real xla crate)"
    ))
}

// --- literals --------------------------------------------------------------

/// Host storage for one literal.
#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<&[Self]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<&[Self]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Host tensor literal (f32 / i32 / tuple), shape in i64 dims.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Array shape of a non-tuple literal.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    /// Tuple literal.
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { dims: Vec::new(), data: Data::Tuple(elems) }
    }

    fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(_) => 0,
        }
    }

    /// Reinterpret with new dims of equal element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error("reshape of a tuple literal".into()));
        }
        if want as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Shape of an array (non-tuple) literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.data {
            Data::Tuple(_) => Err(Error("array_shape of a tuple".into())),
            _ => Ok(ArrayShape { dims: self.dims.clone() }),
        }
    }

    /// Copy the elements out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(elems) => Ok(elems),
            _ => Err(Error("to_tuple of a non-tuple literal".into())),
        }
    }
}

// --- HLO / computation handles --------------------------------------------

/// Parsed HLO module handle (the stub only records the source path).
pub struct HloModuleProto {
    #[allow(dead_code)]
    path: String,
}

impl HloModuleProto {
    /// The stub validates that the file exists, but cannot parse HLO.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        if !std::path::Path::new(path).exists() {
            return Err(Error(format!("no HLO file at {path}")));
        }
        Ok(HloModuleProto { path: path.to_string() })
    }
}

/// Computation wrapper.
pub struct XlaComputation {
    #[allow(dead_code)]
    proto: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: () }
    }
}

// --- PJRT client / buffers / executables ----------------------------------

/// One PJRT device (never constructed by the stub).
pub struct PjRtDevice {
    _private: (),
}

/// PJRT client handle. The stub cannot create one: [`PjRtClient::cpu`]
/// fails, so every caller degrades to its documented "no runtime" path.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self, _data: &[T], _dims: &[usize], _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("buffer_from_host_buffer"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

/// Device buffer handle (never constructed by the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Argument types accepted by [`PjRtLoadedExecutable::execute`].
pub trait BufferArg {}

impl BufferArg for Literal {}
impl BufferArg for PjRtBuffer {}

/// Compiled executable handle (never constructed by the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: BufferArg>(&self, _args: &[T])
        -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer])
        -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![Literal::vec1(&[1i32]),
                                    Literal::vec1(&[2.0f32])]);
        assert!(t.array_shape().is_err());
        let elems = t.to_tuple().unwrap();
        assert_eq!(elems.len(), 2);
        assert_eq!(elems[0].to_vec::<i32>().unwrap(), vec![1]);
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("unavailable"), "{e}");
    }
}
