//! Layer-wise transformer execution over the AOT artifacts.

pub mod forward;

pub use forward::ModelRunner;
