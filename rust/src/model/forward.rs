//! `ModelRunner`: executes one model family layer-by-layer through the
//! PJRT executables, which is what lets the memoization engine intercept
//! each layer's APM.
//!
//! Two forward paths exist:
//! * **fused** — `embed → layer_full× → head`, the non-memoized baseline;
//! * **split** — `embed → (attn_scores → attn_apply)× → head`, where the
//!   engine may replace `attn_scores` output with a database APM.
//!
//! Graphs are lowered at fixed batch sizes; the runner pads a smaller batch
//! up to the nearest lowered size and slices the outputs back.
//!
//! §Perf: arguments are passed as *device buffers* (`execute_b`). Weight
//! buffers are uploaded once per (graph, layer) and cached in an `ArgPlan`;
//! a call uploads only its activations. The engine additionally shares one
//! uploaded hidden-state buffer across the three executables a memoized
//! layer touches (`mlp_embed`, `attn_scores`, `attn_apply`).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::config::ModelConfig;
use crate::runtime::{GraphKey, Runtime, WeightSet};
use crate::tensor::tensor::IdTensor;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// One executable argument: a resident weight buffer or the i-th activation
/// supplied at call time.
enum Slot {
    Weight(xla::PjRtBuffer),
    Act(usize),
}

/// Cached argument plan for one (graph, layer) pair.
struct ArgPlan {
    slots: Vec<Slot>,
    /// Activation names in the order the caller must supply them.
    act_names: Vec<String>,
}

/// Executes one family (dense or a sparse variant — same graphs, different
/// `WeightSet`).
pub struct ModelRunner {
    runtime: Arc<Runtime>,
    cfg: ModelConfig,
    weights: Arc<WeightSet>,
    family: String,
    plans: Mutex<HashMap<(GraphKey, Option<usize>), Arc<ArgPlan>>>,
}

impl ModelRunner {
    pub fn new(runtime: Arc<Runtime>, family: &str,
               weights: Arc<WeightSet>) -> Result<Self> {
        let cfg = runtime.artifacts().family(family)?.config.clone();
        Ok(ModelRunner {
            runtime,
            cfg,
            weights,
            family: family.into(),
            plans: Mutex::new(HashMap::new()),
        })
    }

    /// Load a family with its dense weights.
    pub fn load(runtime: Arc<Runtime>, family: &str) -> Result<Self> {
        let info = runtime.artifacts().family(family)?;
        let ws = WeightSet::load(runtime.artifacts().root(), &info.weights,
                                 &info.tensors)?;
        Self::new(runtime, family, Arc::new(ws))
    }

    /// Load a sparse variant (§6.8) by tag, e.g. `sparse85`.
    pub fn load_sparse(runtime: Arc<Runtime>, family: &str,
                       tag: &str) -> Result<Self> {
        let info = runtime.artifacts().family(family)?;
        let sv = info
            .sparse_variants
            .iter()
            .find(|v| v.tag == tag)
            .ok_or_else(|| {
                Error::config(format!("no sparse variant {tag:?} for {family}"))
            })?;
        let ws = WeightSet::load(runtime.artifacts().root(), &sv.weights,
                                 &sv.tensors)?;
        Self::new(runtime, family, Arc::new(ws))
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn family(&self) -> &str {
        &self.family
    }

    pub fn weights(&self) -> &WeightSet {
        &self.weights
    }

    /// The token-embedding table `[vocab, hidden]` — the `embed` graph's
    /// `tok_emb` weight, read host-side. Request-path consumers (the
    /// semantic affinity signature) mean-pool its rows without any graph
    /// execution.
    pub fn embedding_table(&self) -> Result<&Tensor> {
        self.weights.tensor("tok_emb")
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Smallest lowered batch that fits `want` for a graph kind.
    pub fn fit_batch(&self, kind: &str, seq_len: usize,
                     want: usize) -> Result<usize> {
        self.runtime.fit_batch(&self.family, kind, seq_len, want)
    }

    // -- device-buffer plumbing ---------------------------------------------

    /// Upload an f32 tensor to the device.
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        Ok(self
            .runtime
            .client()
            .buffer_from_host_buffer(t.data(), t.shape(), None)?)
    }

    /// Upload an i32 id tensor to the device.
    pub fn upload_ids(&self, t: &IdTensor) -> Result<xla::PjRtBuffer> {
        Ok(self
            .runtime
            .client()
            .buffer_from_host_buffer(&t.data, &t.shape, None)?)
    }

    /// Pad a `[n, …]` hidden tensor to the lowered batch for `kind` and
    /// upload it once; returns (buffer, padded batch).
    pub fn upload_padded(&self, t: &Tensor, kind: &str)
        -> Result<(xla::PjRtBuffer, usize)> {
        let (n, l) = (t.shape()[0], t.shape()[1]);
        let b = self.fit_batch(kind, l, n)?;
        let padded = pad0(t, b)?;
        Ok((self.upload(&padded)?, b))
    }

    /// Fetch (building if absent) the argument plan for a graph/layer.
    fn plan(&self, key: &GraphKey, act_names: &[&str],
            layer: Option<usize>) -> Result<Arc<ArgPlan>> {
        if let Some(p) = self.plans.lock().unwrap().get(&(key.clone(), layer))
        {
            debug_assert_eq!(p.act_names, act_names);
            return Ok(p.clone());
        }
        let info = self.runtime.artifacts().graph(key)?;
        let mut slots = Vec::with_capacity(info.params.len());
        for p in &info.params {
            if let Some(i) = act_names.iter().position(|n| n == p) {
                slots.push(Slot::Act(i));
            } else {
                // Resolve (layer-scoped first) and upload the weight once.
                let name = match layer {
                    Some(li)
                        if self
                            .weights
                            .tensor(&format!("l{li}_{p}"))
                            .is_ok() =>
                    {
                        format!("l{li}_{p}")
                    }
                    _ => p.clone(),
                };
                let t = self.weights.tensor(&name)?;
                slots.push(Slot::Weight(
                    self.runtime
                        .client()
                        .buffer_from_host_buffer(t.data(), t.shape(), None)?,
                ));
            }
        }
        let plan = Arc::new(ArgPlan {
            slots,
            act_names: act_names.iter().map(|s| s.to_string()).collect(),
        });
        self.plans
            .lock()
            .unwrap()
            .insert((key.clone(), layer), plan.clone());
        Ok(plan)
    }

    /// Execute a graph with activation buffers; weights come from the plan.
    fn run_with(&self, kind: &str, seq_len: usize, batch: usize,
                act_names: &[&str], acts: &[&xla::PjRtBuffer],
                layer: Option<usize>) -> Result<Tensor> {
        let key = GraphKey::new(&self.family, kind, batch, seq_len);
        let exe = self.runtime.executable(&key)?;
        let plan = self.plan(&key, act_names, layer)?;
        let args: Vec<&xla::PjRtBuffer> = plan
            .slots
            .iter()
            .map(|s| match s {
                Slot::Weight(b) => b,
                Slot::Act(i) => acts[*i],
            })
            .collect();
        exe.run_buffers(&args)
    }

    // -- graph wrappers (all pad to the lowered batch and slice back) ------

    /// Token ids → hidden states.
    pub fn embed(&self, ids: &IdTensor) -> Result<Tensor> {
        let (n, l) = (ids.shape[0], ids.shape[1]);
        let b = self.fit_batch("embed", l, n)?;
        let padded = pad_ids(ids, b)?;
        let buf = self.upload_ids(&padded)?;
        let out = self.run_with("embed", l, b, &["ids"], &[&buf], None)?;
        slice_batch(out, n)
    }

    /// Hidden → APM `[n, heads, L, L]` for one layer.
    pub fn attn_scores(&self, hidden: &Tensor, layer: usize) -> Result<Tensor> {
        let n = hidden.shape()[0];
        let (buf, b) = self.upload_padded(hidden, "attn_scores")?;
        let out = self.attn_scores_from(&buf, b, hidden.shape()[1], layer)?;
        slice_batch(out, n)
    }

    /// `attn_scores` over an already-uploaded padded hidden buffer.
    pub fn attn_scores_from(&self, hidden: &xla::PjRtBuffer, batch: usize,
                            seq_len: usize, layer: usize) -> Result<Tensor> {
        self.run_with("attn_scores", seq_len, batch, &["hidden"], &[hidden],
                      Some(layer))
    }

    /// (hidden, APM) → next hidden for one layer. `apm` may come from
    /// `attn_scores` or from the attention database.
    pub fn attn_apply(&self, hidden: &Tensor, apm: &Tensor,
                      layer: usize) -> Result<Tensor> {
        let n = hidden.shape()[0];
        let (hbuf, b) = self.upload_padded(hidden, "attn_apply")?;
        let out = self.attn_apply_from(&hbuf, apm, b, hidden.shape()[1],
                                       layer)?;
        slice_batch(out, n)
    }

    /// `attn_apply` with a shared hidden buffer; the APM batch is padded
    /// with uniform rows and uploaded here.
    pub fn attn_apply_from(&self, hidden: &xla::PjRtBuffer, apm: &Tensor,
                           batch: usize, seq_len: usize,
                           layer: usize) -> Result<Tensor> {
        let pa = pad_apm(apm, batch)?;
        let abuf = self.upload(&pa)?;
        self.run_with("attn_apply", seq_len, batch, &["hidden", "apm"],
                      &[hidden, &abuf], Some(layer))
    }

    /// Fused layer (non-memoized fast path).
    pub fn layer_full(&self, hidden: &Tensor, layer: usize) -> Result<Tensor> {
        let n = hidden.shape()[0];
        let (buf, b) = self.upload_padded(hidden, "layer_full")?;
        let out = self.run_with("layer_full", hidden.shape()[1], b,
                                &["hidden"], &[&buf], Some(layer))?;
        slice_batch(out, n)
    }

    /// Final head: classifier logits `[n, C]` or LM logits `[n, L, V]`.
    pub fn head(&self, hidden: &Tensor) -> Result<Tensor> {
        let n = hidden.shape()[0];
        let kind = if self.cfg.causal { "lm_head" } else { "classifier" };
        let (buf, b) = self.upload_padded(hidden, kind)?;
        let out = self.run_with(kind, hidden.shape()[1], b, &["hidden"],
                                &[&buf], None)?;
        slice_batch(out, n)
    }

    /// AttMemo embedding network: hidden → features `[n, embed_dim]`.
    pub fn mlp_embed(&self, hidden: &Tensor) -> Result<Tensor> {
        let n = hidden.shape()[0];
        let (buf, b) = self.upload_padded(hidden, "mlp_embed")?;
        let out = self.mlp_embed_from(&buf, b, hidden.shape()[1])?;
        slice_batch(out, n)
    }

    /// `mlp_embed` over an already-uploaded padded hidden buffer.
    pub fn mlp_embed_from(&self, hidden: &xla::PjRtBuffer, batch: usize,
                          seq_len: usize) -> Result<Tensor> {
        self.run_with("mlp_embed", seq_len, batch, &["hidden"], &[hidden],
                      None)
    }

    /// Baseline end-to-end forward (fused layers, no memoization).
    pub fn forward_baseline(&self, ids: &IdTensor) -> Result<Tensor> {
        let mut h = self.embed(ids)?;
        for li in 0..self.cfg.layers {
            h = self.layer_full(&h, li)?;
        }
        self.head(&h)
    }

    /// Split forward that also returns each layer's (input hidden, APM) —
    /// used by the offline DB builder.
    pub fn forward_collect(&self, ids: &IdTensor)
        -> Result<(Tensor, Vec<(Tensor, Tensor)>)> {
        let mut h = self.embed(ids)?;
        let mut collected = Vec::with_capacity(self.cfg.layers);
        for li in 0..self.cfg.layers {
            let apm = self.attn_scores(&h, li)?;
            let next = self.attn_apply(&h, &apm, li)?;
            collected.push((h, apm));
            h = next;
        }
        let logits = self.head(&h)?;
        Ok((logits, collected))
    }
}

// -- host fallback kernels ----------------------------------------------
//
// The PJRT executables own the accelerated path, but a deployment with
// no device (and every hermetic bench/test in this repo) still needs the
// miss-path attention at host speed. These free functions compute the
// same shapes the split graphs produce — APM `[n, heads, L, L]` and the
// applied attention `[n, L, H]` — through the blocked, online-softmax
// kernel in `crate::kernels::attention`, replacing the naive
// per-element loops this module would otherwise need.

/// Host-side `attn_scores` fallback: hidden `[n, L, H]` → APM
/// `[n, heads, L, L]`.
///
/// Weightless self-attention: each head's query and key matrices are
/// the head's slice of the hidden state itself (contiguous `d = H /
/// heads` columns within a row, row pitch `H`), scaled by `1/√d`. The
/// strided blocked kernel reads the slices in place — no repacking
/// copy.
pub fn host_attn_scores(hidden: &Tensor, heads: usize) -> Result<Tensor> {
    if hidden.shape().len() != 3 {
        return Err(Error::shape(format!(
            "host_attn_scores wants [n, L, H], got {:?}",
            hidden.shape()
        )));
    }
    let (n, l, h) = (hidden.shape()[0], hidden.shape()[1], hidden.shape()[2]);
    if heads == 0 || h % heads != 0 {
        return Err(Error::shape(format!(
            "hidden width {h} not divisible into {heads} heads"
        )));
    }
    let d = h / heads;
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; n * heads * l * l];
    for b in 0..n {
        let hid = &hidden.data()[b * l * h..(b + 1) * l * h];
        for head in 0..heads {
            let slice = &hid[head * d..];
            let o = (b * heads + head) * l * l;
            crate::kernels::attention::apm_blocked_strided(
                slice,
                h,
                slice,
                h,
                l,
                d,
                scale,
                &mut out[o..o + l * l],
            );
        }
    }
    Tensor::new(vec![n, heads, l, l], out)
}

/// Host-side `attn_apply` fallback: `(hidden [n, L, H], apm [n, heads,
/// L, L])` → `[n, L, H]`, where each head's value matrix is its slice
/// of the hidden state. The APM may come from [`host_attn_scores`] or
/// from the attention database; rows are applied with the kernel
/// layer's axpy accumulate.
pub fn host_attn_apply(hidden: &Tensor, apm: &Tensor) -> Result<Tensor> {
    if hidden.shape().len() != 3 || apm.shape().len() != 4 {
        return Err(Error::shape(format!(
            "host_attn_apply wants [n, L, H] + [n, heads, L, L], got {:?} + {:?}",
            hidden.shape(),
            apm.shape()
        )));
    }
    let (n, l, h) = (hidden.shape()[0], hidden.shape()[1], hidden.shape()[2]);
    let heads = apm.shape()[1];
    if apm.shape() != &[n, heads, l, l][..] || heads == 0 || h % heads != 0 {
        return Err(Error::shape(format!(
            "apm {:?} does not match hidden [{n}, {l}, {h}]",
            apm.shape()
        )));
    }
    let d = h / heads;
    let mut out = vec![0.0f32; n * l * h];
    let mut acc = vec![0.0f32; d];
    for b in 0..n {
        let hid = &hidden.data()[b * l * h..(b + 1) * l * h];
        let out_b = &mut out[b * l * h..(b + 1) * l * h];
        for head in 0..heads {
            let probs =
                &apm.data()[(b * heads + head) * l * l..][..l * l];
            for i in 0..l {
                acc.iter_mut().for_each(|a| *a = 0.0);
                for j in 0..l {
                    let v_j = &hid[j * h + head * d..j * h + (head + 1) * d];
                    crate::kernels::simd::axpy(
                        probs[i * l + j],
                        v_j,
                        &mut acc,
                    );
                }
                out_b[i * h + head * d..i * h + (head + 1) * d]
                    .copy_from_slice(&acc);
            }
        }
    }
    Tensor::new(vec![n, l, h], out)
}

/// Pad ids `[n, L]` to `[b, L]` with PAD(0) rows.
fn pad_ids(ids: &IdTensor, b: usize) -> Result<IdTensor> {
    let (n, l) = (ids.shape[0], ids.shape[1]);
    if n == b {
        return Ok(ids.clone());
    }
    if n > b {
        return Err(Error::shape(format!("pad_ids: {n} > {b}")));
    }
    let mut data = ids.data.clone();
    data.resize(b * l, 0);
    IdTensor::new(vec![b, l], data)
}

/// Pad a `[n, …]` f32 tensor with zero rows to `[b, …]`.
fn pad0(t: &Tensor, b: usize) -> Result<Tensor> {
    let n = t.shape()[0];
    if n == b {
        return Ok(t.clone());
    }
    if n > b {
        return Err(Error::shape(format!("pad0: {n} > {b}")));
    }
    let row: usize = t.shape()[1..].iter().product();
    let mut data = t.data().to_vec();
    data.resize(b * row, 0.0);
    let mut shape = t.shape().to_vec();
    shape[0] = b;
    Tensor::new(shape, data)
}

/// Pad an APM batch with uniform rows (keeps rows stochastic so softmax
/// invariants hold in padded lanes).
fn pad_apm(t: &Tensor, b: usize) -> Result<Tensor> {
    let n = t.shape()[0];
    if n == b {
        return Ok(t.clone());
    }
    let l = *t.shape().last().unwrap();
    let row: usize = t.shape()[1..].iter().product();
    let mut data = t.data().to_vec();
    data.resize(b * row, 1.0 / l as f32);
    let mut shape = t.shape().to_vec();
    shape[0] = b;
    Tensor::new(shape, data)
}

/// Take the first `n` rows of an output tensor.
fn slice_batch(t: Tensor, n: usize) -> Result<Tensor> {
    if t.shape()[0] == n {
        Ok(t)
    } else {
        t.slice0(0, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_helpers() {
        let ids = IdTensor::new(vec![2, 3], vec![1, 2, 3, 4, 5, 6]).unwrap();
        let p = pad_ids(&ids, 4).unwrap();
        assert_eq!(p.shape, vec![4, 3]);
        assert_eq!(&p.data[6..], &[0; 6]);
        assert!(pad_ids(&ids, 1).is_err());

        let t = Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap();
        let p = pad0(&t, 3).unwrap();
        assert_eq!(p.shape(), &[3, 2]);
        assert_eq!(p.data()[2..], [0.0; 4]);
    }

    #[test]
    fn host_attn_scores_shape_and_stochastic_rows() {
        let (n, l, h, heads) = (2, 6, 8, 2);
        let mut rng = crate::util::Pcg32::seeded(41);
        let data: Vec<f32> =
            (0..n * l * h).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let hidden = Tensor::new(vec![n, l, h], data).unwrap();
        let apm = host_attn_scores(&hidden, heads).unwrap();
        assert_eq!(apm.shape(), &[n, heads, l, l]);
        assert!(crate::tensor::ops::rows_stochastic(
            apm.data(),
            n * heads * l,
            l,
            1e-4
        ));
        // Bad head split rejected.
        assert!(host_attn_scores(&hidden, 3).is_err());
    }

    #[test]
    fn host_attn_apply_uniform_apm_averages_values() {
        let (n, l, h, heads) = (1, 4, 6, 2);
        let data: Vec<f32> = (0..n * l * h).map(|i| i as f32).collect();
        let hidden = Tensor::new(vec![n, l, h], data).unwrap();
        let apm = Tensor::new(
            vec![n, heads, l, l],
            vec![1.0 / l as f32; n * heads * l * l],
        )
        .unwrap();
        let out = host_attn_apply(&hidden, &apm).unwrap();
        assert_eq!(out.shape(), &[n, l, h]);
        // A uniform APM means every output row is the column mean.
        for c in 0..h {
            let mean: f32 =
                (0..l).map(|j| hidden.data()[j * h + c]).sum::<f32>()
                    / l as f32;
            for i in 0..l {
                assert!((out.data()[i * h + c] - mean).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn pad_apm_rows_remain_stochastic() {
        let apm = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 0.0, 0.5, 0.5])
            .unwrap();
        let p = pad_apm(&apm, 2).unwrap();
        assert_eq!(p.shape(), &[2, 1, 2, 2]);
        assert_eq!(&p.data()[4..], &[0.5, 0.5, 0.5, 0.5]);
    }
}
