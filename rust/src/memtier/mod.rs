//! Big-memory tier simulator (DESIGN.md §2 substitution).
//!
//! The paper's attention database lives on Intel Optane DC behind a DRAM
//! hardware cache ("memory mode"). This box has plain DRAM, so benches that
//! report absolute DB-access costs (Table 6, Fig. 13) apply this analytic
//! model on top of measured DRAM numbers: a fetch of `bytes` from the cold
//! tier costs `latency + bytes/bandwidth`, with a DRAM-cache hit
//! probability short-circuiting to DRAM cost. Parameters default to
//! published Optane DC characteristics (~300 ns load latency, ~6.6 GB/s
//! per-DIMM sequential read — Izraelevitz et al. 2019), and the Fig. 11
//! reuse analysis justifies the low default hit probability: APM accesses
//! have no hot set, so the DRAM cache rarely helps.

/// Analytic two-tier memory model.
#[derive(Debug, Clone, Copy)]
pub struct TierModel {
    /// Extra latency per access that misses DRAM (seconds).
    pub cold_latency_s: f64,
    /// Cold-tier sequential bandwidth (bytes/second).
    pub cold_bw: f64,
    /// DRAM bandwidth (bytes/second).
    pub dram_bw: f64,
    /// Probability an access hits the DRAM cache.
    pub dram_hit_prob: f64,
}

impl TierModel {
    /// Optane-DC-like defaults (memory mode, low reuse → 10% hit rate).
    pub fn optane() -> Self {
        TierModel {
            cold_latency_s: 300e-9,
            cold_bw: 6.6e9,
            dram_bw: 25e9,
            dram_hit_prob: 0.10,
        }
    }

    /// Pure-DRAM model (what this box actually measures).
    pub fn dram() -> Self {
        TierModel {
            cold_latency_s: 0.0,
            cold_bw: 25e9,
            dram_bw: 25e9,
            dram_hit_prob: 1.0,
        }
    }

    /// Expected seconds to fetch `bytes` once.
    pub fn fetch_seconds(&self, bytes: usize) -> f64 {
        let dram = bytes as f64 / self.dram_bw;
        let cold = self.cold_latency_s + bytes as f64 / self.cold_bw;
        self.dram_hit_prob * dram + (1.0 - self.dram_hit_prob) * cold
    }

    /// Expected seconds for a *copy-based* gather of `n` entries of
    /// `entry_bytes` (read cold + write DRAM — the paper's two reads one
    /// write chain collapses to read-cold + write-dram here).
    pub fn copy_gather_seconds(&self, n: usize, entry_bytes: usize) -> f64 {
        n as f64
            * (self.fetch_seconds(entry_bytes)
                + entry_bytes as f64 / self.dram_bw)
    }

    /// Expected seconds for a *mapping-based* gather: page-table updates
    /// only (`n` mmap calls), data moves lazily on compute access (charged
    /// to compute, as in the paper's accounting).
    pub fn map_gather_seconds(&self, n: usize, syscall_s: f64) -> f64 {
        n as f64 * syscall_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_fetch_slower_than_dram() {
        let m = TierModel::optane();
        let d = TierModel::dram();
        let bytes = 256 * 1024;
        assert!(m.fetch_seconds(bytes) > d.fetch_seconds(bytes));
    }

    #[test]
    fn mapping_beats_copy_by_orders_of_magnitude() {
        let m = TierModel::optane();
        // 64 APMs of 256 KiB, 2 µs per mmap syscall.
        let copy = m.copy_gather_seconds(64, 256 * 1024);
        let map = m.map_gather_seconds(64, 2e-6);
        // The analytic floor is ~20×; the measured gap (Table 6 bench) is
        // far larger because the copy path also pays allocator + framework
        // costs that this model deliberately excludes.
        assert!(copy / map > 10.0, "copy {copy} map {map}");
    }

    #[test]
    fn hit_prob_one_is_pure_dram() {
        let mut m = TierModel::optane();
        m.dram_hit_prob = 1.0;
        let bytes = 4096;
        assert!((m.fetch_seconds(bytes) - bytes as f64 / m.dram_bw).abs()
            < 1e-12);
    }
}
