//! Crate-wide error type (hand-rolled — `thiserror` is not in the offline
//! registry).

use std::fmt;

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error for the AttMemo stack.
#[derive(Debug)]
pub enum Error {
    /// PJRT / XLA runtime failures (compile, execute, literal conversion).
    Xla(xla::Error),

    /// Filesystem and socket failures.
    Io(std::io::Error),

    /// Malformed artifacts, manifests or configs.
    Config(String),

    /// JSON parse errors from the hand-rolled codec.
    Json(String),

    /// Shape mismatches between tensors / literals / executables.
    Shape(String),

    /// Attention/index database errors.
    Memo(String),

    /// Serving-layer errors (queue closed, request rejected…).
    Serving(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(e) => write!(f, "xla: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Json(m) => write!(f, "json: {m}"),
            Error::Shape(m) => write!(f, "shape: {m}"),
            Error::Memo(m) => write!(f, "memo: {m}"),
            Error::Serving(m) => write!(f, "serving: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand for a config error.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Shorthand for a shape error.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    /// Shorthand for a memoization error.
    pub fn memo(msg: impl Into<String>) -> Self {
        Error::Memo(msg.into())
    }
    /// Shorthand for a serving error.
    pub fn serving(msg: impl Into<String>) -> Self {
        Error::Serving(msg.into())
    }
}
