//! Crate-wide error type.

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error for the AttMemo stack.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// PJRT / XLA runtime failures (compile, execute, literal conversion).
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),

    /// Filesystem and socket failures.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Malformed artifacts, manifests or configs.
    #[error("config: {0}")]
    Config(String),

    /// JSON parse errors from the hand-rolled codec.
    #[error("json: {0}")]
    Json(String),

    /// Shape mismatches between tensors / literals / executables.
    #[error("shape: {0}")]
    Shape(String),

    /// Attention/index database errors.
    #[error("memo: {0}")]
    Memo(String),

    /// Serving-layer errors (queue closed, request rejected…).
    #[error("serving: {0}")]
    Serving(String),
}

impl Error {
    /// Shorthand for a config error.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Shorthand for a shape error.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    /// Shorthand for a memoization error.
    pub fn memo(msg: impl Into<String>) -> Self {
        Error::Memo(msg.into())
    }
    /// Shorthand for a serving error.
    pub fn serving(msg: impl Into<String>) -> Self {
        Error::Serving(msg.into())
    }
}
