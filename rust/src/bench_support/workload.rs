//! Shared bench/example setup: locate artifacts, build engines with a
//! populated attention database, and produce workload batches.

use std::path::PathBuf;
use std::sync::Arc;

use crate::config::{MemoConfig, MemoLevel};
use crate::memo::builder::{BuiltDb, DbBuilder};
use crate::memo::index::HnswParams;
use crate::memo::tier::MemoTier;
use crate::model::ModelRunner;
use crate::runtime::Runtime;
use crate::serving::engine::{Engine, EngineOptions};
use crate::tensor::tensor::IdTensor;
use crate::{Error, Result};

/// Artifacts directory: `$ATTMEMO_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("ATTMEMO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Open the runtime, with a helpful error if artifacts are missing.
pub fn open_runtime() -> Result<Arc<Runtime>> {
    let dir = artifacts_dir();
    Runtime::open(&dir).map(Arc::new).map_err(|e| {
        Error::config(format!(
            "{e}\nhint: run `make artifacts` (or set ATTMEMO_ARTIFACTS)"
        ))
    })
}

/// Build a populated database for a family from the serving training set.
///
/// `db_seqs` caps how many training sequences are ingested (DB-size sweeps);
/// 0 means all.
pub fn build_db(runtime: &Arc<Runtime>, family: &str, seq_len: usize,
                db_seqs: usize) -> Result<BuiltDb> {
    let runner = ModelRunner::load(runtime.clone(), family)?;
    let ds_name = dataset_for(runtime, family, seq_len, true)?;
    let (ids, _) = runtime.artifacts().load_dataset(&ds_name)?;
    let n = if db_seqs == 0 { ids.shape[0] } else { db_seqs.min(ids.shape[0]) };
    let ids = ids.slice0(0, n)?;
    DbBuilder::new(&runner).build(&ids)
}

/// Pick the exported dataset matching a family/seq-len (train or test).
pub fn dataset_for(runtime: &Arc<Runtime>, family: &str, seq_len: usize,
                   train: bool) -> Result<String> {
    let serving = runtime.artifacts().serving_seq_len;
    let kind = if family == "gpt" { "lm" } else { "cls" };
    let split = if train { "train" } else { "test" };
    let name = if seq_len == serving {
        format!("{kind}_{split}_serve")
    } else if kind == "cls" && !train {
        format!("cls_sweep_{seq_len}")
    } else {
        format!("{kind}_{split}")
    };
    // Validate existence up front.
    runtime.artifacts().dataset(&name)?;
    Ok(name)
}

/// Engine with a fresh DB at the given level (None ⇒ no DB, pure baseline).
pub fn engine_with_db(runtime: &Arc<Runtime>, family: &str, seq_len: usize,
                      level: MemoLevel, db_seqs: usize,
                      selective: bool) -> Result<Engine> {
    let built = if level == MemoLevel::Off {
        None
    } else {
        Some(Arc::new(build_db(runtime, family, seq_len, db_seqs)?))
    };
    engine_with_shared_db(runtime, family, seq_len, level, built, selective)
}

/// Engine over an already-built (shared) database — sweeps reuse one DB.
pub fn engine_with_shared_db(runtime: &Arc<Runtime>, family: &str,
                             seq_len: usize, level: MemoLevel,
                             built: Option<Arc<BuiltDb>>,
                             selective: bool) -> Result<Engine> {
    let memo = MemoConfig { level, selective, ..MemoConfig::default() };
    engine_with_memo(runtime, family, seq_len, memo, built)
}

/// Engine with explicit memoization options (online-admission sweeps).
pub fn engine_with_memo(runtime: &Arc<Runtime>, family: &str,
                        seq_len: usize, memo: MemoConfig,
                        built: Option<Arc<BuiltDb>>) -> Result<Engine> {
    let runner = ModelRunner::load(runtime.clone(), family)?;
    Engine::new(runner, built, EngineOptions { memo, seq_len })
}

/// A fresh shared online tier for a family (to be cloned into several
/// replicas via [`engine_with_tier`]).
pub fn online_tier(runtime: &Arc<Runtime>, family: &str, seq_len: usize,
                   memo: &MemoConfig) -> Result<Arc<MemoTier>> {
    let cfg = runtime.artifacts().family(family)?.config.clone();
    Ok(Arc::new(MemoTier::new(&cfg, seq_len, HnswParams::default(), memo)))
}

/// Engine replica over a shared online tier: N such engines form the
/// multi-replica serving fleet, all warming/consulting one database.
pub fn engine_with_tier(runtime: &Arc<Runtime>, family: &str,
                        seq_len: usize, memo: MemoConfig,
                        built: Option<Arc<BuiltDb>>,
                        tier: Arc<MemoTier>) -> Result<Engine> {
    let runner = ModelRunner::load(runtime.clone(), family)?;
    Engine::with_shared_tier(runner, built, tier,
                             EngineOptions { memo, seq_len })
}

/// Cold-start engine: empty database, serve-time admission on. The hit
/// rate starts at 0% and warms from live traffic.
pub fn cold_engine(runtime: &Arc<Runtime>, family: &str, seq_len: usize,
                   level: MemoLevel, capacity: usize,
                   min_attempts: u64) -> Result<Engine> {
    let memo = MemoConfig {
        level,
        selective: false,
        online_admission: true,
        max_db_entries: capacity,
        admission_min_attempts: min_attempts,
        ..MemoConfig::default()
    };
    engine_with_memo(runtime, family, seq_len, memo, None)
}

/// Test-set workload for a family.
pub fn test_workload(runtime: &Arc<Runtime>, family: &str, seq_len: usize,
                     n: usize) -> Result<(IdTensor, Vec<i32>)> {
    let ds = dataset_for(runtime, family, seq_len, false)?;
    let (ids, labels) = runtime.artifacts().load_dataset(&ds)?;
    let take = if n == 0 { ids.shape[0] } else { n.min(ids.shape[0]) };
    Ok((ids.slice0(0, take)?, labels[..take].to_vec()))
}
