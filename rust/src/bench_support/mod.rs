//! Benchmark scaffolding: a criterion-free timing harness, aligned table
//! printing (paper-table style output), shared workload setup used by
//! every `benches/bench_*.rs` target, and the `BENCH_SMOKE` short mode
//! CI runs to seed the perf trajectory (`BENCH_smoke.json`).

pub mod harness;
pub mod smoke;
pub mod tables;
pub mod workload;

pub use harness::{bench_fn, BenchResult};
pub use smoke::SmokeSummary;
pub use tables::TableWriter;
