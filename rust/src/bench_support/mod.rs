//! Benchmark scaffolding: a criterion-free timing harness, aligned table
//! printing (paper-table style output), and shared workload setup used by
//! every `benches/bench_*.rs` target.

pub mod harness;
pub mod tables;
pub mod workload;

pub use harness::{bench_fn, BenchResult};
pub use tables::TableWriter;
