//! Paper-style table printing for bench output (aligned columns, a title
//! row naming the table/figure being reproduced, and a CSV sidecar so
//! results can be post-processed).

use std::fmt::Write as _;

/// Collects rows and renders an aligned text table + CSV.
pub struct TableWriter {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TableWriter {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        TableWriter {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: format heterogeneous cells.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells);
    }

    /// Aligned text rendering.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n=== {} ===", self.title);
        let line = |cells: &[String], out: &mut String| {
            let mut parts = Vec::new();
            for (i, c) in cells.iter().enumerate() {
                parts.push(format!("{:<w$}", c, w = widths[i]));
            }
            let _ = writeln!(out, "  {}", parts.join("  "));
        };
        line(&self.headers, &mut out);
        let total: usize =
            widths.iter().sum::<usize>() + 2 * widths.len().saturating_sub(1);
        let _ = writeln!(out, "  {}", "-".repeat(total));
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }

    /// CSV rendering (for EXPERIMENTS.md extraction).
    pub fn csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }

    /// Print the table and optionally persist the CSV next to the bench.
    pub fn emit(&self, csv_path: Option<&std::path::Path>) {
        println!("{}", self.render());
        if let Some(p) = csv_path {
            if let Some(dir) = p.parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            if let Err(e) = std::fs::write(p, self.csv()) {
                eprintln!("warn: could not write {}: {e}", p.display());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TableWriter::new("Demo", &["model", "speedup"]);
        t.row(&["bert".into(), "1.22x".into()]);
        t.row(&["roberta-long".into(), "1.05x".into()]);
        let r = t.render();
        assert!(r.contains("=== Demo ==="));
        assert!(r.contains("roberta-long"));
        let csv = t.csv();
        assert!(csv.starts_with("model,speedup\n"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn row_width_checked() {
        let mut t = TableWriter::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
