//! Criterion-free micro/macro benchmark harness (criterion is not in the
//! offline registry). Warmup + timed iterations with mean/p50/min reporting
//! and an adaptive iteration count targeted at a wall-clock budget.

use crate::util::stats::Summary;
use std::time::Instant;

/// Timing result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub min_ms: f64,
    pub stddev_ms: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>7} iters  mean {:>9.3} ms  p50 {:>9.3} ms  min {:>9.3} ms",
            self.name, self.iters, self.mean_ms, self.p50_ms, self.min_ms
        )
    }
}

/// Benchmark a closure: `warmup` untimed runs, then enough timed runs to
/// fill ~`budget_ms` (bounded to [min_iters, max_iters]).
pub fn bench_fn<F: FnMut()>(name: &str, warmup: usize, budget_ms: f64,
                            mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    // Pilot run to size the iteration count. BENCH_SMOKE caps it so the
    // CI smoke job touches every case without paying full budgets.
    let t0 = Instant::now();
    f();
    let pilot_ms = t0.elapsed().as_secs_f64() * 1e3;
    let iters = if crate::bench_support::smoke::smoke() {
        3
    } else {
        ((budget_ms / pilot_ms.max(1e-6)) as usize).clamp(3, 1000)
    };

    let mut s = Summary::new();
    s.record(pilot_ms);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        s.record(t.elapsed().as_secs_f64() * 1e3);
    }
    BenchResult {
        name: name.to_string(),
        iters: s.count(),
        mean_ms: s.mean(),
        p50_ms: s.p50(),
        min_ms: s.min(),
        stddev_ms: s.stddev(),
    }
}

/// Time a single execution of a closure in milliseconds.
pub fn time_ms<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64() * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench_fn("noop-ish", 1, 5.0, || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert!(r.iters >= 3);
        assert!(r.min_ms <= r.mean_ms * 1.5 + 1e-9);
        assert!(r.line().contains("noop-ish"));
    }

    #[test]
    fn time_ms_measures() {
        let (_, ms) = time_ms(|| std::thread::sleep(
            std::time::Duration::from_millis(5)));
        assert!(ms >= 4.0);
    }
}
