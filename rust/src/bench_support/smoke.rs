//! `BENCH_SMOKE` support: the short CI bench mode and its JSON summary.
//!
//! CI's `bench-smoke` job runs the bench binaries with `BENCH_SMOKE=1`,
//! which caps iteration counts (via [`iters`] and the harness) so the
//! whole suite finishes in seconds, and uploads the [`SmokeSummary`]
//! emitted as `BENCH_smoke.json` — the per-PR perf trajectory (latency,
//! hit-rate and dedup-yield headline numbers) that full local runs also
//! refresh.

use std::fmt::Write as _;
use std::path::Path;

/// Whether the `BENCH_SMOKE` env var asks for the short smoke mode
/// (any non-empty value other than `0`).
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

/// Pick `full` normally, `short` under `BENCH_SMOKE`.
pub fn iters(full: usize, short: usize) -> usize {
    if smoke() { short } else { full }
}

/// The headline keys every CI smoke run must leave in `BENCH_smoke.json`.
/// Living next to the emitters (not in a workflow shell loop) so adding a
/// key to a bench and to the required set is one diff in one language —
/// CI enforces the list through `tests/smoke_keys.rs` calling
/// [`SmokeSummary::require_keys`].
pub const REQUIRED_SMOKE_KEYS: &[&str] = &[
    "cold_hit_p99_ns",
    "hot_resident_ratio",
    "cb_p99_ms",
    "cb_dedup_yield",
    "publish_touched_nodes",
    "mixed_admit_p99_ns",
    "cold_miss_p50_ns",
    "simd_dot_speedup",
];

/// Flat key → number summary collected by a bench run and emitted as
/// `BENCH_smoke.json`.
#[derive(Default)]
pub struct SmokeSummary {
    entries: Vec<(String, f64)>,
}

impl SmokeSummary {
    /// Empty summary.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one headline metric.
    pub fn push(&mut self, key: &str, value: f64) {
        self.entries.push((key.to_string(), value));
    }

    /// Render the summary as a flat JSON object.
    pub fn json(&self) -> String {
        let mut out = String::from("{\n");
        let head_comma = if self.entries.is_empty() { "" } else { "," };
        let _ = writeln!(out, "  \"smoke\": {}{head_comma}", smoke());
        for (i, (k, v)) in self.entries.iter().enumerate() {
            let comma = if i + 1 == self.entries.len() { "" } else { "," };
            if v.is_finite() {
                let _ = writeln!(out, "  \"{k}\": {v:.6}{comma}");
            } else {
                let _ = writeln!(out, "  \"{k}\": null{comma}");
            }
        }
        out.push('}');
        out.push('\n');
        out
    }

    /// Write the JSON summary to `path` (warns instead of failing — a
    /// bench run must not die on an unwritable results file).
    pub fn emit(&self, path: &Path) {
        if let Err(e) = std::fs::write(path, self.json()) {
            eprintln!("warn: could not write {}: {e}", path.display());
        } else {
            println!("smoke summary → {}", path.display());
        }
    }

    /// [`SmokeSummary::emit`], preserving keys already present in the
    /// file that this summary does not set. Several bench binaries share
    /// one `BENCH_smoke.json`; each must merge, not overwrite, or
    /// whichever runs last erases the others' headline numbers. Keys
    /// this summary *does* set always take the fresh value. An existing
    /// file that fails to parse is warned about and replaced outright.
    pub fn emit_merged(&self, path: &Path) {
        let mut merged = SmokeSummary::new();
        merged.entries.clone_from(&self.entries);
        if let Ok(text) = std::fs::read_to_string(path) {
            match crate::config::json::Json::parse(&text) {
                Ok(prev) => {
                    for (k, v) in prev.as_obj().into_iter().flatten() {
                        if k.as_str() == "smoke"
                            || self.entries.iter().any(|(sk, _)| sk == k)
                        {
                            continue;
                        }
                        if let Some(x) = v.as_f64() {
                            merged.push(k, x);
                        }
                    }
                }
                Err(e) => eprintln!(
                    "warn: replacing unparseable {}: {e}",
                    path.display()
                ),
            }
        }
        merged.emit(path);
    }

    /// Render the summary as one compact JSON line (the
    /// `BENCH_history.jsonl` format: one entry per recorded run).
    pub fn history_line(&self) -> String {
        let mut out = String::from("{");
        let _ = write!(out, "\"smoke\": {}", smoke());
        for (k, v) in &self.entries {
            if v.is_finite() {
                let _ = write!(out, ", \"{k}\": {v:.6}");
            } else {
                let _ = write!(out, ", \"{k}\": null");
            }
        }
        out.push('}');
        out
    }

    /// The cross-PR regression gate + trend append: find the most recent
    /// history entry at `path` carrying `key` (several bench binaries
    /// append to one history file, so the literal last line may belong to
    /// a different bench), fail when this run's `key` dropped more than
    /// `margin` below it, then append the current summary as a new JSON
    /// line. A missing file or a history without `key` passes the gate
    /// (the first entry seeds the trajectory) — but any line that exists
    /// and fails to parse is a hard error, not a silent pass: a truncated
    /// or hand-mangled history must never turn the gate off and then
    /// ratchet it down to a regressed value. A failed gate appends
    /// nothing, so the history only ever records runs that passed.
    pub fn check_and_append_history(
        &self, path: &Path, key: &str, margin: f64,
    ) -> std::result::Result<(), String> {
        self.check_history(path, key, margin)?;
        let mut text = std::fs::read_to_string(path).unwrap_or_default();
        if !text.is_empty() && !text.ends_with('\n') {
            text.push('\n');
        }
        text.push_str(&self.history_line());
        text.push('\n');
        std::fs::write(path, text)
            .map_err(|e| format!("write {}: {e}", path.display()))
    }

    /// The lower-bound gate of [`SmokeSummary::check_and_append_history`]
    /// without the append: fail when this run's `key` dropped more than
    /// `margin` below the most recent history entry carrying it. Use for
    /// the extra keys of a bench that already appends its summary through
    /// one `check_and_append_history` call — gating a second key must not
    /// write the history line twice.
    pub fn check_history(
        &self, path: &Path, key: &str, margin: f64,
    ) -> std::result::Result<(), String> {
        let (previous, current) = self.gate_values(path, key)?;
        if let (Some(prev), Some(cur)) = (previous, current) {
            if cur + margin < prev {
                return Err(format!(
                    "{key} regressed: {cur:.4} vs last recorded {prev:.4} \
                     (allowed margin {margin})"
                ));
            }
        }
        Ok(())
    }

    /// Upper-bound (smaller-is-better) variant of
    /// [`SmokeSummary::check_history`]: fail when this run's `key` grew
    /// past `prev * allowed_ratio`. A ratio, not an absolute margin,
    /// because the ceilinged keys are latencies whose scale is
    /// machine-dependent; pick it generously (e.g. 2.0) so only step
    /// regressions trip in CI. Non-appending, like `check_history`.
    pub fn check_history_ceiling(
        &self, path: &Path, key: &str, allowed_ratio: f64,
    ) -> std::result::Result<(), String> {
        let (previous, current) = self.gate_values(path, key)?;
        if let (Some(prev), Some(cur)) = (previous, current) {
            if cur > prev * allowed_ratio {
                return Err(format!(
                    "{key} regressed: {cur:.4} vs last recorded {prev:.4} \
                     (allowed ratio {allowed_ratio})"
                ));
            }
        }
        Ok(())
    }

    /// Assert that the summary file at `path` carries every key in
    /// `keys` — the CI "required smoke keys" gate, replacing the old
    /// workflow shell loop so the list lives next to the emitters (see
    /// [`REQUIRED_SMOKE_KEYS`]). A key is present even when its value is
    /// `null` (a bench that ran but measured a non-finite number is a
    /// bench regression, not a missing bench — the history gates catch
    /// value problems). Errors list *all* missing keys at once.
    pub fn require_keys(
        path: &Path, keys: &[&str],
    ) -> std::result::Result<(), String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let parsed = crate::config::json::Json::parse(&text)
            .map_err(|e| format!("parse {}: {e}", path.display()))?;
        let missing: Vec<&str> = keys
            .iter()
            .copied()
            .filter(|k| parsed.get(k).is_none())
            .collect();
        if missing.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "{} is missing required smoke keys: {}",
                path.display(),
                missing.join(", ")
            ))
        }
    }

    /// Shared reverse scan for the history gates: this run's `key` plus
    /// the most recent history entry at `path` carrying it. Missing file
    /// or absent key → `None` (the gates pass; the first entry seeds the
    /// trajectory); a line that exists but fails to parse is a hard
    /// error so a mangled history can never silently disable a gate.
    fn gate_values(
        &self, path: &Path, key: &str,
    ) -> std::result::Result<(Option<f64>, Option<f64>), String> {
        let current = self
            .entries
            .iter()
            .find(|(k, _)| k.as_str() == key)
            .map(|(_, v)| *v);
        let text = std::fs::read_to_string(path).unwrap_or_default();
        let mut previous = None;
        for line in text.lines().rev().filter(|l| !l.trim().is_empty()) {
            match crate::config::json::Json::parse(line) {
                Ok(entry) => {
                    if let Some(v) = entry.get(key).and_then(|v| v.as_f64())
                    {
                        previous = Some(v);
                        break;
                    }
                }
                Err(e) => {
                    return Err(format!(
                        "unparseable entry in {} ({e}); fix or remove \
                         the line before the gate can run",
                        path.display()
                    ))
                }
            }
        }
        Ok((previous, current))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_renders_flat_json() {
        let mut s = SmokeSummary::new();
        s.push("warm_hit_rate", 0.9375);
        s.push("dedup_yield_semantic", 0.5);
        let j = s.json();
        assert!(j.contains("\"warm_hit_rate\": 0.937500"), "{j}");
        assert!(j.contains("\"dedup_yield_semantic\": 0.500000"), "{j}");
        assert!(j.trim_start().starts_with('{'));
        assert!(j.trim_end().ends_with('}'));
        // The last metric line carries no trailing comma.
        assert!(j.contains("0.500000\n}"), "{j}");
    }

    #[test]
    fn non_finite_values_become_null() {
        let mut s = SmokeSummary::new();
        s.push("bad", f64::NAN);
        assert!(s.json().contains("\"bad\": null"));
    }

    #[test]
    fn history_line_is_one_parseable_json_line() {
        let mut s = SmokeSummary::new();
        s.push("sim_warm_hit_rate", 0.9375);
        let line = s.history_line();
        assert!(!line.contains('\n'));
        let parsed = crate::config::json::Json::parse(&line).unwrap();
        assert_eq!(
            parsed.get("sim_warm_hit_rate").and_then(|v| v.as_f64()),
            Some(0.9375)
        );
    }

    #[test]
    fn emit_merged_preserves_other_benches_keys() {
        let dir = std::env::temp_dir().join("attmemo_smoke_merge");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("smoke.json");
        let _ = std::fs::remove_file(&path);

        let mut a = SmokeSummary::new();
        a.push("sim_warm_hit_rate", 0.9);
        a.push("admit_p99_ns", 1200.0);
        a.emit_merged(&path);
        let mut b = SmokeSummary::new();
        b.push("cold_hit_p99_ns", 8000.0);
        b.push("admit_p99_ns", 1500.0); // fresh value wins
        b.emit_merged(&path);

        let merged = crate::config::json::Json::from_file(&path).unwrap();
        assert_eq!(
            merged.get("sim_warm_hit_rate").and_then(|v| v.as_f64()),
            Some(0.9),
            "the first bench's key must survive the second emit"
        );
        assert_eq!(
            merged.get("cold_hit_p99_ns").and_then(|v| v.as_f64()),
            Some(8000.0)
        );
        assert_eq!(
            merged.get("admit_p99_ns").and_then(|v| v.as_f64()),
            Some(1500.0),
            "a re-emitted key takes the fresh value"
        );
    }

    #[test]
    fn require_keys_reports_every_missing_key() {
        let dir = std::env::temp_dir().join("attmemo_smoke_require");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("smoke.json");

        let mut s = SmokeSummary::new();
        s.push("cb_p99_ms", 4.0);
        s.push("nan_key", f64::NAN); // present as null — still counts
        s.emit(&path);
        SmokeSummary::require_keys(&path, &["cb_p99_ms", "nan_key"])
            .unwrap();
        let err = SmokeSummary::require_keys(
            &path,
            &["cb_p99_ms", "cold_hit_p99_ns", "publish_touched_nodes"],
        )
        .unwrap_err();
        assert!(err.contains("cold_hit_p99_ns"), "{err}");
        assert!(err.contains("publish_touched_nodes"), "{err}");
        assert!(!err.contains("cb_p99_ms"), "{err}");
        // A missing file is an error, not a pass.
        assert!(SmokeSummary::require_keys(
            &dir.join("absent.json"),
            REQUIRED_SMOKE_KEYS
        )
        .is_err());
    }

    #[test]
    fn history_gate_skips_other_benches_lines() {
        let dir = std::env::temp_dir().join("attmemo_smoke_hist_multi");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hist.jsonl");
        let _ = std::fs::remove_file(&path);

        let mut a = SmokeSummary::new();
        a.push("sim_warm_hit_rate", 0.9);
        a.check_and_append_history(&path, "sim_warm_hit_rate", 0.05)
            .unwrap();
        // A different bench appends a line without the gated key.
        let mut b = SmokeSummary::new();
        b.push("cold_warm_hit_rate", 1.0);
        b.check_and_append_history(&path, "cold_warm_hit_rate", 0.01)
            .unwrap();
        // The gate must reach past b's line to a's entry and still
        // catch the regression.
        let mut worse = SmokeSummary::new();
        worse.push("sim_warm_hit_rate", 0.5);
        let err = worse
            .check_and_append_history(&path, "sim_warm_hit_rate", 0.05)
            .unwrap_err();
        assert!(err.contains("regressed"), "{err}");
    }

    #[test]
    fn non_appending_gates_check_without_writing() {
        let dir = std::env::temp_dir().join("attmemo_smoke_hist_cb");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hist.jsonl");
        let _ = std::fs::remove_file(&path);

        // Empty history: both gates pass and neither creates the file.
        let mut s = SmokeSummary::new();
        s.push("cb_p99_ms", 4.0);
        s.push("cb_dedup_yield", 0.6);
        s.check_history(&path, "cb_dedup_yield", 0.05).unwrap();
        s.check_history_ceiling(&path, "cb_p99_ms", 2.0).unwrap();
        assert!(!path.exists(), "non-appending gates must not write");

        // Seed via the appending gate, then exercise both directions.
        s.check_and_append_history(&path, "cb_dedup_yield", 0.05)
            .unwrap();
        let before = std::fs::read_to_string(&path).unwrap();

        let mut worse = SmokeSummary::new();
        worse.push("cb_p99_ms", 9.0); // > 4.0 * 2.0 → ceiling trips
        worse.push("cb_dedup_yield", 0.4); // 0.4 + 0.05 < 0.6 → floor trips
        let err = worse
            .check_history_ceiling(&path, "cb_p99_ms", 2.0)
            .unwrap_err();
        assert!(err.contains("cb_p99_ms"), "{err}");
        let err =
            worse.check_history(&path, "cb_dedup_yield", 0.05).unwrap_err();
        assert!(err.contains("regressed"), "{err}");

        // Within bounds: lower latency always passes the ceiling, a
        // within-margin dip passes the floor — and the file is untouched.
        let mut ok = SmokeSummary::new();
        ok.push("cb_p99_ms", 2.5);
        ok.push("cb_dedup_yield", 0.57);
        ok.check_history_ceiling(&path, "cb_p99_ms", 2.0).unwrap();
        ok.check_history(&path, "cb_dedup_yield", 0.05).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), before,
                   "check-only gates must never append");
    }

    /// Satellite: the CI trend gate — first entries seed, equal values
    /// append, a regression beyond the margin fails without appending.
    #[test]
    fn history_gate_detects_regression_and_appends() {
        let dir = std::env::temp_dir().join("attmemo_smoke_hist");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hist.jsonl");
        let _ = std::fs::remove_file(&path);

        let mut s = SmokeSummary::new();
        s.push("sim_warm_hit_rate", 0.9);
        // No history yet: the gate passes and seeds the file.
        s.check_and_append_history(&path, "sim_warm_hit_rate", 0.05)
            .unwrap();
        // Equal value: passes and appends a second entry.
        s.check_and_append_history(&path, "sim_warm_hit_rate", 0.05)
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2, "{text}");
        // Within-margin dip still passes.
        let mut dip = SmokeSummary::new();
        dip.push("sim_warm_hit_rate", 0.87);
        dip.check_and_append_history(&path, "sim_warm_hit_rate", 0.05)
            .unwrap();
        // A clear regression beyond the margin fails and must not append.
        let mut worse = SmokeSummary::new();
        worse.push("sim_warm_hit_rate", 0.7);
        let err = worse
            .check_and_append_history(&path, "sim_warm_hit_rate", 0.05)
            .unwrap_err();
        assert!(err.contains("regressed"), "{err}");
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3, "failed gate must not append");
        // A mangled last line must fail loudly, never silently disable
        // the gate (and must not append on top of the damage).
        std::fs::write(&path, "{\"sim_warm_hit_rate\": 0.9}\n{trunc")
            .unwrap();
        let mut s2 = SmokeSummary::new();
        s2.push("sim_warm_hit_rate", 0.9);
        let err = s2
            .check_and_append_history(&path, "sim_warm_hit_rate", 0.05)
            .unwrap_err();
        assert!(err.contains("unparseable"), "{err}");
    }
}
