//! `BENCH_SMOKE` support: the short CI bench mode and its JSON summary.
//!
//! CI's `bench-smoke` job runs the bench binaries with `BENCH_SMOKE=1`,
//! which caps iteration counts (via [`iters`] and the harness) so the
//! whole suite finishes in seconds, and uploads the [`SmokeSummary`]
//! emitted as `BENCH_smoke.json` — the per-PR perf trajectory (latency,
//! hit-rate and dedup-yield headline numbers) that full local runs also
//! refresh.

use std::fmt::Write as _;
use std::path::Path;

/// Whether the `BENCH_SMOKE` env var asks for the short smoke mode
/// (any non-empty value other than `0`).
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

/// Pick `full` normally, `short` under `BENCH_SMOKE`.
pub fn iters(full: usize, short: usize) -> usize {
    if smoke() { short } else { full }
}

/// Flat key → number summary collected by a bench run and emitted as
/// `BENCH_smoke.json`.
#[derive(Default)]
pub struct SmokeSummary {
    entries: Vec<(String, f64)>,
}

impl SmokeSummary {
    /// Empty summary.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one headline metric.
    pub fn push(&mut self, key: &str, value: f64) {
        self.entries.push((key.to_string(), value));
    }

    /// Render the summary as a flat JSON object.
    pub fn json(&self) -> String {
        let mut out = String::from("{\n");
        let head_comma = if self.entries.is_empty() { "" } else { "," };
        let _ = writeln!(out, "  \"smoke\": {}{head_comma}", smoke());
        for (i, (k, v)) in self.entries.iter().enumerate() {
            let comma = if i + 1 == self.entries.len() { "" } else { "," };
            if v.is_finite() {
                let _ = writeln!(out, "  \"{k}\": {v:.6}{comma}");
            } else {
                let _ = writeln!(out, "  \"{k}\": null{comma}");
            }
        }
        out.push('}');
        out.push('\n');
        out
    }

    /// Write the JSON summary to `path` (warns instead of failing — a
    /// bench run must not die on an unwritable results file).
    pub fn emit(&self, path: &Path) {
        if let Err(e) = std::fs::write(path, self.json()) {
            eprintln!("warn: could not write {}: {e}", path.display());
        } else {
            println!("smoke summary → {}", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_renders_flat_json() {
        let mut s = SmokeSummary::new();
        s.push("warm_hit_rate", 0.9375);
        s.push("dedup_yield_semantic", 0.5);
        let j = s.json();
        assert!(j.contains("\"warm_hit_rate\": 0.937500"), "{j}");
        assert!(j.contains("\"dedup_yield_semantic\": 0.500000"), "{j}");
        assert!(j.trim_start().starts_with('{'));
        assert!(j.trim_end().ends_with('}'));
        // The last metric line carries no trailing comma.
        assert!(j.contains("0.500000\n}"), "{j}");
    }

    #[test]
    fn non_finite_values_become_null() {
        let mut s = SmokeSummary::new();
        s.push("bad", f64::NAN);
        assert!(s.json().contains("\"bad\": null"));
    }
}
