//! The serving layer: vLLM-router-like request path with AttMemo as a
//! first-class feature.
//!
//! Flow: client → TCP line protocol (`server`) or in-process handle →
//! affinity-bucketed request router (`affinity`: requests sketching
//! alike — by token prefix or, in semantic mode, by meaning through the
//! embedding table — share a bucket; batchers prefer home buckets,
//! work-steal when idle, and the bucket space can adaptively resize) →
//! per-replica batching loop (`batcher`): either the legacy one-shot
//! fixed-batch path or, with `continuous_batching`, the iteration-level
//! scheduler in `schedule` (sequences join and leave a persistent
//! in-flight batch at every step boundary, responses stream back as
//! chunks with per-client backpressure) → inference engine (`engine`,
//! where memoization happens) → streamed response. `metrics` records
//! per-stage latency for the paper's Table 4 breakdown plus the
//! affinity/dedup/scheduler gauges. `queue` keeps the plain single-FIFO
//! `BoundedQueue` primitive.

pub mod affinity;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod schedule;
pub mod server;

pub use affinity::{bucket_for, bucket_of, signature, AffinityRouter,
                   Signer};
pub use batcher::{form_batch, Batcher};
pub use engine::{BatchResult, Engine, EngineOptions};
pub use metrics::EngineMetrics;
pub use queue::BoundedQueue;
pub use request::{Request, RequestId, Response, ResponseChunk};
pub use schedule::{
    run_fixed_batch, ContinuousScheduler, FinishedSeq, InFlightBatch,
    IterReport, StepEngine,
};
