//! The serving layer: vLLM-router-like request path with AttMemo as a
//! first-class feature.
//!
//! Flow: client → TCP line protocol (`server`) or in-process handle →
//! bounded queue (`queue`) → dynamic batcher (`batcher`) → inference
//! engine (`engine`, where memoization happens) → response. `metrics`
//! records per-stage latency for the paper's Table 4 breakdown.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod server;

pub use batcher::Batcher;
pub use engine::{Engine, EngineOptions};
pub use metrics::EngineMetrics;
pub use queue::BoundedQueue;
pub use request::{Request, RequestId, Response};
