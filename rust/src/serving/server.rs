//! TCP line-protocol server + client (the external request path).
//!
//! Protocol (one line per message, UTF-8):
//!   client → `INFER <text…>`          classify a raw sentence
//!   client → `STREAM <steps> <text…>` run `steps` iterations, streaming
//!                                     one chunk line per step
//!   client → `STATS`                  engine metrics snapshot
//!   client → `QUIT`                   close the connection
//!   server → `OK <label> <memo_hits> <latency_ms>`
//!   server → `CH <step> <label> <memo_hits>`   (per non-final chunk)
//!   server → `DONE <label> <memo_hits> <latency_ms>`
//!   server → `ERR <reason>` / `STATS <report>` / `BYE`
//!
//! `STREAM` rides the same queue as `INFER`; under
//! `--continuous-batching` each step's chunk is produced at one
//! scheduler iteration and the handler relays it as soon as the bounded
//! per-client channel hands it over (a client that stops reading fills
//! its own channel and stalls only its own in-flight slot).
//!
//! Connections are handled by a small thread pool; handlers tokenize,
//! sketch the request's affinity signature through the server's
//! [`Signer`] (token-prefix min-hash, or — with `--signature-mode
//! semantic` — a SimHash over mean-pooled embedding-table rows, so
//! paraphrases share a bucket; with no embedding table loaded an
//! *explicit* semantic request fails startup while a semantic config
//! default warns and falls back to the min-hash), and enqueue into the
//! signature's bucket
//! of the shared [`AffinityRouter`]. The server runs one batcher thread
//! per engine *replica*; each prefers its home buckets (similar requests
//! batch together) and steals from the fullest bucket when idle; with
//! `--adaptive-buckets` the router grows/shrinks its bucket space when
//! the steal rate or occupancy skew shows the partition fighting the
//! traffic. Replicas are expected to share one online `MemoTier`
//! (`Engine::with_shared_tier`): each replica's forward pass runs behind
//! its own mutex, while tier lookups from all replicas proceed in
//! parallel on the tier's lock-free seqlock snapshots — there is no
//! global engine mutex (nor any shard lock) on the lookup path.
//! `STATS` aggregates the fleet and appends the router's affinity
//! gauges (per-bucket depth, steal and resize counts).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::{ServingConfig, SignatureMode};
use crate::data::tokenizer::Vocab;
use crate::memo::semhash::SemanticSketcher;
use crate::serving::affinity::{AffinityRouter, Signer};
use crate::serving::batcher::Batcher;
use crate::serving::engine::Engine;
use crate::serving::metrics::EngineMetrics;
use crate::serving::request::Request;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Resolve the server's affinity signer from the configured mode and the
/// runner's embedding table.
///
/// An *explicitly requested* semantic mode (`--signature-mode semantic`,
/// `--set signature_mode=semantic`) with no usable embedding table is a
/// hard startup error: silently serving a different bucketing than the
/// operator asked for hides real capacity/locality regressions. When
/// semantic mode merely came from a config default, the prefix min-hash
/// fallback applies with a warning, as before.
fn build_signer(cfg: &ServingConfig,
                table: Result<&Tensor>) -> Result<Signer> {
    match cfg.signature_mode {
        SignatureMode::Semantic => {
            match table.and_then(|t| {
                SemanticSketcher::from_embedding(t, cfg.signature_prefix_len)
            }) {
                Ok(sk) => Ok(Signer::semantic(sk)),
                Err(e) if cfg.signature_explicit => Err(Error::config(
                    format!(
                        "--signature-mode semantic was requested but the \
                         semantic signer is unavailable ({e}); load a model \
                         with an embedding table or drop the flag"
                    ),
                )),
                Err(e) => {
                    log::warn!(
                        "semantic signatures unavailable ({e}); \
                         falling back to the prefix min-hash"
                    );
                    Ok(Signer::prefix(cfg.signature_prefix_len))
                }
            }
        }
        SignatureMode::Prefix => Ok(Signer::prefix(cfg.signature_prefix_len)),
    }
}

/// A running server: listener thread + per-replica batcher threads +
/// handler pool.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    queue: Arc<AffinityRouter<Request>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving with one batcher thread per engine replica.
    /// Returns once the listener is live. Pass a single-element vector for
    /// the classic one-engine server.
    pub fn start(engines: Vec<Engine>, vocab: Arc<Vocab>,
                 cfg: ServingConfig) -> Result<Server> {
        if engines.is_empty() {
            return Err(Error::serving(
                "server needs at least one engine replica",
            ));
        }
        if engines.len() != cfg.replicas {
            return Err(Error::serving(format!(
                "cfg.replicas = {} but {} engines were supplied",
                cfg.replicas,
                engines.len()
            )));
        }
        // The request signer is built once, before the engines disappear
        // behind their mutexes: semantic mode sketches by meaning through
        // the model's embedding table. A missing table downgrades a
        // semantic *default* to the prefix min-hash with a warning, but
        // fails startup when the operator asked for semantic explicitly.
        let signer = Arc::new(build_signer(
            &cfg, engines[0].runner().embedding_table())?);
        log::info!("affinity signatures: {} mode", signer.mode_name());

        let listener = TcpListener::bind(&cfg.bind)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let queue: Arc<AffinityRouter<Request>> = Arc::new(
            AffinityRouter::new(cfg.affinity_buckets, cfg.replicas,
                                cfg.queue_depth)
                .with_adaptive(cfg.affinity_adaptive,
                               cfg.affinity_max_buckets),
        );
        let engines: Arc<Vec<Arc<Mutex<Engine>>>> = Arc::new(
            engines
                .into_iter()
                .map(|e| Arc::new(Mutex::new(e)))
                .collect(),
        );
        let stop = Arc::new(AtomicBool::new(false));
        let mut threads = Vec::new();

        // One batcher thread per replica, all competing for the queue.
        for (replica, engine) in engines.iter().enumerate() {
            let batcher = Batcher::new(queue.clone(), engine.clone(),
                                       cfg.clone(), replica);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("attmemo-batcher-{replica}"))
                    .spawn(move || batcher.run())
                    .expect("spawn batcher"),
            );
        }

        // Rejections are counted lock-free: the overload path must never
        // wait on an engine mutex held across a forward pass.
        let rejected = Arc::new(AtomicU64::new(0));

        // Accept loop.
        {
            let queue = queue.clone();
            let stop2 = stop.clone();
            let engines2 = engines.clone();
            let rejected2 = rejected.clone();
            let signer2 = signer.clone();
            let seq_len = cfg.seq_len;
            let chunk_depth = cfg.chunk_depth;
            threads.push(
                std::thread::Builder::new()
                    .name("attmemo-accept".into())
                    .spawn(move || {
                        let next_id = Arc::new(AtomicU64::new(0));
                        let mut handlers: Vec<std::thread::JoinHandle<()>> =
                            Vec::new();
                        loop {
                            if stop2.load(Ordering::SeqCst) {
                                break;
                            }
                            match listener.accept() {
                                Ok((stream, _)) => {
                                    let q = queue.clone();
                                    let v = vocab.clone();
                                    let e = engines2.clone();
                                    let rej = rejected2.clone();
                                    let ids = next_id.clone();
                                    let sg = signer2.clone();
                                    handlers.push(std::thread::spawn(move || {
                                        let _ = handle_conn(
                                            stream, q, v, e, rej, ids, sg,
                                            seq_len, chunk_depth,
                                        );
                                    }));
                                }
                                Err(ref e)
                                    if e.kind()
                                        == std::io::ErrorKind::WouldBlock =>
                                {
                                    std::thread::sleep(Duration::from_millis(
                                        5,
                                    ));
                                }
                                Err(e) => {
                                    log::error!("accept: {e}");
                                    break;
                                }
                            }
                        }
                        for h in handlers {
                            let _ = h.join();
                        }
                    })
                    .expect("spawn accept"),
            );
        }

        log::info!("server listening on {addr}");
        Ok(Server { addr, stop, queue, threads })
    }

    /// Stop accepting, drain, and join all threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.queue.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn handle_conn(stream: TcpStream, queue: Arc<AffinityRouter<Request>>,
               vocab: Arc<Vocab>, engines: Arc<Vec<Arc<Mutex<Engine>>>>,
               rejected: Arc<AtomicU64>, next_id: Arc<AtomicU64>,
               signer: Arc<Signer>, seq_len: usize,
               chunk_depth: usize) -> Result<()> {
    stream.set_nodelay(true)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // connection closed
        }
        let msg = line.trim_end();
        if let Some(text) = msg.strip_prefix("INFER ") {
            let ids = vocab.encode(text, seq_len);
            // Affinity routing: requests that sketch alike (by prefix
            // min-hash or by embedding-space SimHash) share a bucket, so
            // they meet in the same batch downstream.
            let sig = signer.sign(&ids);
            let (req, rx) = Request::streaming(
                next_id.fetch_add(1, Ordering::SeqCst), ids, sig, 1,
                chunk_depth,
            );
            let t0 = std::time::Instant::now();
            if queue.try_push(sig, req).is_err() {
                rejected.fetch_add(1, Ordering::Relaxed);
                writeln!(out, "ERR overloaded")?;
                continue;
            }
            match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(resp) => writeln!(
                    out,
                    "OK {} {} {:.2}",
                    resp.label,
                    resp.memo_hits,
                    t0.elapsed().as_secs_f64() * 1e3
                )?,
                Err(_) => writeln!(out, "ERR timeout")?,
            }
        } else if let Some(rest) = msg.strip_prefix("STREAM ") {
            // `STREAM <steps> <text…>`: run the request for `steps`
            // iterations and relay each chunk as its own line; the final
            // chunk closes with DONE and the client-observed latency.
            let mut split = rest.splitn(2, ' ');
            let steps: usize = split
                .next()
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            let text = split.next().unwrap_or("");
            if steps == 0 || steps > 64 || text.is_empty() {
                writeln!(out, "ERR usage: STREAM <steps 1..=64> <text>")?;
                continue;
            }
            let ids = vocab.encode(text, seq_len);
            let sig = signer.sign(&ids);
            let (req, rx) = Request::streaming(
                next_id.fetch_add(1, Ordering::SeqCst), ids, sig, steps,
                chunk_depth,
            );
            let t0 = std::time::Instant::now();
            if queue.try_push(sig, req).is_err() {
                rejected.fetch_add(1, Ordering::Relaxed);
                writeln!(out, "ERR overloaded")?;
                continue;
            }
            loop {
                match rx.recv_timeout(Duration::from_secs(60)) {
                    Ok(ch) if ch.last => {
                        writeln!(
                            out,
                            "DONE {} {} {:.2}",
                            ch.label,
                            ch.memo_hits,
                            t0.elapsed().as_secs_f64() * 1e3
                        )?;
                        break;
                    }
                    Ok(ch) => writeln!(
                        out,
                        "CH {} {} {}",
                        ch.step, ch.label, ch.memo_hits
                    )?,
                    Err(_) => {
                        writeln!(out, "ERR timeout")?;
                        break;
                    }
                }
            }
        } else if msg == "STATS" {
            // Aggregate the replica fleet into one report, then stamp on
            // the router-level affinity gauges (shared, not per-replica).
            let mut agg = EngineMetrics::new();
            for engine in engines.iter() {
                agg.absorb(&engine.lock().unwrap().metrics);
            }
            agg.rejected += rejected.load(Ordering::Relaxed);
            let router = queue.stats();
            agg.steals = router.steals;
            agg.bucket_resizes = router.resizes;
            agg.queue_depths = router.depths;
            writeln!(out, "STATS {}", agg.report())?;
        } else if msg == "QUIT" {
            writeln!(out, "BYE")?;
            return Ok(());
        } else {
            writeln!(out, "ERR unknown command")?;
        }
    }
}

/// Minimal blocking client for examples/tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), stream })
    }

    fn roundtrip(&mut self, msg: &str) -> Result<String> {
        writeln!(self.stream, "{msg}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim_end().to_string())
    }

    /// Returns (label, memo_hits, latency_ms).
    pub fn infer(&mut self, text: &str) -> Result<(i32, u32, f64)> {
        let line = self.roundtrip(&format!("INFER {text}"))?;
        let mut parts = line.split_whitespace();
        match parts.next() {
            Some("OK") => {
                let label = parts.next().unwrap_or("0").parse().unwrap_or(0);
                let hits = parts.next().unwrap_or("0").parse().unwrap_or(0);
                let ms = parts.next().unwrap_or("0").parse().unwrap_or(0.0);
                Ok((label, hits, ms))
            }
            _ => Err(crate::Error::serving(format!("server said: {line}"))),
        }
    }

    /// Stream `steps` iterations; returns one `(step, label, memo_hits)`
    /// per chunk, the last entry being the final (DONE) chunk with the
    /// step index `steps - 1`.
    pub fn infer_stream(&mut self, text: &str,
                        steps: usize) -> Result<Vec<(u32, i32, u32)>> {
        writeln!(self.stream, "STREAM {steps} {text}")?;
        let mut chunks = Vec::new();
        loop {
            let mut line = String::new();
            self.reader.read_line(&mut line)?;
            let line = line.trim_end();
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("CH") => {
                    let step = parts.next().unwrap_or("0").parse()
                        .unwrap_or(0);
                    let label = parts.next().unwrap_or("0").parse()
                        .unwrap_or(0);
                    let hits = parts.next().unwrap_or("0").parse()
                        .unwrap_or(0);
                    chunks.push((step, label, hits));
                }
                Some("DONE") => {
                    let label = parts.next().unwrap_or("0").parse()
                        .unwrap_or(0);
                    let hits = parts.next().unwrap_or("0").parse()
                        .unwrap_or(0);
                    chunks.push((steps.saturating_sub(1) as u32, label,
                                 hits));
                    return Ok(chunks);
                }
                _ => {
                    return Err(crate::Error::serving(format!(
                        "server said: {line}"
                    )))
                }
            }
        }
    }

    pub fn stats(&mut self) -> Result<String> {
        self.roundtrip("STATS")
    }

    pub fn quit(mut self) -> Result<()> {
        let _ = self.roundtrip("QUIT")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mode: SignatureMode, explicit: bool) -> ServingConfig {
        ServingConfig {
            signature_mode: mode,
            signature_explicit: explicit,
            ..ServingConfig::default()
        }
    }

    fn table() -> Tensor {
        Tensor::new(vec![16, 8], vec![0.1; 16 * 8]).unwrap()
    }

    #[test]
    fn prefix_mode_ignores_missing_table() {
        let s = build_signer(&cfg(SignatureMode::Prefix, true),
                             Err(Error::serving("no table")))
            .unwrap();
        assert_eq!(s.mode_name(), "prefix");
    }

    #[test]
    fn semantic_mode_with_table_builds_semantic_signer() {
        let t = table();
        for explicit in [false, true] {
            let s = build_signer(&cfg(SignatureMode::Semantic, explicit),
                                 Ok(&t))
                .unwrap();
            assert_eq!(s.mode_name(), "semantic");
        }
    }

    /// Satellite regression: `--signature-mode semantic` without an
    /// embedding table must fail startup, not silently degrade.
    #[test]
    fn explicit_semantic_without_table_is_a_startup_error() {
        let err = build_signer(&cfg(SignatureMode::Semantic, true),
                               Err(Error::serving("no table")))
            .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("semantic"), "{msg}");
        assert!(msg.contains("no table"),
                "the root cause must be surfaced: {msg}");
    }

    /// A semantic *config default* keeps the documented warn-and-fallback.
    #[test]
    fn default_semantic_without_table_falls_back_to_prefix() {
        let s = build_signer(&cfg(SignatureMode::Semantic, false),
                             Err(Error::serving("no table")))
            .unwrap();
        assert_eq!(s.mode_name(), "prefix");
    }
}
