//! Engine-level metrics: request latency, batch occupancy, throughput, and
//! the per-stage attention breakdown (paper Table 4 / Fig. 1).

use crate::util::stats::Summary;
use std::time::Instant;

/// Aggregated serving metrics. Single-writer (the batcher thread); readers
/// take snapshots through the engine's lock.
#[derive(Debug)]
pub struct EngineMetrics {
    pub started: Instant,
    pub requests: u64,
    pub batches: u64,
    pub rejected: u64,
    /// Serve-time APM admissions into the online attention database.
    pub admissions: u64,
    /// Online-database evictions forced by the capacity budget.
    pub evictions: u64,
    /// Live entries across the online database's layers (occupancy gauge).
    pub online_entries: u64,
    pub request_latency_ms: Summary,
    pub queue_wait_ms: Summary,
    pub batch_size: Summary,
    pub batch_compute_ms: Summary,
    /// Non-XLA coordinator time per batch (L3 overhead tracking).
    pub coordinator_ms: Summary,
}

impl Default for EngineMetrics {
    fn default() -> Self {
        EngineMetrics {
            started: Instant::now(),
            requests: 0,
            batches: 0,
            rejected: 0,
            admissions: 0,
            evictions: 0,
            online_entries: 0,
            request_latency_ms: Summary::new(),
            queue_wait_ms: Summary::new(),
            batch_size: Summary::new(),
            batch_compute_ms: Summary::new(),
            coordinator_ms: Summary::new(),
        }
    }
}

impl EngineMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Requests per second since start.
    pub fn throughput(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.requests as f64 / secs
        }
    }

    /// One-line human summary.
    pub fn report(&mut self) -> String {
        format!(
            "requests={} batches={} rejected={} rps={:.1} \
             lat(ms) p50={:.1} p99={:.1} mean_batch={:.1} compute_ms p50={:.1} \
             online(admit={} evict={} entries={})",
            self.requests,
            self.batches,
            self.rejected,
            self.throughput(),
            self.request_latency_ms.p50(),
            self.request_latency_ms.p99(),
            self.batch_size.mean(),
            self.batch_compute_ms.p50(),
            self.admissions,
            self.evictions,
            self.online_entries,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_contains_counts() {
        let mut m = EngineMetrics::new();
        m.requests = 7;
        m.request_latency_ms.record(4.0);
        let r = m.report();
        assert!(r.contains("requests=7"), "{r}");
    }
}
