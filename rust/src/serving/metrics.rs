//! Engine-level metrics: request latency, batch occupancy, throughput, and
//! the per-stage attention breakdown (paper Table 4 / Fig. 1).

use crate::util::stats::Summary;
use std::time::Instant;

/// Aggregated serving metrics. Single-writer (each replica's batcher
/// thread); readers take snapshots through the replica's lock and can
/// [`EngineMetrics::absorb`] several replicas into one fleet view.
#[derive(Debug, Clone)]
pub struct EngineMetrics {
    pub started: Instant,
    pub requests: u64,
    pub batches: u64,
    pub rejected: u64,
    /// Serve-time APM admissions into the online attention database.
    pub admissions: u64,
    /// Online-database evictions forced by the capacity budget.
    pub evictions: u64,
    /// Miss rows skipped by intra-batch dedup on the admission path.
    pub dedup_skips: u64,
    /// Miss rows *offered* to online admission — the denominator of the
    /// dedup yield (`dedup_skips / admit_offered`), the metric affinity
    /// routing exists to raise.
    pub admit_offered: u64,
    /// Requests taken from a non-home affinity bucket (work stealing).
    /// Router-level: zero on per-replica metrics, stamped onto the
    /// aggregated fleet view by the server's STATS path.
    pub steals: u64,
    /// Adaptive re-bucketing epochs completed by the router (bucket-space
    /// grows/shrinks). Router-level like `steals`.
    pub bucket_resizes: u64,
    /// Per-affinity-bucket queue depth at report time. Router-level like
    /// `steals`; empty on per-replica metrics.
    pub queue_depths: Vec<usize>,
    /// Live entries across the online database's layers (occupancy gauge).
    pub online_entries: u64,
    /// Snapshot publishes the online tier skipped outright because every
    /// row in an admitted batch deduplicated against the current snapshot
    /// (the steady-state cheap-write path). Tier-level gauge like
    /// `online_entries`: every replica reports the same shared tier, so
    /// aggregation takes the max.
    pub publish_skips: u64,
    /// Live entries across the cold spill tier's shards (0 without one).
    /// Tier-level gauge: aggregation takes the max.
    pub cold_entries: u64,
    /// Hot-snapshot misses served from the cold tier. Tier-level counter
    /// shared by every replica, so aggregation takes the max.
    pub cold_hits: u64,
    /// Cold hits re-admitted into the hot tier. Tier-level like
    /// `cold_hits`.
    pub promotions: u64,
    /// Hot clock victims demoted into the cold tier. Tier-level like
    /// `cold_hits`.
    pub demotions: u64,
    /// Resident bytes of the hot tier's payload arenas. Tier-level gauge.
    pub hot_resident_bytes: u64,
    /// Bytes of the cold tier's file-backed payload arenas (0 without
    /// one). Tier-level gauge.
    pub cold_resident_bytes: u64,
    /// Continuous-batching scheduler iterations run (0 on the legacy
    /// fixed path — the presence gate for the `cb(...)` report section).
    pub cb_steps: u64,
    /// Sequences admitted into the in-flight batch (fresh joins plus
    /// rejoins of previously parked sequences).
    pub cb_joins: u64,
    /// Chunks that hit a full per-client channel (backpressure events).
    pub cb_stalls: u64,
    /// Sequences that exhausted the stall budget and yielded their slot.
    pub cb_parks: u64,
    pub request_latency_ms: Summary,
    pub queue_wait_ms: Summary,
    pub batch_size: Summary,
    pub batch_compute_ms: Summary,
    /// Non-XLA coordinator time per batch (L3 overhead tracking).
    pub coordinator_ms: Summary,
}

impl Default for EngineMetrics {
    fn default() -> Self {
        EngineMetrics {
            started: Instant::now(),
            requests: 0,
            batches: 0,
            rejected: 0,
            admissions: 0,
            evictions: 0,
            dedup_skips: 0,
            admit_offered: 0,
            steals: 0,
            bucket_resizes: 0,
            queue_depths: Vec::new(),
            online_entries: 0,
            publish_skips: 0,
            cold_entries: 0,
            cold_hits: 0,
            promotions: 0,
            demotions: 0,
            hot_resident_bytes: 0,
            cold_resident_bytes: 0,
            cb_steps: 0,
            cb_joins: 0,
            cb_stalls: 0,
            cb_parks: 0,
            request_latency_ms: Summary::new(),
            queue_wait_ms: Summary::new(),
            batch_size: Summary::new(),
            batch_compute_ms: Summary::new(),
            coordinator_ms: Summary::new(),
        }
    }
}

impl EngineMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Requests per second since start.
    pub fn throughput(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.requests as f64 / secs
        }
    }

    /// Intra-batch dedup yield: dedup skips per miss row offered to
    /// admission. Higher means similar rows reached the admission path
    /// together — the observable benefit of affinity routing.
    pub fn dedup_yield(&self) -> f64 {
        if self.admit_offered == 0 {
            0.0
        } else {
            self.dedup_skips as f64 / self.admit_offered as f64
        }
    }

    /// One-line human summary.
    pub fn report(&mut self) -> String {
        let mut s = format!(
            "requests={} batches={} rejected={} rps={:.1} \
             lat(ms) p50={:.1} p99={:.1} mean_batch={:.1} compute_ms p50={:.1} \
             online(admit={} evict={} dedup={} offered={} yield={:.3} \
             entries={} pskip={})",
            self.requests,
            self.batches,
            self.rejected,
            self.throughput(),
            self.request_latency_ms.p50(),
            self.request_latency_ms.p99(),
            self.batch_size.mean(),
            self.batch_compute_ms.p50(),
            self.admissions,
            self.evictions,
            self.dedup_skips,
            self.admit_offered,
            self.dedup_yield(),
            self.online_entries,
            self.publish_skips,
        );
        if !self.queue_depths.is_empty() {
            let depths: Vec<String> =
                self.queue_depths.iter().map(|d| d.to_string()).collect();
            s.push_str(&format!(
                " affinity(buckets={} steals={} resizes={} depths=[{}])",
                self.queue_depths.len(),
                self.steals,
                self.bucket_resizes,
                depths.join(",")
            ));
        }
        // The cold section appears only when a spill tier is attached —
        // its arenas preallocate pages, so resident bytes are the
        // reliable "a cold tier exists" signal even before any demotion.
        if self.cold_resident_bytes > 0 || self.cold_entries > 0 {
            s.push_str(&format!(
                " cold(entries={} hits={} promote={} demote={} \
                 hot_resident={:.1}MiB cold_resident={:.1}MiB)",
                self.cold_entries,
                self.cold_hits,
                self.promotions,
                self.demotions,
                self.hot_resident_bytes as f64 / (1 << 20) as f64,
                self.cold_resident_bytes as f64 / (1 << 20) as f64,
            ));
        }
        // Continuous-batching section: present only when the iteration
        // scheduler actually ran (legacy-path reports stay byte-stable).
        if self.cb_steps > 0 {
            s.push_str(&format!(
                " cb(steps={} joins={} stalls={} parks={})",
                self.cb_steps, self.cb_joins, self.cb_stalls,
                self.cb_parks,
            ));
        }
        s
    }

    /// Fold another replica's metrics into this one: counters add,
    /// latency summaries merge, the start time takes the earliest (so
    /// fleet throughput divides by the true serving window), and the
    /// shared-tier occupancy gauge takes the max (every replica reports
    /// the same tier).
    pub fn absorb(&mut self, other: &EngineMetrics) {
        self.started = self.started.min(other.started);
        self.requests += other.requests;
        self.batches += other.batches;
        self.rejected += other.rejected;
        self.admissions += other.admissions;
        self.evictions += other.evictions;
        self.dedup_skips += other.dedup_skips;
        self.admit_offered += other.admit_offered;
        self.steals += other.steals;
        // Router-level epoch counter: both sides report the same router,
        // so take the max instead of double-counting.
        self.bucket_resizes = self.bucket_resizes.max(other.bucket_resizes);
        // Replicas share one router, so bucket depths are a router-level
        // gauge: keep whichever side carries them rather than summing.
        if self.queue_depths.is_empty() {
            self.queue_depths.clone_from(&other.queue_depths);
        }
        self.online_entries = self.online_entries.max(other.online_entries);
        self.publish_skips = self.publish_skips.max(other.publish_skips);
        // All cold-tier fields report one shared tier (gauges *and*
        // counters read the tier's own atomics), so max, never sum.
        self.cold_entries = self.cold_entries.max(other.cold_entries);
        self.cold_hits = self.cold_hits.max(other.cold_hits);
        self.promotions = self.promotions.max(other.promotions);
        self.demotions = self.demotions.max(other.demotions);
        self.hot_resident_bytes =
            self.hot_resident_bytes.max(other.hot_resident_bytes);
        self.cold_resident_bytes =
            self.cold_resident_bytes.max(other.cold_resident_bytes);
        // Per-replica scheduler counters: each batcher owns its own
        // scheduler, so these sum like requests/batches.
        self.cb_steps += other.cb_steps;
        self.cb_joins += other.cb_joins;
        self.cb_stalls += other.cb_stalls;
        self.cb_parks += other.cb_parks;
        self.request_latency_ms.merge(&other.request_latency_ms);
        self.queue_wait_ms.merge(&other.queue_wait_ms);
        self.batch_size.merge(&other.batch_size);
        self.batch_compute_ms.merge(&other.batch_compute_ms);
        self.coordinator_ms.merge(&other.coordinator_ms);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_contains_counts() {
        let mut m = EngineMetrics::new();
        m.requests = 7;
        m.request_latency_ms.record(4.0);
        let r = m.report();
        assert!(r.contains("requests=7"), "{r}");
    }

    #[test]
    fn dedup_yield_and_affinity_section() {
        let mut m = EngineMetrics::new();
        assert_eq!(m.dedup_yield(), 0.0, "no offers, no yield");
        m.admit_offered = 8;
        m.dedup_skips = 6;
        assert!((m.dedup_yield() - 0.75).abs() < 1e-12);
        let r = m.report();
        assert!(r.contains("offered=8"), "{r}");
        assert!(r.contains("yield=0.750"), "{r}");
        assert!(!r.contains("affinity("), "no router gauges, no section");
        m.steals = 3;
        m.bucket_resizes = 2;
        m.queue_depths = vec![2, 0, 1];
        let r = m.report();
        assert!(
            r.contains(
                "affinity(buckets=3 steals=3 resizes=2 depths=[2,0,1])"
            ),
            "{r}"
        );
    }

    #[test]
    fn cold_section_is_gated_and_absorbs_by_max() {
        let mut m = EngineMetrics::new();
        assert!(!m.report().contains("cold("),
                "no cold tier, no cold section");
        m.cold_entries = 12;
        m.cold_hits = 3;
        m.promotions = 2;
        m.demotions = 14;
        m.cold_resident_bytes = 2 << 20;
        m.hot_resident_bytes = 1 << 20;
        let r = m.report();
        assert!(r.contains("cold(entries=12 hits=3 promote=2 demote=14"),
                "{r}");
        let mut other = EngineMetrics::new();
        other.cold_entries = 10;
        other.cold_hits = 3;
        other.demotions = 20;
        m.absorb(&other);
        assert_eq!(m.cold_entries, 12, "shared gauge must not double");
        assert_eq!(m.cold_hits, 3, "shared counter must not double");
        assert_eq!(m.demotions, 20, "max carries the fresher reading");
    }

    #[test]
    fn cb_section_is_gated_and_absorbs_by_sum() {
        let mut m = EngineMetrics::new();
        assert!(!m.report().contains("cb("),
                "legacy path must not grow a cb section");
        m.cb_steps = 4;
        m.cb_joins = 6;
        m.cb_stalls = 2;
        m.cb_parks = 1;
        let r = m.report();
        assert!(r.contains("cb(steps=4 joins=6 stalls=2 parks=1)"), "{r}");
        let mut other = EngineMetrics::new();
        other.cb_steps = 3;
        other.cb_parks = 2;
        m.absorb(&other);
        assert_eq!(m.cb_steps, 7, "per-replica counters sum");
        assert_eq!(m.cb_parks, 3);
    }

    #[test]
    fn absorb_aggregates_replicas() {
        let mut a = EngineMetrics::new();
        a.requests = 3;
        a.dedup_skips = 1;
        a.admit_offered = 2;
        a.online_entries = 10;
        a.publish_skips = 5;
        a.request_latency_ms.record(1.0);
        let mut b = EngineMetrics::new();
        b.requests = 4;
        b.admit_offered = 3;
        b.online_entries = 10;
        b.publish_skips = 5;
        b.queue_depths = vec![1, 2];
        b.request_latency_ms.record(3.0);
        a.absorb(&b);
        assert_eq!(a.requests, 7);
        assert_eq!(a.dedup_skips, 1);
        assert_eq!(a.admit_offered, 5);
        assert_eq!(a.queue_depths, vec![1, 2],
                   "router gauge carries over, not summed");
        assert_eq!(a.online_entries, 10, "shared gauge must not double");
        assert_eq!(a.publish_skips, 5, "tier gauge must not double");
        assert_eq!(a.request_latency_ms.count(), 2);
    }
}
