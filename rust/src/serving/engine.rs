//! The inference engine: the memoized forward pass (paper Fig. 5).
//!
//! Per batch, per layer:
//! 1. the selective policy (Eq. 3) decides whether to attempt memoization;
//! 2. if attempting — embed the hidden states (§5.2), query the layer's
//!    index databases (the offline-built one and, when serve-time
//!    admission is on, the shared online `MemoTier`), and accept entries
//!    whose estimated similarity clears the level's threshold; online-tier
//!    payloads are fetched atomically against one frozen shard snapshot
//!    per batch (the tier's seqlock read path — no lock held);
//! 3. missing rows (if any) run `attn_scores` as a packed sub-batch; hit
//!    rows are fetched from the attention database (memory-mapped window
//!    or direct arena view);
//! 4. freshly computed miss APMs are admitted into the online tier
//!    (capacity-bounded, reuse-aware eviction, intra-batch dedup) when the
//!    Eq. 3 admission gate approves — this is how a cold or drifting
//!    workload warms from 0% to a steady-state hit rate;
//! 5. the combined APM batch feeds `attn_apply`.
//! Layers that skip memoization take the fused `layer_full` fast path.
//!
//! The online tier is an `Arc<MemoTier>`: several engine replicas (one
//! batcher thread each, see `serving::server`) can share it, so lookups
//! proceed in parallel across replicas with no global engine mutex — and,
//! since the tier's seqlock read path, no shard lock either — on the
//! lookup path; admissions by one replica become hits for all.

use std::sync::Arc;
use std::time::Instant;

use crate::config::{MemoConfig, MemoLevel};
use crate::memo::arena::ApmId;
use crate::memo::attdb::Lookup;
use crate::memo::builder::BuiltDb;
use crate::memo::gather::GatherWindow;
use crate::memo::index::HnswParams;
use crate::memo::policy::SelectivePolicy;
use crate::memo::stats::MemoStats;
use crate::memo::thresholds::Thresholds;
use crate::memo::tier::MemoTier;
use crate::model::ModelRunner;
use crate::serving::metrics::EngineMetrics;
use crate::tensor::tensor::IdTensor;
use crate::tensor::{ops, Tensor};
use crate::Result;

/// Engine construction options.
pub struct EngineOptions {
    pub memo: MemoConfig,
    pub seq_len: usize,
}

/// Result of one batched inference.
pub struct BatchResult {
    /// Task logits: `[n, C]` (encoders) or `[n, V]` next-token (gpt).
    pub logits: Tensor,
    /// Predicted label per sequence.
    pub labels: Vec<i32>,
    /// Memoized layers per sequence.
    pub memo_hits: Vec<u32>,
    /// Engine wall-clock for this batch (seconds).
    pub seconds: f64,
}

/// The memoizing inference engine for one model family.
///
/// SAFETY (Send): the engine owns `!Send` XLA literals transitively; each
/// replica is moved once into its batcher thread and only ever accessed
/// behind its own `Arc<Mutex<Engine>>`, so no two threads touch one
/// engine's XLA state concurrently. The only state replicas *share* is
/// the online `Arc<MemoTier>`, which is `Sync` by construction (per-layer
/// seqlock-published snapshots, writer-mutex-serialized mutations).
pub struct Engine {
    runner: ModelRunner,
    built: Option<Arc<BuiltDb>>,
    online: Option<Arc<MemoTier>>,
    policy: SelectivePolicy,
    threshold: f32,
    opts: MemoConfig,
    /// Memoization counters (hits, admissions, dedup skips, stage times).
    pub stats: MemoStats,
    /// Serving metrics (latency, batch occupancy, online-tier gauges).
    pub metrics: EngineMetrics,
    gather: Option<GatherWindow>,
    seq_len: usize,
}

// SAFETY: see the struct doc — single-owner moves plus `Mutex` sharing;
// no concurrent access to the wrapped XLA objects is possible.
unsafe impl Send for Engine {}

impl Engine {
    /// Build an engine. `built = None` serves the pure compute baseline —
    /// unless online admission is on, in which case the engine starts cold
    /// and warms its own private tier from live traffic.
    pub fn new(runner: ModelRunner, built: Option<Arc<BuiltDb>>,
               opts: EngineOptions) -> Result<Self> {
        let online = if opts.memo.online_admission
            && opts.memo.level != MemoLevel::Off
        {
            Some(Arc::new(MemoTier::new(
                runner.config(),
                opts.seq_len,
                HnswParams::default(),
                &opts.memo,
            )))
        } else {
            None
        };
        Self::build(runner, built, online, opts)
    }

    /// Build an engine replica over a *shared* online tier: N replicas
    /// constructed with clones of one `Arc<MemoTier>` serve one attention
    /// database — lookups run in parallel (lock-free snapshot reads), and
    /// entries admitted by any replica are hits for all of them.
    pub fn with_shared_tier(runner: ModelRunner, built: Option<Arc<BuiltDb>>,
                            tier: Arc<MemoTier>,
                            opts: EngineOptions) -> Result<Self> {
        // A mismatched tier (e.g. a warm snapshot saved at another seq_len)
        // would make every payload fetch copy the wrong entry size — but
        // only if the tier is actually used. `level = off` discards it, so
        // a baseline run over a foreign snapshot must not be rejected.
        let want = runner.config().apm_elems(opts.seq_len);
        let mismatch = tier.seq_len() != opts.seq_len
            || tier.apm_elems() != want
            || tier.embed_dim() != runner.config().embed_dim
            || tier.num_layers() != runner.config().layers;
        if opts.memo.level == MemoLevel::Off {
            if mismatch {
                log::warn!(
                    "shared tier shape mismatch ignored: memo level is off, \
                     the tier will not be used"
                );
            }
            return Self::build(runner, built, None, opts);
        }
        if mismatch {
            return Err(crate::Error::serving(format!(
                "shared tier shape (layers {}, seq {}, elems {}, dim {}) \
                 does not match engine (layers {}, seq {}, elems {want}, \
                 dim {})",
                tier.num_layers(),
                tier.seq_len(),
                tier.apm_elems(),
                tier.embed_dim(),
                runner.config().layers,
                opts.seq_len,
                runner.config().embed_dim,
            )));
        }
        Self::build(runner, built, Some(tier), opts)
    }

    fn build(runner: ModelRunner, built: Option<Arc<BuiltDb>>,
             online: Option<Arc<MemoTier>>,
             opts: EngineOptions) -> Result<Self> {
        let layers = runner.config().layers;
        let (policy, threshold) = match (&built, opts.memo.level) {
            (Some(b), level) => {
                let thr = opts
                    .memo
                    .threshold_override
                    .map(|t| t as f32)
                    .unwrap_or_else(|| b.thresholds.for_level(level));
                (b.policy(thr, opts.memo.selective), thr)
            }
            (None, level) if online.is_some() => {
                // Cold start: no offline profiles, default thresholds.
                let thr = opts
                    .memo
                    .threshold_override
                    .map(|t| t as f32)
                    .unwrap_or_else(|| {
                        Thresholds::calibrate(Vec::new()).for_level(level)
                    });
                (SelectivePolicy::always(layers), thr)
            }
            (None, _) => (SelectivePolicy::always(layers), f32::INFINITY),
        };
        let gather = match &built {
            Some(b) if opts.memo.mmap_gather
                && b.db.layer(0).arena().dense_mappable() =>
            {
                Some(GatherWindow::new(b.db.apm_elems(), 64)?)
            }
            _ => None,
        };
        Ok(Engine {
            stats: MemoStats::new(layers),
            metrics: EngineMetrics::new(),
            policy,
            threshold,
            opts: opts.memo,
            built,
            online,
            gather,
            runner,
            seq_len: opts.seq_len,
        })
    }

    pub fn runner(&self) -> &ModelRunner {
        &self.runner
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn threshold(&self) -> f32 {
        self.threshold
    }

    pub fn built(&self) -> Option<&BuiltDb> {
        self.built.as_deref()
    }

    /// The serve-time attention tier (possibly shared with other engine
    /// replicas), when online memoization is enabled.
    pub fn online(&self) -> Option<&Arc<MemoTier>> {
        self.online.as_ref()
    }

    /// Memoization active at all?
    pub fn memo_enabled(&self) -> bool {
        (self.built.is_some() || self.online.is_some())
            && self.opts.level != MemoLevel::Off
    }

    /// Whether the model family is causal — a step's argmax is a next
    /// token (appended by the scheduler for multi-step requests) rather
    /// than a class label.
    pub fn causal(&self) -> bool {
        self.runner.config().causal
    }

    /// Prefill half of the continuous-batching API: normalize a joining
    /// request's token ids to the engine's fixed sequence length (pad
    /// with `PAD`, truncate overflow) so the row can be packed into the
    /// in-flight batch tensor. O(seq_len) bookkeeping; all compute is
    /// charged per iteration by [`Engine::step_batch`].
    pub fn prefill(&self, ids: &mut Vec<i32>) {
        ids.resize(self.seq_len, crate::data::tokenizer::PAD);
    }

    /// Run one batch of token id rows — the single-shot (legacy) entry
    /// point, now an alias for one [`Engine::step_batch`] iteration.
    pub fn infer(&mut self, ids: &IdTensor) -> Result<BatchResult> {
        self.step_batch(ids)
    }

    /// Step half of the continuous-batching API: one full forward pass
    /// over the packed rows of an in-flight batch. Each row's per-layer
    /// memo lookups run against a fresh [`MemoTier`] shard snapshot taken
    /// this iteration (inside `run_layer`), so sequences that joined a
    /// step ago immediately see what the previous step admitted.
    pub fn step_batch(&mut self, ids: &IdTensor) -> Result<BatchResult> {
        let t0 = Instant::now();
        let n = ids.shape[0];
        let mut memo_hits = vec![0u32; n];
        let last_pos = last_nonpad_positions(ids);

        let mut h = self.runner.embed(ids)?;
        let layers = self.runner.config().layers;
        for li in 0..layers {
            h = self.run_layer(li, h, &mut memo_hits)?;
        }
        let logits = self.head_logits(&h, &last_pos)?;

        let labels = (0..n)
            .map(|i| ops::argmax(logits.row(i)) as i32)
            .collect();
        let seconds = t0.elapsed().as_secs_f64();
        self.metrics.batch_compute_ms.record(seconds * 1e3);
        self.metrics.batch_size.record(n as f64);
        self.metrics.batches += 1;
        self.metrics.requests += n as u64;
        if let Some(tier) = &self.online {
            self.metrics.online_entries = tier.total_entries() as u64;
            self.metrics.publish_skips = tier.publish_skips();
            self.metrics.hot_resident_bytes = tier.resident_bytes() as u64;
            if tier.cold().is_some() {
                self.metrics.cold_entries = tier.cold_entries() as u64;
                self.metrics.cold_hits = tier.cold_hits();
                self.metrics.promotions = tier.promotions();
                self.metrics.demotions = tier.demotions();
                self.metrics.cold_resident_bytes =
                    tier.cold_resident_bytes() as u64;
            }
        }
        Ok(BatchResult { logits, labels, memo_hits, seconds })
    }

    /// One layer with optional memoization.
    fn run_layer(&mut self, li: usize, h: Tensor,
                 memo_hits: &mut [u32]) -> Result<Tensor> {
        let n = h.shape()[0];
        let tokens = (n * self.seq_len) as u64;
        self.stats.layers[li].total += n as u64;

        // Cheap Arc clone so the shared tier can be used without borrowing
        // `self` across the mutable accounting below.
        let online = self.online.clone();
        let static_ready = self
            .built
            .as_ref()
            .map_or(false, |b| !b.db.layer(li).is_empty());
        let online_ready =
            online.as_ref().map_or(false, |t| !t.is_layer_empty(li));
        // Admission gate: is this layer allowed to invest in warming the
        // shared online tier this batch?
        let admission_open = online.as_ref().map_or(false, |t| {
            t.should_admit(
                self.policy.profiles().get(li),
                self.stats.layers[li].attempts,
                tokens,
            )
        });
        let attempt = self.memo_enabled()
            && (static_ready || online_ready || admission_open)
            && self.policy.attempt(li, tokens);
        if !attempt {
            self.stats.layers[li].skipped += n as u64;
            return self.runner.layer_full(&h, li);
        }

        // Upload the (padded) hidden state once; the three executables a
        // memoized layer touches share this device buffer (§Perf).
        let (hbuf, b) = self.runner.upload_padded(&h, "attn_apply")?;
        let seq = self.seq_len;
        let elems = self.runner.config().apm_elems(seq);

        // 1. Embed + search (the memoization overhead, Table 4 rows 1-2).
        let te = Instant::now();
        let feats_t = self.runner.mlp_embed_from(&hbuf, b, seq)?;
        let feats = crate::memo::embedder::Features::from_tensor(
            &feats_t.slice0(0, n)?)?;
        self.stats.stages.embedding_ms.record(te.elapsed().as_secs_f64() * 1e3);

        // Per-row two-tier search. One frozen shard snapshot
        // (`MemoTier::reader`) serves the whole batch: every row's search,
        // epoch-checked payload read and copy resolve against a single
        // publish epoch with no lock held — admissions by other replicas
        // publish new snapshots without ever blocking this batch, and a
        // fetched payload can never be a reused slot's stale bytes
        // (displaced slots are reclaimed only after this snapshot drops).
        let ts = Instant::now();
        let online_snap = online.as_ref().map(|t| t.reader(li));
        // The batch APM is allocated lazily: nothing writes into it until
        // either the first *online hit* (`lookup_fetch_lazy` zero-fills it
        // just before copying the payload in) or the post-early-return
        // assembly below — so total-miss and quorum-reverted layers never
        // pay the multi-MB allocation, with or without an online tier.
        let mut apm_data: Vec<f32> = Vec::new();
        let mut stat_hits: Vec<(usize, ApmId)> = Vec::new();
        let mut online_rows: Vec<usize> = Vec::new();
        let mut miss_rows: Vec<usize> = Vec::new();
        for i in 0..n {
            let q = feats.vector(i);
            let mut best_static: Option<Lookup> = None;
            if let Some(bdb) = self.built.as_ref() {
                if let Some(hit) =
                    bdb.db.layer(li).lookup(q, self.opts.ef_search)
                {
                    if hit.similarity >= self.threshold {
                        best_static = Some(hit);
                    }
                }
            }
            // The online tier wins the row when it at least matches the
            // static tier's similarity (ties prefer the warmer entry).
            let floor =
                best_static.map_or(self.threshold, |s| s.similarity);
            let online_hit = online_snap.as_ref().and_then(|s| {
                s.lookup_fetch_lazy(q, self.opts.ef_search, floor,
                                    &mut apm_data, n, i)
            });
            if online_hit.is_some() {
                online_rows.push(i);
                memo_hits[i] += 1;
            } else if let Some(s) = best_static {
                stat_hits.push((i, s.id));
                memo_hits[i] += 1;
            } else {
                miss_rows.push(i);
            }
        }
        let hit_count = stat_hits.len() + online_rows.len();
        // Release the snapshot before the admission below: holding it
        // would only delay the reclaim of slots that admission displaces.
        drop(online_snap);
        self.stats.stages.search_ms.record(ts.elapsed().as_secs_f64() * 1e3);
        self.stats.layers[li].attempts += n as u64;
        self.stats.layers[li].hits += hit_count as u64;

        // Admit this batch's misses? (Gate approved and there is material.)
        let admit_now = admission_open && !miss_rows.is_empty();

        if hit_count == 0 && !admit_now {
            // Total miss with nothing to warm: the fused path is strictly
            // cheaper.
            return self.runner.layer_full(&h, li);
        }

        // §Perf quorum: memoization only pays when the miss sub-batch is
        // *smaller after padding* than the full batch — otherwise computing
        // scores for the misses costs the same as computing everything, and
        // the fused path wins. Revert the optimistic hit accounting (the
        // attempt happened, but its counters must stay consistent:
        // attempts/hits go back, the rows are tallied as `reverted`).
        // Online reuse marks made during the fetch stand — the entries
        // *were* matched; keeping them hot is the honest clock signal.
        // While admitting, the split path runs regardless — computing the
        // scores is the warm-up investment the admission gate approved.
        if hit_count > 0 && !miss_rows.is_empty() && !admit_now {
            let padded_miss = self
                .runner
                .fit_batch("attn_scores", seq, miss_rows.len())
                .unwrap_or(miss_rows.len());
            if padded_miss >= b {
                self.stats.layers[li].attempts -= n as u64;
                self.stats.layers[li].hits -= hit_count as u64;
                self.stats.layers[li].reverted += n as u64;
                for &(r, _) in &stat_hits {
                    memo_hits[r] -= 1;
                }
                for &r in &online_rows {
                    memo_hits[r] -= 1;
                }
                return self.runner.layer_full(&h, li);
            }
        }

        // 2. Compute scores only for the misses (packed sub-batch).
        let miss_apm = if miss_rows.is_empty() {
            None
        } else {
            let tsc = Instant::now();
            let sub = gather_rows(&h, &miss_rows)?;
            let apm = self.runner.attn_scores(&sub, li)?;
            self.stats
                .stages
                .scores_ms
                .record(tsc.elapsed().as_secs_f64() * 1e3);
            Some(apm)
        };

        // 3. Assemble the batch APM: DB pages for static hits, computed
        //    rows for misses (Table 4 row 3: mapping time); online rows
        //    were already filled during the locked fetch above.
        let tm = Instant::now();
        if apm_data.is_empty() {
            apm_data = vec![0.0f32; n * elems];
        }
        if !stat_hits.is_empty() {
            // Mark reuse + fetch static-tier entries.
            let built = self.built.as_ref().unwrap();
            let layer_db = built.db.layer(li);
            for &(_, id) in &stat_hits {
                layer_db.mark_reused(id);
            }
            if let Some(win) = self.gather.as_mut() {
                let ids: Vec<ApmId> =
                    stat_hits.iter().map(|&(_, id)| id).collect();
                let mapped = win.map_batch(layer_db.arena(), &ids)?;
                for (k, &(row, _)) in stat_hits.iter().enumerate() {
                    put_row(&mut apm_data, elems, row, mapped, k);
                }
            } else {
                for &(row, id) in &stat_hits {
                    put_row(&mut apm_data, elems, row,
                            layer_db.arena().get(id)?, 0);
                }
            }
        }
        if let Some(m) = &miss_apm {
            for (k, &row) in miss_rows.iter().enumerate() {
                put_row(&mut apm_data, elems, row, m.data(), k);
            }
        }
        let cfg = self.runner.config();
        let apm = Tensor::new(
            vec![n, cfg.heads, self.seq_len, self.seq_len],
            apm_data,
        )?;
        self.stats.stages.mapping_ms.record(tm.elapsed().as_secs_f64() * 1e3);

        // 3b. Admission — after assembly, so this batch's gathered
        // payloads are complete before any eviction churn. One write lock
        // per layer shard for the whole batch; near-identical rows are
        // deduplicated inside `admit_batch`.
        if admit_now {
            if let (Some(tier), Some(m)) = (online.as_ref(), miss_apm.as_ref())
            {
                let rows: Vec<(&[f32], &[f32])> = miss_rows
                    .iter()
                    .enumerate()
                    .map(|(k, &row)| {
                        (feats.vector(row),
                         &m.data()[k * elems..(k + 1) * elems])
                    })
                    .collect();
                let out = tier.admit_batch(li, &rows, self.threshold,
                                           self.opts.ef_search)?;
                self.stats.layers[li].admitted += out.admitted;
                self.stats.layers[li].evicted += out.evicted;
                self.stats.layers[li].deduped += out.deduped;
                self.stats.layers[li].demoted += out.demoted;
                self.metrics.admit_offered += rows.len() as u64;
                self.metrics.admissions += out.admitted;
                self.metrics.evictions += out.evicted;
                self.metrics.dedup_skips += out.deduped;
            }
        }

        // 4. Remainder of the layer (reuses the shared hidden buffer).
        let ta = Instant::now();
        let out = self.runner.attn_apply_from(&hbuf, &apm, b, seq, li)?;
        let out = if out.shape()[0] == n { out } else { out.slice0(0, n)? };
        self.stats.stages.apply_ms.record(ta.elapsed().as_secs_f64() * 1e3);
        Ok(out)
    }

    /// Task logits: classifier as-is; for gpt, next-token logits at each
    /// sequence's last non-pad position (reading a fixed `L-1` would
    /// condition padded rows' predictions on PAD tokens).
    fn head_logits(&self, h: &Tensor, last_pos: &[usize]) -> Result<Tensor> {
        let out = self.runner.head(h)?;
        if !self.runner.config().causal {
            return Ok(out);
        }
        take_positions(&out, last_pos)
    }

    /// Baseline (fused, never memoized) for comparisons.
    pub fn infer_baseline(&mut self, ids: &IdTensor) -> Result<BatchResult> {
        let t0 = Instant::now();
        let n = ids.shape[0];
        let last_pos = last_nonpad_positions(ids);
        let mut h = self.runner.embed(ids)?;
        for li in 0..self.runner.config().layers {
            h = self.runner.layer_full(&h, li)?;
        }
        let logits = self.head_logits(&h, &last_pos)?;
        let labels = (0..n)
            .map(|i| ops::argmax(logits.row(i)) as i32)
            .collect();
        Ok(BatchResult {
            logits,
            labels,
            memo_hits: vec![0; n],
            seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

/// Copy selected rows of a `[n, …]` tensor into a packed `[k, …]` tensor.
fn gather_rows(t: &Tensor, rows: &[usize]) -> Result<Tensor> {
    let stride: usize = t.shape()[1..].iter().product();
    let mut data = Vec::with_capacity(rows.len() * stride);
    for &r in rows {
        data.extend_from_slice(&t.data()[r * stride..(r + 1) * stride]);
    }
    let mut shape = t.shape().to_vec();
    shape[0] = rows.len();
    Tensor::new(shape, data)
}

/// Copy `src`'s `k`-th row of `elems` values into `dst`'s row `row` — the
/// one primitive the APM assembly uses for every source (arena view,
/// mapped window, computed scores).
fn put_row(dst: &mut [f32], elems: usize, row: usize, src: &[f32], k: usize) {
    dst[row * elems..(row + 1) * elems]
        .copy_from_slice(&src[k * elems..(k + 1) * elems]);
}

/// Gather `[n, V]` rows at per-sequence positions from `[n, L, V]` logits.
fn take_positions(out: &Tensor, pos: &[usize]) -> Result<Tensor> {
    let (n, l, v) = (out.shape()[0], out.shape()[1], out.shape()[2]);
    let mut data = Vec::with_capacity(n * v);
    for i in 0..n {
        let p = pos.get(i).copied().unwrap_or(l - 1).min(l - 1);
        let base = i * l * v + p * v;
        data.extend_from_slice(&out.data()[base..base + v]);
    }
    Tensor::new(vec![n, v], data)
}

/// Per-row index of the last non-PAD token of a `[n, L]` id batch (0 for
/// an all-pad row).
pub fn last_nonpad_positions(ids: &IdTensor) -> Vec<usize> {
    let (n, l) = (ids.shape[0], ids.shape[1]);
    (0..n)
        .map(|i| {
            ids.data[i * l..(i + 1) * l]
                .iter()
                .rposition(|&t| t != crate::data::tokenizer::PAD)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memo::arena::ApmArena;

    #[test]
    fn gather_rows_packs() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let g = gather_rows(&t, &[2, 0]).unwrap();
        assert_eq!(g.shape(), &[2, 2]);
        assert_eq!(g.data(), &[5., 6., 1., 2.]);
    }

    #[test]
    fn last_nonpad_positions_respects_padding() {
        // Rows: fully packed / padded tail / all-pad.
        let ids = IdTensor::new(
            vec![3, 4],
            vec![1, 5, 6, 2, /**/ 1, 5, 2, 0, /**/ 0, 0, 0, 0],
        )
        .unwrap();
        assert_eq!(last_nonpad_positions(&ids), vec![3, 2, 0]);
    }

    #[test]
    fn take_positions_reads_per_row_offsets() {
        // [2, 3, 2] logits: row 0 position 1, row 1 position 2.
        let out = Tensor::new(
            vec![2, 3, 2],
            (0..12).map(|x| x as f32).collect(),
        )
        .unwrap();
        let t = take_positions(&out, &[1, 2]).unwrap();
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.data(), &[2.0, 3.0, 10.0, 11.0]);
        // Out-of-range positions clamp to L-1 instead of panicking.
        let t = take_positions(&out, &[9, 0]).unwrap();
        assert_eq!(t.data(), &[4.0, 5.0, 6.0, 7.0]);
    }

    /// The run_layer assembly invariant (regression for the mixed-batch
    /// path): hit rows must be byte-for-byte the arena payloads, miss rows
    /// byte-for-byte the freshly computed scores.
    #[test]
    fn apm_assembly_mixes_arena_and_computed_rows() {
        let elems = 16usize;
        let n = 4usize;
        let mut arena = ApmArena::new(elems).unwrap();
        let hit_a: Vec<f32> = (0..elems).map(|j| j as f32 + 0.25).collect();
        let hit_b: Vec<f32> = (0..elems).map(|j| -(j as f32) - 0.5).collect();
        let ia = arena.push(&hit_a).unwrap();
        let ib = arena.push(&hit_b).unwrap();

        // Rows 1 and 3 hit (ids b, a); rows 0 and 2 miss.
        let hit_rows = [(1usize, ib), (3usize, ia)];
        let miss_rows = [0usize, 2];
        let miss_apm: Vec<f32> =
            (0..2 * elems).map(|j| 1000.0 + j as f32).collect();

        let mut apm_data = vec![0.0f32; n * elems];
        for &(row, id) in &hit_rows {
            put_row(&mut apm_data, elems, row, arena.get(id).unwrap(), 0);
        }
        for (k, &row) in miss_rows.iter().enumerate() {
            put_row(&mut apm_data, elems, row, &miss_apm, k);
        }

        assert_eq!(&apm_data[elems..2 * elems], &hit_b[..]);
        assert_eq!(&apm_data[3 * elems..4 * elems], &hit_a[..]);
        assert_eq!(&apm_data[..elems], &miss_apm[..elems]);
        assert_eq!(&apm_data[2 * elems..3 * elems], &miss_apm[elems..]);
    }
}
