//! The inference engine: the memoized forward pass (paper Fig. 5).
//!
//! Per batch, per layer:
//! 1. the selective policy (Eq. 3) decides whether to attempt memoization;
//! 2. if attempting — embed the hidden states (§5.2), query the layer's
//!    index database, and accept entries whose estimated similarity clears
//!    the level's threshold;
//! 3. missing rows (if any) run `attn_scores` as a packed sub-batch; hit
//!    rows are fetched from the attention database (memory-mapped window
//!    or direct arena view);
//! 4. the combined APM batch feeds `attn_apply`.
//! Layers that skip memoization take the fused `layer_full` fast path.

use std::sync::Arc;
use std::time::Instant;

use crate::config::{MemoConfig, MemoLevel};
use crate::memo::builder::BuiltDb;
use crate::memo::gather::GatherWindow;
use crate::memo::policy::SelectivePolicy;
use crate::memo::stats::MemoStats;
use crate::model::ModelRunner;
use crate::serving::metrics::EngineMetrics;
use crate::tensor::tensor::IdTensor;
use crate::tensor::{ops, Tensor};
use crate::Result;

/// Engine construction options.
pub struct EngineOptions {
    pub memo: MemoConfig,
    pub seq_len: usize,
}

/// Result of one batched inference.
pub struct BatchResult {
    /// Task logits: `[n, C]` (encoders) or `[n, V]` next-token (gpt).
    pub logits: Tensor,
    /// Predicted label per sequence.
    pub labels: Vec<i32>,
    /// Memoized layers per sequence.
    pub memo_hits: Vec<u32>,
    /// Engine wall-clock for this batch (seconds).
    pub seconds: f64,
}

/// The memoizing inference engine for one model family.
///
/// SAFETY (Send): the engine owns `!Send` XLA literals transitively; it is
/// moved once into the batcher thread and only ever accessed behind
/// `Arc<Mutex<Engine>>`, so no two threads touch XLA state concurrently.
pub struct Engine {
    runner: ModelRunner,
    built: Option<Arc<BuiltDb>>,
    policy: SelectivePolicy,
    threshold: f32,
    opts: MemoConfig,
    pub stats: MemoStats,
    pub metrics: EngineMetrics,
    gather: Option<GatherWindow>,
    seq_len: usize,
}

// SAFETY: see the struct doc — single-owner moves plus `Mutex` sharing;
// no concurrent access to the wrapped XLA objects is possible.
unsafe impl Send for Engine {}

impl Engine {
    /// Build an engine. `built = None` serves the pure compute baseline.
    pub fn new(runner: ModelRunner, built: Option<Arc<BuiltDb>>,
               opts: EngineOptions) -> Result<Self> {
        let layers = runner.config().layers;
        let (policy, threshold) = match (&built, opts.memo.level) {
            (Some(b), level) => {
                let thr = opts
                    .memo
                    .threshold_override
                    .map(|t| t as f32)
                    .unwrap_or_else(|| b.thresholds.for_level(level));
                (b.policy(thr, opts.memo.selective), thr)
            }
            (None, _) => (SelectivePolicy::always(layers), f32::INFINITY),
        };
        let gather = match &built {
            Some(b) if opts.memo.mmap_gather
                && b.db.layer(0).arena().dense_mappable() =>
            {
                Some(GatherWindow::new(b.db.apm_elems(), 64)?)
            }
            _ => None,
        };
        Ok(Engine {
            stats: MemoStats::new(layers),
            metrics: EngineMetrics::new(),
            policy,
            threshold,
            opts: opts.memo,
            built,
            gather,
            runner,
            seq_len: opts.seq_len,
        })
    }

    pub fn runner(&self) -> &ModelRunner {
        &self.runner
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn threshold(&self) -> f32 {
        self.threshold
    }

    pub fn built(&self) -> Option<&BuiltDb> {
        self.built.as_deref()
    }

    /// Memoization active at all?
    pub fn memo_enabled(&self) -> bool {
        self.built.is_some() && self.opts.level != MemoLevel::Off
    }

    /// Run one batch of token id rows.
    pub fn infer(&mut self, ids: &IdTensor) -> Result<BatchResult> {
        let t0 = Instant::now();
        let n = ids.shape[0];
        let mut memo_hits = vec![0u32; n];

        let mut h = self.runner.embed(ids)?;
        let layers = self.runner.config().layers;
        for li in 0..layers {
            h = self.run_layer(li, h, &mut memo_hits)?;
        }
        let logits = self.head_logits(&h)?;

        let labels = (0..n)
            .map(|i| ops::argmax(logits.row(i)) as i32)
            .collect();
        let seconds = t0.elapsed().as_secs_f64();
        self.metrics.batch_compute_ms.record(seconds * 1e3);
        self.metrics.batch_size.record(n as f64);
        self.metrics.batches += 1;
        self.metrics.requests += n as u64;
        Ok(BatchResult { logits, labels, memo_hits, seconds })
    }

    /// One layer with optional memoization.
    fn run_layer(&mut self, li: usize, h: Tensor,
                 memo_hits: &mut [u32]) -> Result<Tensor> {
        let n = h.shape()[0];
        let tokens = (n * self.seq_len) as u64;
        self.stats.layers[li].total += n as u64;

        let attempt = self.memo_enabled()
            && self.built.as_ref().map_or(false, |b| !b.db.layer(li).is_empty())
            && self.policy.attempt(li, tokens);
        if !attempt {
            self.stats.layers[li].skipped += n as u64;
            return self.runner.layer_full(&h, li);
        }

        // Upload the (padded) hidden state once; the three executables a
        // memoized layer touches share this device buffer (§Perf).
        let (hbuf, b) = self.runner.upload_padded(&h, "attn_apply")?;
        let seq = self.seq_len;

        // 1. Embed + search (the memoization overhead, Table 4 rows 1-2).
        let te = Instant::now();
        let feats_t = self.runner.mlp_embed_from(&hbuf, b, seq)?;
        let feats = crate::memo::embedder::Features::from_tensor(
            &feats_t.slice0(0, n)?)?;
        self.stats.stages.embedding_ms.record(te.elapsed().as_secs_f64() * 1e3);

        let ts = Instant::now();
        let built = self.built.as_ref().unwrap();
        let mut hit_ids = Vec::new();
        let mut hit_rows = Vec::new();
        let mut miss_rows = Vec::new();
        for i in 0..n {
            match built.db.layer(li).lookup(feats.vector(i), self.opts.ef_search)
            {
                Some(hit) if hit.similarity >= self.threshold => {
                    hit_ids.push(hit.id);
                    hit_rows.push(i);
                }
                _ => miss_rows.push(i),
            }
        }
        self.stats.stages.search_ms.record(ts.elapsed().as_secs_f64() * 1e3);
        self.stats.layers[li].attempts += n as u64;
        self.stats.layers[li].hits += hit_rows.len() as u64;
        for &r in &hit_rows {
            memo_hits[r] += 1;
        }

        if hit_rows.is_empty() {
            // Total miss: the fused path is strictly cheaper.
            return self.runner.layer_full(&h, li);
        }

        // §Perf quorum: memoization only pays when the miss sub-batch is
        // *smaller after padding* than the full batch — otherwise computing
        // scores for the misses costs the same as computing everything, and
        // the fused path wins. Revert the optimistic hit accounting.
        if !miss_rows.is_empty() {
            let padded_miss = self
                .runner
                .fit_batch("attn_scores", seq, miss_rows.len())
                .unwrap_or(b);
            if padded_miss >= b {
                self.stats.layers[li].hits -= hit_rows.len() as u64;
                for &r in &hit_rows {
                    memo_hits[r] -= 1;
                }
                return self.runner.layer_full(&h, li);
            }
        }

        // 2. Compute scores only for the misses (packed sub-batch).
        let miss_apm = if miss_rows.is_empty() {
            None
        } else {
            let tsc = Instant::now();
            let sub = gather_rows(&h, &miss_rows)?;
            let apm = self.runner.attn_scores(&sub, li)?;
            self.stats
                .stages
                .scores_ms
                .record(tsc.elapsed().as_secs_f64() * 1e3);
            Some(apm)
        };

        // 3. Assemble the batch APM: DB pages for hits, computed rows for
        //    misses (Table 4 row 3: mapping time).
        let tm = Instant::now();
        let elems = built.db.apm_elems();
        let mut apm_data = vec![0.0f32; n * elems];
        {
            // Mark reuse + fetch hit entries.
            let built = self.built.as_ref().unwrap();
            let layer_db = built.db.layer(li);
            for &id in &hit_ids {
                layer_db.mark_reused(id);
            }
            if let Some(win) = self.gather.as_mut() {
                let mapped = win.map_batch(layer_db.arena(), &hit_ids)?;
                for (k, &row) in hit_rows.iter().enumerate() {
                    apm_data[row * elems..(row + 1) * elems]
                        .copy_from_slice(&mapped[k * elems..(k + 1) * elems]);
                }
            } else {
                for (&row, &id) in hit_rows.iter().zip(&hit_ids) {
                    apm_data[row * elems..(row + 1) * elems]
                        .copy_from_slice(layer_db.arena().get(id)?);
                }
            }
        }
        if let Some(m) = &miss_apm {
            for (k, &row) in miss_rows.iter().enumerate() {
                apm_data[row * elems..(row + 1) * elems]
                    .copy_from_slice(&m.data()[k * elems..(k + 1) * elems]);
            }
        }
        let cfg = self.runner.config();
        let apm = Tensor::new(
            vec![n, cfg.heads, self.seq_len, self.seq_len],
            apm_data,
        )?;
        self.stats.stages.mapping_ms.record(tm.elapsed().as_secs_f64() * 1e3);

        // 4. Remainder of the layer (reuses the shared hidden buffer).
        let ta = Instant::now();
        let out = self.runner.attn_apply_from(&hbuf, &apm, b, seq, li)?;
        let out = if out.shape()[0] == n { out } else { out.slice0(0, n)? };
        self.stats.stages.apply_ms.record(ta.elapsed().as_secs_f64() * 1e3);
        Ok(out)
    }

    /// Task logits: classifier as-is; for gpt, next-token logits at each
    /// sequence's last non-pad position.
    fn head_logits(&self, h: &Tensor) -> Result<Tensor> {
        let out = self.runner.head(h)?;
        if !self.runner.config().causal {
            return Ok(out);
        }
        // [n, L, V] → [n, V] at the final position (ids aren't visible here;
        // position L-1 is used — serving sequences are fully packed).
        let (n, l, v) = (out.shape()[0], out.shape()[1], out.shape()[2]);
        let mut data = Vec::with_capacity(n * v);
        for i in 0..n {
            let base = i * l * v + (l - 1) * v;
            data.extend_from_slice(&out.data()[base..base + v]);
        }
        Tensor::new(vec![n, v], data)
    }

    /// Baseline (fused, never memoized) for comparisons.
    pub fn infer_baseline(&mut self, ids: &IdTensor) -> Result<BatchResult> {
        let t0 = Instant::now();
        let n = ids.shape[0];
        let mut h = self.runner.embed(ids)?;
        for li in 0..self.runner.config().layers {
            h = self.runner.layer_full(&h, li)?;
        }
        let logits = self.head_logits(&h)?;
        let labels = (0..n)
            .map(|i| ops::argmax(logits.row(i)) as i32)
            .collect();
        Ok(BatchResult {
            logits,
            labels,
            memo_hits: vec![0; n],
            seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

/// Copy selected rows of a `[n, …]` tensor into a packed `[k, …]` tensor.
fn gather_rows(t: &Tensor, rows: &[usize]) -> Result<Tensor> {
    let stride: usize = t.shape()[1..].iter().product();
    let mut data = Vec::with_capacity(rows.len() * stride);
    for &r in rows {
        data.extend_from_slice(&t.data()[r * stride..(r + 1) * stride]);
    }
    let mut shape = t.shape().to_vec();
    shape[0] = rows.len();
    Tensor::new(shape, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_rows_packs() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let g = gather_rows(&t, &[2, 0]).unwrap();
        assert_eq!(g.shape(), &[2, 2]);
        assert_eq!(g.data(), &[5., 6., 1., 2.]);
    }
}
