//! Bounded MPMC queue with blocking pop and timeout — the admission-control
//! point of the serving path (backpressure beyond `depth`). Producers are
//! the connection handlers; consumers are the per-replica batcher threads
//! (every operation runs under one mutex, so any number of each is safe).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::{Error, Result};

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded queue shared between connection handlers and the batcher.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    depth: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(depth: usize) -> Self {
        BoundedQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            depth: depth.max(1),
        }
    }

    /// Non-blocking push; `Err` when full or closed (caller sheds load).
    pub fn try_push(&self, item: T) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(Error::serving("queue closed"));
        }
        if g.items.len() >= self.depth {
            return Err(Error::serving("queue full"));
        }
        g.items.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push (waits for space); `Err` when closed.
    pub fn push(&self, item: T) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(Error::serving("queue closed"));
            }
            if g.items.len() < self.depth {
                g.items.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Pop one item, waiting up to `timeout`; `None` on timeout or when
    /// closed-and-drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<T> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, res) =
                self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
            if res.timed_out() && g.items.is_empty() {
                return None;
            }
        }
    }

    /// Drain up to `max` items without blocking (after a first blocking pop,
    /// the batcher uses this to fill the rest of a batch).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let n = max.min(g.items.len());
        let out = g.items.drain(..n).collect();
        if n > 0 {
            self.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue; producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        let got = q.drain_up_to(10);
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn backpressure_when_full() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert!(q.try_push(3).is_err());
        q.drain_up_to(1);
        q.try_push(3).unwrap();
    }

    #[test]
    fn pop_timeout_expires() {
        let q: BoundedQueue<i32> = BoundedQueue::new(2);
        let t0 = Instant::now();
        assert!(q.pop_timeout(Duration::from_millis(30)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn close_unblocks_consumer() {
        let q: Arc<BoundedQueue<i32>> = Arc::new(BoundedQueue::new(2));
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            q2.pop_timeout(Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
        assert!(q.try_push(1).is_err());
    }

    #[test]
    fn cross_thread_transfer() {
        let q: Arc<BoundedQueue<usize>> = Arc::new(BoundedQueue::new(4));
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                q2.push(i).unwrap();
            }
        });
        let mut got = Vec::new();
        while got.len() < 100 {
            if let Some(x) = q.pop_timeout(Duration::from_secs(1)) {
                got.push(x);
            }
        }
        producer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }
}
