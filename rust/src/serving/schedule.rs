//! Iteration-level (continuous) batching: the Orca-style scheduler that
//! replaces one-shot `form_batch → infer → reply` with a persistent
//! in-flight batch that sequences join and leave at every step boundary.
//!
//! The pieces:
//!
//! - [`StepEngine`]: the `prefill`/`step` half of the old `Engine::infer`
//!   contract, as a trait so the scheduler is hermetically testable (the
//!   real [`Engine`] implements it through its `Arc<Mutex<_>>` handle;
//!   tests and benches substitute synthetic engines).
//! - [`InFlightBatch`]: fixed `max_inflight` slots plus a free-list. A
//!   slot holds one sequence's state (padded ids, step progress, pending
//!   chunk) for as many iterations as it needs.
//! - [`ContinuousScheduler`]: the per-replica iteration loop body. Each
//!   [`ContinuousScheduler::poll`] retries stalled consumers, admits
//!   joins from the [`AffinityRouter`] (preferring the in-flight batch's
//!   dominant affinity bucket so intra-batch dedup yield survives the
//!   refactor), runs exactly one engine step over the active rows, and
//!   streams one [`ResponseChunk`] per row.
//!
//! Backpressure is per-client: a chunk that doesn't fit its request's
//! bounded channel stalls only that slot (the row sits out subsequent
//! steps), and after a configurable stall budget the sequence is *parked*
//! — it yields its slot to queued work and rejoins once the consumer
//! drains. A slow client therefore costs exactly one slot for the stall
//! budget, never the whole batch; the legacy fixed path
//! ([`run_fixed_batch`]) keeps the old queue-global behaviour for A/B.
//!
//! One "step" here is one full forward pass of the packed rows (this
//! engine keeps no KV cache, so there is no incremental-decode shortcut);
//! multi-step requests on causal families append each step's argmax token
//! at the first pad position before the next iteration.

use std::sync::mpsc::TrySendError;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::serving::affinity::{bucket_of, AffinityRouter};
use crate::serving::engine::{BatchResult, Engine};
use crate::serving::request::{Request, RequestId, ResponseChunk};
use crate::tensor::tensor::IdTensor;
use crate::Result;

/// How long to block for a first join when the batch is empty but parked
/// sequences still need send retries (they must not starve behind a long
/// idle wait).
const PARKED_POLL: Duration = Duration::from_millis(1);

/// Extra patience past the stall budget before a stuck consumer is
/// dropped at shutdown (a closed queue must drain even when one client
/// never reads its chunks).
const SHUTDOWN_GRACE: Duration = Duration::from_millis(250);

/// The `prefill`/`step` engine contract the scheduler drives. One `step`
/// is one forward pass over the packed rows of the in-flight batch; memo
/// shard snapshots are (re)taken inside the step, so rows see what the
/// previous iteration admitted.
pub trait StepEngine {
    /// Fixed sequence length every packed row must match.
    fn seq_len(&self) -> usize;

    /// Whether a step's argmax is a next token to append (causal
    /// families) rather than a class label.
    fn causal(&self) -> bool {
        false
    }

    /// Prefill: normalize a joining request's token ids to `seq_len`
    /// (pad, truncate) so the row packs into the batch tensor.
    fn prefill(&self, ids: &mut Vec<i32>) {
        ids.resize(self.seq_len(), crate::data::tokenizer::PAD);
    }

    /// Run one iteration over the packed rows; one result row per input
    /// row, in order.
    fn step(&mut self, ids: &IdTensor) -> Result<BatchResult>;
}

/// The real engine behind its replica handle. The mutex is held for
/// exactly one forward pass per step — chunk sends and latency recording
/// all happen outside it.
impl StepEngine for Arc<Mutex<Engine>> {
    fn seq_len(&self) -> usize {
        self.lock().unwrap().seq_len()
    }

    fn causal(&self) -> bool {
        self.lock().unwrap().causal()
    }

    fn prefill(&self, ids: &mut Vec<i32>) {
        self.lock().unwrap().prefill(ids);
    }

    fn step(&mut self, ids: &IdTensor) -> Result<BatchResult> {
        self.lock().unwrap().step_batch(ids)
    }
}

/// Per-sequence state while it rides the in-flight batch (or sits parked
/// waiting for its consumer to drain).
struct SeqState {
    req: Request,
    /// First inclusion in a step: queue wait ends here.
    joined: Instant,
    steps_done: u32,
    /// Cumulative memoized-layer count across steps.
    memo_hits: u32,
    /// A produced chunk the consumer's channel couldn't take. While set,
    /// the sequence sits out engine steps (its own backpressure).
    pending: Option<ResponseChunk>,
    /// When the current stall began (cleared on every delivered chunk).
    stalled_since: Option<Instant>,
}

impl SeqState {
    fn new(req: Request) -> Self {
        SeqState {
            req,
            joined: Instant::now(),
            steps_done: 0,
            memo_hits: 0,
            pending: None,
            stalled_since: None,
        }
    }

    /// Steps still owed after the ones already done.
    fn remaining(&self) -> bool {
        (self.steps_done as usize) < self.req.max_steps
    }

    fn record(&self) -> FinishedSeq {
        FinishedSeq {
            id: self.req.id,
            request_ms: self.req.arrived.elapsed().as_secs_f64() * 1e3,
            queue_ms: self
                .joined
                .duration_since(self.req.arrived)
                .as_secs_f64()
                * 1e3,
        }
    }
}

/// Chunk for the step just completed (`steps_done` already incremented).
fn make_chunk(seq: &SeqState, logits: &[f32], label: i32,
              seconds: f64) -> ResponseChunk {
    ResponseChunk {
        id: seq.req.id,
        step: seq.steps_done - 1,
        last: !seq.remaining(),
        logits: logits.to_vec(),
        label,
        memo_hits: seq.memo_hits,
        queue_seconds: seq
            .joined
            .duration_since(seq.req.arrived)
            .as_secs_f64(),
        compute_seconds: seconds,
    }
}

/// Append a generated token at the first pad position (no-op when the
/// sequence is already at capacity).
fn advance_causal(ids: &mut [i32], token: i32) {
    if let Some(p) =
        ids.iter().position(|&t| t == crate::data::tokenizer::PAD)
    {
        ids[p] = token;
    }
}

/// The persistent batch: `max_inflight` slots and a free-list. Sequences
/// occupy a slot from join to final chunk (or until parked).
pub struct InFlightBatch {
    slots: Vec<Option<SeqState>>,
    free: Vec<usize>,
}

impl InFlightBatch {
    /// Batch with `capacity` slots (clamped to at least 1).
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        InFlightBatch {
            slots: (0..capacity).map(|_| None).collect(),
            free: (0..capacity).rev().collect(),
        }
    }

    /// Total slots.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Occupied slots.
    pub fn len(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Whether no sequence is in flight.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn free_count(&self) -> usize {
        self.free.len()
    }

    fn insert(&mut self, seq: SeqState) -> Option<usize> {
        let idx = self.free.pop()?;
        self.slots[idx] = Some(seq);
        Some(idx)
    }

    /// Vacate `idx`, returning its occupant (if any) and recycling the
    /// slot through the free-list.
    fn release(&mut self, idx: usize) -> Option<SeqState> {
        let seq = self.slots[idx].take();
        if seq.is_some() {
            self.free.push(idx);
        }
        seq
    }

    /// The affinity bucket most of the in-flight sequences map to under
    /// `buckets` — joins prefer it so batches stay bucket-homogeneous
    /// (what makes intra-batch dedup pay) across join/leave churn.
    fn dominant_bucket(&self, buckets: usize) -> Option<usize> {
        let mut counts = vec![0usize; buckets.max(1)];
        for seq in self.slots.iter().flatten() {
            counts[bucket_of(seq.req.sig, buckets)] += 1;
        }
        let (bucket, &count) =
            counts.iter().enumerate().max_by_key(|&(_, &c)| c)?;
        if count == 0 {
            None
        } else {
            Some(bucket)
        }
    }
}

/// A request that produced (and delivered) its final chunk this
/// iteration, with the latencies the serving metrics record.
#[derive(Debug, Clone)]
pub struct FinishedSeq {
    /// The completed request.
    pub id: RequestId,
    /// Arrival → final chunk delivered (milliseconds).
    pub request_ms: f64,
    /// Arrival → first inclusion in a step (milliseconds).
    pub queue_ms: f64,
}

/// What one [`ContinuousScheduler::poll`] did — the driving loop records
/// these into the engine metrics (outside the engine lock) and uses
/// [`IterReport::progressed`] to pace itself.
#[derive(Debug, Default)]
pub struct IterReport {
    /// Fresh sequences admitted from the router this iteration.
    pub joins: usize,
    /// Parked sequences that re-entered a slot.
    pub rejoins: usize,
    /// Rows stepped (0 when every slot was empty or stalled).
    pub stepped: usize,
    /// Whether an engine step ran at all.
    pub ran_step: bool,
    /// Chunks that hit a full client channel this iteration.
    pub stalls: usize,
    /// Sequences that exhausted the stall budget and yielded their slot.
    pub parks: usize,
    /// Previously stalled chunks that finally got through.
    pub drained: usize,
    /// Sequences dropped (consumer gone, engine error, or stuck past
    /// shutdown grace).
    pub abandoned: usize,
    /// Requests whose final chunk was delivered this iteration.
    pub finished: Vec<FinishedSeq>,
}

impl IterReport {
    /// Did this iteration move anything? (When false the driving loop
    /// may sleep briefly instead of spinning.)
    pub fn progressed(&self) -> bool {
        self.ran_step
            || self.joins + self.rejoins + self.drained > 0
            || self.parks + self.abandoned > 0
            || !self.finished.is_empty()
    }

    fn finish(&mut self, seq: &SeqState) {
        self.finished.push(seq.record());
    }
}

/// Per-replica continuous-batching loop body. The owning thread calls
/// [`ContinuousScheduler::poll`] in a loop; each call is one iteration.
pub struct ContinuousScheduler<E: StepEngine> {
    engine: E,
    batch: InFlightBatch,
    /// Sequences that yielded their slot to backpressure; retried every
    /// iteration, rejoining (ahead of fresh work) once drained.
    parked: Vec<SeqState>,
    stall_budget: Duration,
}

impl<E: StepEngine> ContinuousScheduler<E> {
    /// Scheduler over `engine` with `max_inflight` slots; a consumer
    /// that stays stalled past `stall_budget` yields its slot.
    pub fn new(engine: E, max_inflight: usize,
               stall_budget: Duration) -> Self {
        ContinuousScheduler {
            engine,
            batch: InFlightBatch::new(max_inflight),
            parked: Vec::new(),
            stall_budget,
        }
    }

    /// Sequences currently holding a slot.
    pub fn inflight(&self) -> usize {
        self.batch.len()
    }

    /// Sequences parked on backpressure.
    pub fn parked(&self) -> usize {
        self.parked.len()
    }

    /// Nothing in flight and nothing parked — together with a closed,
    /// drained router this means the loop can exit.
    pub fn is_idle(&self) -> bool {
        self.batch.is_empty() && self.parked.is_empty()
    }

    /// One scheduler iteration: retry stalled/parked consumers, admit
    /// joins (blocking up to `idle_wait` only when nothing is in
    /// flight), run one engine step over the active rows, and stream the
    /// resulting chunks. An engine error fails only the sequences that
    /// were in that step; the scheduler itself stays usable.
    pub fn poll(&mut self, queue: &AffinityRouter<Request>,
                replica: usize, idle_wait: Duration)
        -> Result<IterReport> {
        let mut report = IterReport::default();
        let closed = queue.is_closed();
        self.drain_pending(closed, &mut report);
        let waited = self.admit(queue, replica, idle_wait, &mut report);
        let stepped = self.step(&mut report);
        if !waited && !report.progressed() {
            // Every slot stalled and the queue idle: don't spin.
            std::thread::sleep(Duration::from_micros(500));
        }
        stepped.map(|()| report)
    }

    /// Retry every pending chunk (in-slot stalls first, then parked
    /// sequences), parking slots that exhausted the stall budget and
    /// dropping consumers that disconnected or are stuck past shutdown.
    fn drain_pending(&mut self, closed: bool, report: &mut IterReport) {
        for idx in 0..self.batch.slots.len() {
            let Some(seq) = self.batch.slots[idx].as_mut() else {
                continue;
            };
            let Some(chunk) = seq.pending.take() else { continue };
            match seq.req.reply.try_send(chunk) {
                Ok(()) => {
                    report.drained += 1;
                    seq.stalled_since = None;
                    if !seq.remaining() {
                        let done = self.batch.release(idx).unwrap();
                        report.finish(&done);
                    }
                }
                Err(TrySendError::Full(chunk)) => {
                    let since = *seq
                        .stalled_since
                        .get_or_insert_with(Instant::now);
                    seq.pending = Some(chunk);
                    if closed
                        && since.elapsed()
                            > self.stall_budget + SHUTDOWN_GRACE
                    {
                        self.batch.release(idx);
                        report.abandoned += 1;
                    } else if since.elapsed() >= self.stall_budget {
                        let parked = self.batch.release(idx).unwrap();
                        self.parked.push(parked);
                        report.parks += 1;
                    }
                }
                Err(TrySendError::Disconnected(_)) => {
                    self.batch.release(idx);
                    report.abandoned += 1;
                }
            }
        }
        let mut i = 0;
        while i < self.parked.len() {
            let seq = &mut self.parked[i];
            let Some(chunk) = seq.pending.take() else {
                i += 1;
                continue;
            };
            match seq.req.reply.try_send(chunk) {
                Ok(()) => {
                    report.drained += 1;
                    seq.stalled_since = None;
                    if !seq.remaining() {
                        let done = self.parked.swap_remove(i);
                        report.finish(&done);
                    } else {
                        i += 1;
                    }
                }
                Err(TrySendError::Full(chunk)) => {
                    let stuck = seq.stalled_since.map_or(false, |s| {
                        s.elapsed() > self.stall_budget + SHUTDOWN_GRACE
                    });
                    seq.pending = Some(chunk);
                    if closed && stuck {
                        self.parked.swap_remove(i);
                        report.abandoned += 1;
                    } else {
                        i += 1;
                    }
                }
                Err(TrySendError::Disconnected(_)) => {
                    self.parked.swap_remove(i);
                    report.abandoned += 1;
                }
            }
        }
    }

    /// Fill free slots: drained parked sequences rejoin first (they
    /// already yielded once), then fresh requests — from the in-flight
    /// batch's dominant affinity bucket when one exists. Blocks for the
    /// first join only when nothing at all is in flight. Returns whether
    /// the call slept on an empty router.
    fn admit(&mut self, queue: &AffinityRouter<Request>, replica: usize,
             idle_wait: Duration, report: &mut IterReport) -> bool {
        while self.batch.free_count() > 0 {
            let Some(pos) =
                self.parked.iter().position(|s| s.pending.is_none())
            else {
                break;
            };
            let seq = self.parked.swap_remove(pos);
            self.batch.insert(seq);
            report.rejoins += 1;
        }
        if self.batch.free_count() == 0 {
            return false;
        }
        let mut hint = self.batch.dominant_bucket(queue.num_buckets());
        if self.batch.is_empty() {
            let wait = if self.parked.is_empty() {
                idle_wait
            } else {
                PARKED_POLL
            };
            match queue.pop_timeout(replica, wait) {
                Some((bucket, req)) => {
                    hint = Some(bucket);
                    self.join(req, report);
                }
                None => return true,
            }
        }
        let free = self.batch.free_count();
        if free > 0 {
            for req in
                queue.drain_affine(replica, hint.unwrap_or(0), free)
            {
                self.join(req, report);
            }
        }
        false
    }

    fn join(&mut self, mut req: Request, report: &mut IterReport) {
        self.engine.prefill(&mut req.ids);
        let inserted = self.batch.insert(SeqState::new(req));
        debug_assert!(inserted.is_some(), "join admitted past capacity");
        report.joins += 1;
    }

    /// One engine step over the active (occupied, un-stalled) rows, then
    /// chunk distribution with per-client backpressure.
    fn step(&mut self, report: &mut IterReport) -> Result<()> {
        let active: Vec<usize> = (0..self.batch.slots.len())
            .filter(|&i| {
                self.batch.slots[i]
                    .as_ref()
                    .map_or(false, |s| s.pending.is_none())
            })
            .collect();
        if active.is_empty() {
            return Ok(());
        }
        let seq_len = self.engine.seq_len();
        let mut data = Vec::with_capacity(active.len() * seq_len);
        for &i in &active {
            let seq = self.batch.slots[i].as_ref().unwrap();
            debug_assert_eq!(seq.req.ids.len(), seq_len);
            data.extend_from_slice(&seq.req.ids);
        }
        let ids = IdTensor::new(vec![active.len(), seq_len], data)?;
        let result = match self.engine.step(&ids) {
            Ok(r) => r,
            Err(e) => {
                // Fail only this step's sequences (their clients time
                // out, exactly like a failed legacy batch); the
                // scheduler stays alive for everyone else.
                for &i in &active {
                    self.batch.release(i);
                    report.abandoned += 1;
                }
                return Err(e);
            }
        };
        let causal = self.engine.causal();
        report.ran_step = true;
        report.stepped = active.len();
        for (row, &idx) in active.iter().enumerate() {
            let seq = self.batch.slots[idx].as_mut().unwrap();
            seq.steps_done += 1;
            seq.memo_hits += result.memo_hits[row];
            let label = result.labels[row];
            let last = !seq.remaining();
            if !last && causal {
                advance_causal(&mut seq.req.ids, label);
            }
            let chunk =
                make_chunk(seq, result.logits.row(row), label,
                           result.seconds);
            match seq.req.reply.try_send(chunk) {
                Ok(()) => {
                    if last {
                        let done = self.batch.release(idx).unwrap();
                        report.finish(&done);
                    }
                }
                Err(TrySendError::Full(chunk)) => {
                    seq.pending = Some(chunk);
                    seq.stalled_since = Some(Instant::now());
                    report.stalls += 1;
                }
                Err(TrySendError::Disconnected(_)) => {
                    self.batch.release(idx);
                    report.abandoned += 1;
                }
            }
        }
        Ok(())
    }
}

/// The legacy fixed-membership path over the same [`StepEngine`]: step
/// the given batch until every member produced its final chunk — nobody
/// joins mid-flight, chunk sends are *blocking* (queue-global
/// backpressure), and early finishers leave the tensor but their slot
/// stays unused until the whole batch drains. This is both what
/// `--no-continuous-batching` serves and the "fixed" arm of the bench
/// A/B. Returns the finished-request latencies for metric recording
/// (done by the caller, outside any engine lock).
pub fn run_fixed_batch<E: StepEngine>(engine: &mut E,
                                      batch: Vec<Request>)
    -> Result<Vec<FinishedSeq>> {
    let seq_len = engine.seq_len();
    let mut seqs: Vec<Option<SeqState>> = batch
        .into_iter()
        .map(|mut r| {
            engine.prefill(&mut r.ids);
            Some(SeqState::new(r))
        })
        .collect();
    let mut done = Vec::new();
    loop {
        let active: Vec<usize> =
            (0..seqs.len()).filter(|&i| seqs[i].is_some()).collect();
        if active.is_empty() {
            return Ok(done);
        }
        let mut data = Vec::with_capacity(active.len() * seq_len);
        for &i in &active {
            data.extend_from_slice(&seqs[i].as_ref().unwrap().req.ids);
        }
        let ids = IdTensor::new(vec![active.len(), seq_len], data)?;
        let result = engine.step(&ids)?;
        let causal = engine.causal();
        for (row, &i) in active.iter().enumerate() {
            let seq = seqs[i].as_mut().unwrap();
            seq.steps_done += 1;
            seq.memo_hits += result.memo_hits[row];
            let label = result.labels[row];
            let last = !seq.remaining();
            if !last && causal {
                advance_causal(&mut seq.req.ids, label);
            }
            let chunk =
                make_chunk(seq, result.logits.row(row), label,
                           result.seconds);
            let delivered = seq.req.reply.send(chunk).is_ok();
            if last || !delivered {
                let seq = seqs[i].take().unwrap();
                if last {
                    done.push(seq.record());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::tensor::Tensor;

    /// Zero-cost engine: every row gets label 7 and one memo hit per
    /// step. Deterministic, so tests drive iterations by hand.
    struct ToyEngine {
        seq: usize,
        causal: bool,
        steps: usize,
    }

    impl StepEngine for ToyEngine {
        fn seq_len(&self) -> usize {
            self.seq
        }

        fn causal(&self) -> bool {
            self.causal
        }

        fn step(&mut self, ids: &IdTensor) -> Result<BatchResult> {
            self.steps += 1;
            let n = ids.shape[0];
            let logits = Tensor::new(vec![n, 2], vec![0.5; n * 2])?;
            Ok(BatchResult {
                logits,
                labels: vec![7; n],
                memo_hits: vec![1; n],
                seconds: 0.0,
            })
        }
    }

    fn toy(seq: usize) -> ToyEngine {
        ToyEngine { seq, causal: false, steps: 0 }
    }

    #[test]
    fn single_step_request_joins_steps_and_finishes_in_one_poll() {
        let q: AffinityRouter<Request> = AffinityRouter::new(4, 1, 64);
        let (req, rx) = Request::streaming(1, vec![5, 6], 0, 1, 4);
        q.try_push(req.sig, req).unwrap();
        let mut sched =
            ContinuousScheduler::new(toy(8), 4, Duration::ZERO);
        let r = sched
            .poll(&q, 0, Duration::from_millis(5))
            .unwrap();
        assert_eq!(r.joins, 1);
        assert_eq!(r.stepped, 1);
        assert_eq!(r.finished.len(), 1);
        let chunk = rx.try_recv().unwrap();
        assert!(chunk.last);
        assert_eq!(chunk.step, 0);
        assert_eq!(chunk.label, 7);
        assert!(sched.is_idle());
    }

    #[test]
    fn multi_step_request_streams_one_chunk_per_iteration() {
        let q: AffinityRouter<Request> = AffinityRouter::new(4, 1, 64);
        let (req, rx) = Request::streaming(1, vec![5], 0, 3, 8);
        q.try_push(req.sig, req).unwrap();
        let mut sched =
            ContinuousScheduler::new(toy(4), 4, Duration::ZERO);
        for step in 0..3u32 {
            let r = sched.poll(&q, 0, Duration::ZERO).unwrap();
            assert_eq!(r.stepped, 1, "step {step}");
            let chunk = rx.try_recv().unwrap();
            assert_eq!(chunk.step, step);
            assert_eq!(chunk.last, step == 2);
            assert_eq!(chunk.memo_hits, step + 1, "hits accumulate");
        }
        assert!(sched.is_idle());
    }

    #[test]
    fn stalled_consumer_parks_and_yields_its_slot_then_completes() {
        let q: AffinityRouter<Request> = AffinityRouter::new(1, 1, 64);
        // Capacity-1 channel, 3 steps, never drained at first: the
        // second chunk must stall and (budget zero) park immediately.
        let (slow, slow_rx) = Request::streaming(1, vec![9], 0, 3, 1);
        q.try_push(slow.sig, slow).unwrap();
        let mut sched =
            ContinuousScheduler::new(toy(4), 1, Duration::ZERO);
        sched.poll(&q, 0, Duration::ZERO).unwrap(); // chunk 0 buffered
        let r = sched.poll(&q, 0, Duration::ZERO).unwrap();
        assert_eq!(r.stalls, 1, "second chunk hits the full channel");
        let r = sched.poll(&q, 0, Duration::ZERO).unwrap();
        assert_eq!(r.parks, 1, "stall budget exhausted → parked");
        assert_eq!(sched.parked(), 1);

        // The single slot is free again: a fast request flows past the
        // parked one without waiting for it.
        let (fast, fast_rx) = Request::streaming(2, vec![3], 0, 1, 4);
        q.try_push(fast.sig, fast).unwrap();
        let r = sched.poll(&q, 0, Duration::ZERO).unwrap();
        assert_eq!(r.joins, 1);
        assert!(fast_rx.try_recv().unwrap().last);

        // Now the slow consumer drains; the parked sequence rejoins and
        // runs to completion.
        let mut got = vec![slow_rx.try_recv().unwrap()];
        for _ in 0..8 {
            let _ = sched.poll(&q, 0, Duration::ZERO).unwrap();
            while let Ok(c) = slow_rx.try_recv() {
                got.push(c);
            }
            if got.len() == 3 {
                break;
            }
        }
        assert_eq!(got.len(), 3, "slow client still completes");
        assert!(got[2].last);
        assert!(sched.is_idle());
    }

    #[test]
    fn disconnected_consumer_is_dropped_not_wedged() {
        let q: AffinityRouter<Request> = AffinityRouter::new(1, 1, 64);
        let (req, rx) = Request::streaming(1, vec![2], 0, 5, 1);
        q.try_push(req.sig, req).unwrap();
        drop(rx);
        let mut sched =
            ContinuousScheduler::new(toy(4), 2, Duration::ZERO);
        let r = sched.poll(&q, 0, Duration::ZERO).unwrap();
        assert_eq!(r.abandoned, 1);
        assert!(sched.is_idle());
    }

    #[test]
    fn joins_prefer_the_dominant_affinity_bucket() {
        // 4 buckets, in-flight work in bucket 1; queued work in buckets
        // 1 and 2. With free slots the scheduler must drain bucket 1
        // (the dominant one) before touching bucket 2.
        let q: AffinityRouter<Request> = AffinityRouter::new(4, 1, 64);
        let (a, _a_rx) = Request::streaming(1, vec![1], 1, 4, 8);
        q.try_push(1, a).unwrap();
        let mut sched =
            ContinuousScheduler::new(toy(4), 2, Duration::ZERO);
        sched.poll(&q, 0, Duration::ZERO).unwrap();
        assert_eq!(sched.inflight(), 1);

        let (b, b_rx) = Request::streaming(2, vec![2], 2, 1, 8);
        let (c, c_rx) = Request::streaming(3, vec![3], 1, 1, 8);
        q.try_push(2, b).unwrap();
        q.try_push(1, c).unwrap();
        let r = sched.poll(&q, 0, Duration::ZERO).unwrap();
        // One free slot: the join must come from bucket 1 (request c),
        // leaving bucket 2's request queued.
        assert_eq!(r.joins, 1);
        assert!(c_rx.try_recv().is_ok(), "same-bucket request joined");
        assert!(b_rx.try_recv().is_err(), "other bucket still queued");
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn causal_steps_append_the_generated_token() {
        let q: AffinityRouter<Request> = AffinityRouter::new(1, 1, 8);
        let (req, _rx) = Request::streaming(1, vec![5], 0, 2, 8);
        q.try_push(0, req).unwrap();
        let mut sched = ContinuousScheduler::new(
            ToyEngine { seq: 4, causal: true, steps: 0 },
            1,
            Duration::ZERO,
        );
        sched.poll(&q, 0, Duration::ZERO).unwrap();
        let seq =
            sched.batch.slots[0].as_ref().expect("still in flight");
        assert_eq!(seq.req.ids, vec![5, 7, 0, 0],
                   "argmax token appended at the first pad position");
    }

    #[test]
    fn fixed_batch_runs_members_to_their_own_lengths() {
        let mut eng = toy(4);
        let mut reqs = Vec::new();
        let mut rxs = Vec::new();
        for (i, steps) in [1usize, 3, 2].into_iter().enumerate() {
            let (r, rx) =
                Request::streaming(i as u64, vec![1], 0, steps, 8);
            reqs.push(r);
            rxs.push((rx, steps));
        }
        let done = run_fixed_batch(&mut eng, reqs).unwrap();
        assert_eq!(done.len(), 3);
        assert_eq!(eng.steps, 3, "membership frozen: longest rules");
        for (rx, steps) in rxs {
            let chunks: Vec<_> = rx.try_iter().collect();
            assert_eq!(chunks.len(), steps);
            assert!(chunks.last().unwrap().last);
        }
    }
}
