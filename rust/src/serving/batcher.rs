//! Per-replica batching loop, in two flavours selected by
//! `ServingConfig::continuous_batching`: the legacy fixed path fuses
//! queued requests into one-shot batches under a max-batch / max-wait
//! policy, while the continuous path runs the iteration-level scheduler
//! in `serving::schedule` (sequences join and leave the in-flight batch
//! at every step boundary).
//!
//! The server runs one batcher per engine replica, all popping from the
//! same [`AffinityRouter`]: each batcher prefers its *home* affinity
//! buckets (similar requests share a bucket, so batches come out
//! bucket-homogeneous — that's what makes intra-batch dedup and
//! online-tier locality pay), and steals from the fullest bucket when it
//! has no home work so skewed traffic never strands a replica. The
//! engines themselves are never locked by another replica's batcher; the
//! shared state (the online `MemoTier`) synchronizes internally per layer
//! shard.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::ServingConfig;
use crate::serving::affinity::AffinityRouter;
use crate::serving::engine::Engine;
use crate::serving::request::Request;
use crate::serving::schedule::{
    run_fixed_batch, ContinuousScheduler, FinishedSeq,
};
use crate::Result;

/// Form one batch for `replica`: block up to `idle_wait` for the first
/// request, then give stragglers up to `max_wait` to fill the batch,
/// draining the first request's affinity bucket (then the replica's other
/// home buckets) in preference.
///
/// The deadline is re-checked on **every** loop iteration — after a
/// non-blocking drain, so already-queued work is always taken (even with
/// `max_wait = 0`), but a continuous trickle of single requests still
/// closes the batch at `max_wait` like any other straggler pattern (the
/// old loop only checked the deadline when a drain came back empty, so a
/// steady trickle could hold a batch open until `max_batch` filled —
/// unbounded latency for the first request).
pub fn form_batch<T>(queue: &AffinityRouter<T>, replica: usize,
                     max_batch: usize, max_wait: Duration,
                     idle_wait: Duration) -> Vec<T> {
    let Some((bucket, first)) = queue.pop_timeout(replica, idle_wait) else {
        return Vec::new();
    };
    let mut batch = vec![first];
    let deadline = Instant::now() + max_wait;
    while batch.len() < max_batch {
        // Snapshot the push counter *before* draining: a push racing the
        // drain advances it, so the wait below returns immediately
        // instead of sleeping through the work.
        let seen = queue.push_seq();
        let more = queue.drain_affine(replica, bucket,
                                      max_batch - batch.len());
        let idle = more.is_empty();
        batch.extend(more);
        let now = Instant::now();
        if batch.len() >= max_batch || now >= deadline {
            break;
        }
        if idle {
            if queue.is_closed() {
                break;
            }
            // Park on the router's condvar until the next push (or the
            // batch deadline) — the old 200 µs sleep-poll burned a core
            // per idle batcher and added up to 200 µs to every
            // straggler's latency.
            queue.wait_newer_push(seen, deadline - now);
        }
    }
    batch
}

/// Owns the batching loop; runs on its own thread via [`Batcher::run`].
pub struct Batcher {
    queue: Arc<AffinityRouter<Request>>,
    engine: Arc<Mutex<Engine>>,
    cfg: ServingConfig,
    /// Replica index: selects this batcher's home affinity buckets and
    /// names its thread in multi-replica servers.
    replica: usize,
}

impl Batcher {
    pub fn new(queue: Arc<AffinityRouter<Request>>,
               engine: Arc<Mutex<Engine>>, cfg: ServingConfig,
               replica: usize) -> Self {
        Batcher { queue, engine, cfg, replica }
    }

    fn next_batch(&self, idle_wait: Duration) -> Vec<Request> {
        form_batch(&self.queue, self.replica, self.cfg.max_batch,
                   Duration::from_millis(self.cfg.max_wait_ms), idle_wait)
    }

    /// Execute one fixed-membership batch and stream every reply. Each
    /// member is timestamped at batch start (inside `run_fixed_batch`),
    /// so `queue_seconds` is a real arrival→batch-start interval — no
    /// whole-batch `result.seconds` subtraction, no clamp. The engine
    /// mutex is held only inside each forward pass (the `StepEngine`
    /// impl locks per step); chunk sends and latency recording happen
    /// outside it, so a slow reply channel never blocks the engine for
    /// the other replicas' batchers or the STATS path.
    fn serve_batch(&self, batch: Vec<Request>) -> Result<()> {
        let mut engine = Arc::clone(&self.engine);
        let done = run_fixed_batch(&mut engine, batch)?;
        self.record_finished(&done);
        Ok(())
    }

    /// Record per-request latencies under one short metrics lock (after
    /// all replies went out).
    fn record_finished(&self, done: &[FinishedSeq]) {
        if done.is_empty() {
            return;
        }
        let mut engine = self.engine.lock().unwrap();
        for f in done {
            engine.metrics.request_latency_ms.record(f.request_ms);
            engine.metrics.queue_wait_ms.record(f.queue_ms);
        }
    }

    /// Batch loop; returns when the queue is closed and drained. With
    /// `continuous_batching` set this is the iteration-level scheduler,
    /// otherwise the legacy fixed-batch loop (still the default, and the
    /// A/B baseline).
    pub fn run(&self) {
        if self.cfg.continuous_batching {
            self.run_continuous();
        } else {
            self.run_fixed();
        }
    }

    /// Legacy loop: form a batch behind the max-wait deadline, run it to
    /// completion, repeat.
    fn run_fixed(&self) {
        loop {
            let batch = self.next_batch(Duration::from_millis(50));
            if batch.is_empty() {
                if self.queue.is_closed() && self.queue.is_empty() {
                    return;
                }
                continue;
            }
            if let Err(e) = self.serve_batch(batch) {
                log::error!("batcher[{}]: batch failed: {e}", self.replica);
            }
        }
    }

    /// Continuous loop: one scheduler iteration per pass — sequences
    /// join and leave at every step boundary, chunks stream back with
    /// per-client backpressure.
    fn run_continuous(&self) {
        let mut sched = ContinuousScheduler::new(
            Arc::clone(&self.engine),
            self.cfg.max_inflight,
            Duration::from_millis(self.cfg.client_stall_ms),
        );
        loop {
            match sched.poll(&self.queue, self.replica,
                             Duration::from_millis(50)) {
                Ok(r) => {
                    if r.progressed() {
                        let mut engine = self.engine.lock().unwrap();
                        let m = &mut engine.metrics;
                        m.cb_steps += u64::from(r.ran_step);
                        m.cb_joins += (r.joins + r.rejoins) as u64;
                        m.cb_stalls += r.stalls as u64;
                        m.cb_parks += r.parks as u64;
                        for f in &r.finished {
                            m.request_latency_ms.record(f.request_ms);
                            m.queue_wait_ms.record(f.queue_ms);
                        }
                    }
                }
                Err(e) => log::error!(
                    "batcher[{}]: step failed: {e}", self.replica
                ),
            }
            if sched.is_idle() && self.queue.is_closed()
                && self.queue.is_empty()
            {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};

    /// Regression: a continuous trickle of single requests must not hold
    /// the batch open past `max_wait` — the old loop `continue`d past the
    /// deadline check whenever a drain returned something, so a 2 ms
    /// trickle with a large `max_batch` kept the first request waiting
    /// for seconds.
    #[test]
    fn trickle_closes_batch_at_deadline() {
        let q: Arc<AffinityRouter<u32>> =
            Arc::new(AffinityRouter::new(1, 1, 4096));
        let stop = Arc::new(AtomicBool::new(false));
        let (q2, stop2) = (q.clone(), stop.clone());
        let producer = std::thread::spawn(move || {
            let mut i = 0u32;
            while !stop2.load(Ordering::Relaxed) {
                let _ = q2.try_push(0, i);
                i += 1;
                std::thread::sleep(Duration::from_millis(2));
            }
        });
        let t0 = Instant::now();
        let batch = form_batch(&*q, 0, 1000, Duration::from_millis(40),
                               Duration::from_secs(2));
        let took = t0.elapsed();
        stop.store(true, Ordering::Relaxed);
        producer.join().unwrap();
        assert!(!batch.is_empty());
        assert!(batch.len() < 1000,
                "a 2 ms trickle cannot legitimately fill 1000 slots");
        // Old behaviour: ~2 s (1000 × 2 ms). Fixed behaviour: ~40 ms plus
        // scheduling slack; 400 ms cleanly separates the two.
        assert!(took < Duration::from_millis(400),
                "batch held open past the deadline: {took:?}");
    }

    #[test]
    fn batch_fills_up_to_max_batch_when_queue_is_deep() {
        let q: AffinityRouter<u32> = AffinityRouter::new(2, 1, 64);
        for i in 0..32 {
            q.try_push((i % 2) as u64, i).unwrap();
        }
        let batch = form_batch(&q, 0, 8, Duration::from_millis(50),
                               Duration::from_millis(50));
        assert_eq!(batch.len(), 8, "deep queue must fill the batch");
        assert_eq!(q.len(), 24);
    }

    #[test]
    fn zero_wait_still_takes_queued_work() {
        // max_wait_ms = 0 is a legal config: the deadline is expired from
        // the start, but already-queued work must still fill the batch
        // (the drain runs before the deadline check).
        let q: AffinityRouter<u32> = AffinityRouter::new(1, 1, 64);
        for i in 0..8 {
            q.try_push(0, i).unwrap();
        }
        let batch = form_batch(&q, 0, 8, Duration::from_millis(0),
                               Duration::from_millis(10));
        assert_eq!(batch.len(), 8,
                   "queued work must be taken even with a zero wait");
    }

    #[test]
    fn idle_queue_returns_empty_batch() {
        let q: AffinityRouter<u32> = AffinityRouter::new(2, 1, 8);
        let batch = form_batch(&q, 0, 8, Duration::from_millis(5),
                               Duration::from_millis(5));
        assert!(batch.is_empty());
    }

    #[test]
    fn batch_prefers_the_popped_bucket() {
        // Two buckets, one replica (both home): the batch should drain
        // the first request's bucket before touching the other, keeping
        // batches bucket-homogeneous.
        let q: AffinityRouter<u32> = AffinityRouter::new(2, 1, 64);
        for i in 0..4 {
            q.try_push(0, 100 + i).unwrap();
        }
        q.try_push(1, 7).unwrap();
        let batch = form_batch(&q, 0, 3, Duration::from_millis(20),
                               Duration::from_millis(20));
        // Rotation starts at bucket 0; the drain stays in that bucket
        // until the batch fills, leaving bucket 1 (and bucket 0's tail)
        // for the next batch.
        assert_eq!(batch, vec![100, 101, 102]);
        assert_eq!(q.len(), 2);
    }
}
