//! Dynamic batcher: fuses queued requests into engine batches under a
//! max-batch / max-wait policy (the vLLM-style continuous batch former).
//!
//! The server runs one batcher per engine replica, all popping from the
//! same bounded queue — the queue is the only point of contention between
//! replicas, and each pop hands a whole batch to exactly one replica. The
//! engines themselves are never locked by another replica's batcher; the
//! shared state (the online `MemoTier`) synchronizes internally per layer
//! shard.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::ServingConfig;
use crate::serving::engine::Engine;
use crate::serving::queue::BoundedQueue;
use crate::serving::request::{Request, Response};
use crate::tensor::tensor::IdTensor;
use crate::Result;

/// Owns the batching loop; runs on its own thread via [`Batcher::run`].
pub struct Batcher {
    queue: Arc<BoundedQueue<Request>>,
    engine: Arc<Mutex<Engine>>,
    cfg: ServingConfig,
    /// Replica index, for logging/thread naming in multi-replica servers.
    replica: usize,
}

impl Batcher {
    pub fn new(queue: Arc<BoundedQueue<Request>>, engine: Arc<Mutex<Engine>>,
               cfg: ServingConfig, replica: usize) -> Self {
        Batcher { queue, engine, cfg, replica }
    }

    /// Form one batch: block for the first request (up to `idle_wait`),
    /// then give stragglers `max_wait_ms` to fill the batch.
    fn next_batch(&self, idle_wait: Duration) -> Vec<Request> {
        let Some(first) = self.queue.pop_timeout(idle_wait) else {
            return Vec::new();
        };
        let mut batch = vec![first];
        let deadline = std::time::Instant::now()
            + Duration::from_millis(self.cfg.max_wait_ms);
        while batch.len() < self.cfg.max_batch {
            let more = self.queue.drain_up_to(self.cfg.max_batch - batch.len());
            if !more.is_empty() {
                batch.extend(more);
                continue;
            }
            if std::time::Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        batch
    }

    /// Execute one batch and reply to every request.
    fn serve_batch(&self, batch: Vec<Request>) -> Result<()> {
        let n = batch.len();
        let seq = self.cfg.seq_len;
        let mut data = Vec::with_capacity(n * seq);
        for r in &batch {
            debug_assert_eq!(r.ids.len(), seq);
            data.extend_from_slice(&r.ids);
        }
        let ids = IdTensor::new(vec![n, seq], data)?;

        let mut engine = self.engine.lock().unwrap();
        let result = engine.infer(&ids)?;
        for (i, req) in batch.into_iter().enumerate() {
            let queue_seconds = req.arrived.elapsed().as_secs_f64()
                - result.seconds;
            let resp = Response {
                id: req.id,
                logits: result.logits.row(i).to_vec(),
                label: result.labels[i],
                memo_hits: result.memo_hits[i],
                queue_seconds: queue_seconds.max(0.0),
                compute_seconds: result.seconds,
            };
            engine
                .metrics
                .request_latency_ms
                .record(req.arrived.elapsed().as_secs_f64() * 1e3);
            engine.metrics.queue_wait_ms.record(resp.queue_seconds * 1e3);
            let _ = req.reply.send(resp); // receiver may have gone away
        }
        Ok(())
    }

    /// Batch loop; returns when the queue is closed and drained.
    pub fn run(&self) {
        loop {
            let batch = self.next_batch(Duration::from_millis(50));
            if batch.is_empty() {
                if self.queue.is_closed() && self.queue.is_empty() {
                    return;
                }
                continue;
            }
            if let Err(e) = self.serve_batch(batch) {
                log::error!("batcher[{}]: batch failed: {e}", self.replica);
            }
        }
    }
}
