//! Request/response types for the serving path.
//!
//! Since the continuous-batching refactor the reply side is a *stream*:
//! every request carries a bounded [`ResponseChunk`] channel and receives
//! one chunk per scheduler iteration it rides in (`max_steps` total, the
//! final one flagged `last`). The bounded channel is the per-client
//! backpressure mechanism — a consumer that stops draining fills only its
//! own channel, stalling (then parking) only its own slot instead of the
//! whole batch. Single-shot `INFER` requests are the degenerate case:
//! `max_steps == 1`, one `last` chunk.

use std::sync::mpsc;
use std::time::Instant;

/// Default bound of a request's chunk channel: deep enough that a client
/// draining at compute speed never blocks the scheduler, shallow enough
/// that a stalled client hits backpressure within a few iterations.
pub const DEFAULT_CHUNK_DEPTH: usize = 4;

/// Monotonically assigned request id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// One inference request: a pre-tokenized sequence (the server tokenizes
/// text before enqueueing, keeping the engine allocation-free on strings).
pub struct Request {
    pub id: RequestId,
    pub ids: Vec<i32>,
    pub arrived: Instant,
    /// Affinity signature stamped at enqueue time. The continuous
    /// scheduler uses it to prefer joins from the in-flight batch's
    /// dominant bucket (keeping batches dedup-friendly); the legacy path
    /// ignores it (the router already bucketed on it).
    pub sig: u64,
    /// Scheduler iterations this request runs for (≥ 1). Classification
    /// requests take one step; causal families generate one token per
    /// step, a chunk each.
    pub max_steps: usize,
    /// Bounded streaming channel back to the submitter.
    pub reply: mpsc::SyncSender<ResponseChunk>,
}

/// One streamed engine answer for one step of one request. The final
/// chunk of a request has `last == true`; `INFER`-style single-shot
/// requests produce exactly one chunk, which is also the last.
#[derive(Debug, Clone)]
pub struct ResponseChunk {
    pub id: RequestId,
    /// 0-based step index within the request.
    pub step: u32,
    /// Whether this is the request's final chunk.
    pub last: bool,
    /// Class logits (encoder families) or final-position LM logits.
    pub logits: Vec<f32>,
    /// argmax class (encoder) / generated token (causal) for this step.
    pub label: i32,
    /// Cumulative layers-served-from-memo count across steps so far.
    pub memo_hits: u32,
    /// Queue + batch wait (seconds): arrival → first inclusion in a step.
    pub queue_seconds: f64,
    /// Engine execution time for the iteration this chunk came from.
    pub compute_seconds: f64,
}

/// Pre-refactor name for the single-shot answer; a one-step request's
/// only chunk carries exactly the old fields.
pub type Response = ResponseChunk;

impl Request {
    /// Single-shot request (one step, default channel depth, no affinity
    /// signature). The receiver sees exactly one `last` chunk.
    pub fn new(id: u64, ids: Vec<i32>)
        -> (Self, mpsc::Receiver<ResponseChunk>) {
        Self::streaming(id, ids, 0, 1, DEFAULT_CHUNK_DEPTH)
    }

    /// Streaming request: `max_steps` chunks over a channel bounded at
    /// `chunk_depth` (both clamped to ≥ 1), tagged with the affinity
    /// signature `sig` the router bucketed it by.
    pub fn streaming(id: u64, ids: Vec<i32>, sig: u64, max_steps: usize,
                     chunk_depth: usize)
        -> (Self, mpsc::Receiver<ResponseChunk>) {
        let (tx, rx) = mpsc::sync_channel(chunk_depth.max(1));
        (
            Request {
                id: RequestId(id),
                ids,
                arrived: Instant::now(),
                sig,
                max_steps: max_steps.max(1),
                reply: tx,
            },
            rx,
        )
    }
}
