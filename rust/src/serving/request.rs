//! Request/response types for the serving path.

use std::sync::mpsc;
use std::time::Instant;

/// Monotonically assigned request id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// One inference request: a pre-tokenized sequence (the server tokenizes
/// text before enqueueing, keeping the engine allocation-free on strings).
pub struct Request {
    pub id: RequestId,
    pub ids: Vec<i32>,
    pub arrived: Instant,
    /// Completion channel back to the submitter.
    pub reply: mpsc::Sender<Response>,
}

/// Engine answer for one request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    /// Class logits (encoder families) or final-position LM logits.
    pub logits: Vec<f32>,
    /// argmax class for convenience.
    pub label: i32,
    /// Layers where this sequence's APM came from the database.
    pub memo_hits: u32,
    /// Queue + batch wait (seconds).
    pub queue_seconds: f64,
    /// Engine execution time for the batch this request rode in.
    pub compute_seconds: f64,
}

impl Request {
    pub fn new(id: u64, ids: Vec<i32>) -> (Self, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                id: RequestId(id),
                ids,
                arrived: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }
}
