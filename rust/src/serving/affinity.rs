//! Similarity-affinity request routing: bucketed sub-queues in front of
//! the batchers, so semantically similar requests land in the *same*
//! batch instead of being scattered across replicas by a single MPMC
//! queue.
//!
//! The paper's core observation (and AttnCache's, at LLM-prefill scale)
//! is that inference traffic is semantically clustered. PR 2's
//! intra-batch dedup and the online tier's locality only pay off when a
//! cluster's requests actually ride in one batch — this module makes that
//! happen without any model forward:
//!
//! * [`Signer`] — sketches a request's token ids into a 64-bit affinity
//!   signature, in one of two modes:
//!   - **prefix** ([`signature`]): a min-hash over the token-bigram set
//!     of the non-pad prefix. Two requests sharing most prefix bigrams
//!     share the minimum with high probability (classic min-wise LSH),
//!     so near-duplicate prompts sketch alike — but the sketch is
//!     order-sensitive, so paraphrases scatter.
//!   - **semantic** ([`crate::memo::semhash::SemanticSketcher`]): a
//!     SimHash over the mean-pooled embedding-table rows of the prefix —
//!     a bag-of-words point in the model's own embedding space, so
//!     word-order variants and near-paraphrases agree on most bits and
//!     share a bucket. Used when `--signature-mode semantic` and an
//!     embedding table is loaded; the min-hash is the fallback.
//! * [`AffinityRouter`] — a bounded set of per-bucket FIFO sub-queues
//!   behind one mutex/condvar pair, keyed by signature (`bucket = sig mod
//!   buckets`; the prefix signer pre-mixes so its skewed minima spread
//!   uniformly, the semantic signer's bits are uniform hyperplane signs
//!   already). Bucket `b` is *home* to replica `b % replicas`; a batcher
//!   round-robins over its non-empty home buckets (so a hot bucket cannot
//!   starve a sparse sibling) and, when it has no home work, **steals**
//!   from the fullest bucket overall so skewed traffic never starves a
//!   replica (or leaves one idle). Capacity is global across buckets —
//!   the admission-control semantics of the old `BoundedQueue` are
//!   preserved.
//! * **Adaptive re-bucketing** — with [`AffinityRouter::with_adaptive`],
//!   the router watches a sliding window of pops: a high steal rate means
//!   the partition is too coarse for the traffic (replicas idle while
//!   work queues elsewhere), so the bucket space **doubles**; a window
//!   that touched only a small fraction of the buckets means the space is
//!   over-partitioned, so it **halves**. Each resize is a
//!   drain-and-requeue epoch under the router lock: every queued request
//!   is re-mapped from its stored signature, preserving per-signature
//!   FIFO order and losing nothing (doubling/halving keeps `sig mod n`
//!   consistent: each new bucket inherits from exactly one old bucket on
//!   grow, and merged buckets concatenate in bucket order on shrink).
//!
//! With `buckets = 1` the router degenerates to the plain shared FIFO
//! queue (`--no-affinity`): bucket 0 is home to replica 0 and every other
//! replica's pop counts as a steal, which is exactly what "no affinity"
//! means operationally.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::memo::semhash::SemanticSketcher;
use crate::{Error, Result};

/// Default non-pad prefix length fed into the signature sketch
/// (`ServingConfig::signature_prefix_len` overrides it). Long enough to
/// tell topics apart, short enough that signing is O(1) per request.
pub const DEFAULT_SIG_PREFIX: usize = 32;

/// Pops observed before the adaptive router re-evaluates its bucket count.
const RESIZE_WINDOW: u64 = 128;

/// Grow when more than 1 in `GROW_STEAL_DIV` window pops were steals.
const GROW_STEAL_DIV: u64 = 4;

/// Shrink when pushes touched no more than 1 in `SHRINK_TOUCH_DIV`
/// buckets over the window.
const SHRINK_TOUCH_DIV: usize = 4;

/// Hysteresis: whole observation windows sat out after any resize. A
/// freshly doubled space trivially satisfies the shrink test (the same
/// traffic now touches a smaller *fraction* of the buckets), so without a
/// cooldown mixed traffic ping-pongs double→halve every window.
const RESIZE_COOLDOWN_WINDOWS: u32 = 1;

/// Asymmetric damping: growth reacts in one window (an idle-stealing
/// replica is lost capacity *now*), but a shrink requires the
/// over-partitioned signal to persist for this many consecutive evaluated
/// windows — a transient traffic dip must not collapse the bucket space.
const SHRINK_STREAK_WINDOWS: u32 = 2;

/// SplitMix64 finalizer: cheap, well-distributed 64-bit mixing.
fn mix(h: u64) -> u64 {
    let mut z = h.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Cheap prefix signature: the min-hash of the token-bigram set of the
/// first `prefix_len` non-pad tokens. No model forward, no float math —
/// O(prefix) integer hashing at enqueue time.
///
/// Property (min-wise hashing): for two requests the probability that
/// their signatures collide equals the Jaccard similarity of their bigram
/// sets, so small edits (a word changed near the tail) usually preserve
/// the signature while unrelated prompts diverge.
pub fn signature(ids: &[i32], prefix_len: usize) -> u64 {
    let prefix_len = prefix_len.max(1);
    let mut prev: Option<u64> = None;
    let mut min = u64::MAX;
    let mut taken = 0usize;
    for &t in ids {
        if t == crate::data::tokenizer::PAD {
            continue;
        }
        let tok = t as u32 as u64;
        if let Some(p) = prev {
            min = min.min(mix((p << 32) | tok));
        }
        prev = Some(tok);
        taken += 1;
        if taken >= prefix_len {
            break;
        }
    }
    match (min, prev) {
        (u64::MAX, Some(only)) => mix(only), // single-token request
        (u64::MAX, None) => 0,               // all-pad request
        (m, _) => m,
    }
}

/// Signature → bucket index under a given bucket count. Doubling the
/// count splits each bucket in two (`sig mod 2n` refines `sig mod n`),
/// which is what makes adaptive power-of-two resizing order-preserving.
pub fn bucket_of(sig: u64, buckets: usize) -> usize {
    if buckets <= 1 {
        return 0;
    }
    (sig % buckets as u64) as usize
}

/// Affinity bucket for a request's token ids under the default prefix
/// signer (tests and benches predicting bucket placement): the min-hash
/// re-mixed modulo the bucket count (a raw min-hash is a minimum, hence
/// skewed small — the extra mix spreads it uniformly over buckets).
pub fn bucket_for(ids: &[i32], buckets: usize) -> usize {
    bucket_of(mix(signature(ids, DEFAULT_SIG_PREFIX)), buckets)
}

/// Sketches request token ids into the 64-bit affinity signature the
/// router buckets by. Built once per server from `ServingConfig`
/// (`signature_mode`, `signature_prefix_len`) and shared by all
/// connection handlers.
pub enum Signer {
    /// Token-prefix min-hash (pre-mixed so `sig mod buckets` is uniform).
    Prefix {
        /// Non-pad prefix tokens sketched per request.
        prefix_len: usize,
    },
    /// Feature-space SimHash over the model's embedding table.
    Semantic(SemanticSketcher),
}

impl Signer {
    /// Prefix min-hash signer.
    pub fn prefix(prefix_len: usize) -> Signer {
        Signer::Prefix { prefix_len: prefix_len.max(1) }
    }

    /// Semantic signer over a built sketcher.
    pub fn semantic(sketcher: SemanticSketcher) -> Signer {
        Signer::Semantic(sketcher)
    }

    /// The request's affinity signature.
    pub fn sign(&self, ids: &[i32]) -> u64 {
        match self {
            Signer::Prefix { prefix_len } => {
                mix(signature(ids, *prefix_len))
            }
            Signer::Semantic(sk) => sk.sketch(ids),
        }
    }

    /// Mode name for logs/STATS (`prefix` or `semantic`).
    pub fn mode_name(&self) -> &'static str {
        match self {
            Signer::Prefix { .. } => "prefix",
            Signer::Semantic(_) => "semantic",
        }
    }
}

struct Inner<T> {
    /// Per-bucket FIFO of `(signature, request)` — the signature rides
    /// along so a resize epoch can re-map queued requests.
    buckets: Vec<VecDeque<(u64, T)>>,
    len: usize,
    closed: bool,
    /// Per-replica rotation cursor over home buckets: the next pop scans
    /// from here, so every non-empty home bucket gets a turn (a deepest-
    /// first policy would let one hot bucket starve a sparse sibling
    /// indefinitely under sustained skew).
    next_home: Vec<usize>,
    /// Buckets that received at least one push in the current adaptive
    /// observation window.
    touched: Vec<bool>,
    /// Monotone count of accepted pushes. Batchers snapshot it before an
    /// affine drain and sleep on `not_empty` until it moves
    /// ([`AffinityRouter::wait_newer_push`]) — a counter, not a boolean,
    /// so a push that lands between the drain and the wait is never a
    /// lost wakeup.
    pushes: u64,
    window_pops: u64,
    window_steals: u64,
    resizes: u64,
    /// Windows left to sit out after a resize (hysteresis).
    cooldown: u32,
    /// Consecutive evaluated windows that met the shrink condition.
    shrink_streak: u32,
}

/// Snapshot of the router's observable state (for STATS reporting).
///
/// Every field is captured under one router guard, so the snapshot is
/// internally consistent even while a drain-and-requeue resize epoch is
/// mid-flight — `depths.len()` always equals `buckets`, and the depths
/// always sum to the queued-request count at snapshot time. (Composing
/// separate `steals()`/`num_buckets()` calls instead can interleave with
/// a resize and report depths against a stale bucket count.)
#[derive(Debug, Clone)]
pub struct RouterStats {
    /// Bucket count at snapshot time (same guard as `depths`).
    pub buckets: usize,
    /// Queue depth per bucket at snapshot time.
    pub depths: Vec<usize>,
    /// Total pops that took a request from a non-home bucket.
    pub steals: u64,
    /// Completed adaptive resize epochs since construction.
    pub resizes: u64,
}

/// Bounded affinity-bucketed request queue shared between connection
/// handlers (producers) and the per-replica batcher threads (consumers).
///
/// All operations run under one mutex, so any number of producers and
/// consumers is safe; the capacity (`depth`) is global across buckets, so
/// backpressure behaves exactly like the old single `BoundedQueue`.
pub struct AffinityRouter<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    depth: usize,
    replicas: usize,
    adaptive: bool,
    max_buckets: usize,
    steals: AtomicU64,
}

impl<T> AffinityRouter<T> {
    /// Router with `buckets` sub-queues serving `replicas` batchers and a
    /// global capacity of `depth` requests (each clamped to at least 1).
    /// Adaptive re-bucketing is off until
    /// [`AffinityRouter::with_adaptive`] enables it.
    pub fn new(buckets: usize, replicas: usize, depth: usize) -> Self {
        let buckets = buckets.max(1);
        let replicas = replicas.max(1);
        AffinityRouter {
            inner: Mutex::new(Inner {
                buckets: (0..buckets).map(|_| VecDeque::new()).collect(),
                len: 0,
                closed: false,
                next_home: vec![0; replicas],
                touched: vec![false; buckets],
                pushes: 0,
                window_pops: 0,
                window_steals: 0,
                resizes: 0,
                cooldown: 0,
                shrink_streak: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            depth: depth.max(1),
            replicas,
            adaptive: false,
            max_buckets: buckets,
            steals: AtomicU64::new(0),
        }
    }

    /// Enable (or disable) adaptive re-bucketing, capping growth at
    /// `max_buckets` (clamped to at least the current bucket count).
    pub fn with_adaptive(mut self, enabled: bool,
                         max_buckets: usize) -> Self {
        let current = self.inner.get_mut().unwrap().buckets.len();
        self.adaptive = enabled;
        self.max_buckets = max_buckets.max(current);
        self
    }

    /// Current number of affinity buckets (takes the router lock — the
    /// count changes across adaptive resize epochs).
    pub fn num_buckets(&self) -> usize {
        self.inner.lock().unwrap().buckets.len()
    }

    /// Is `bucket` one of `replica`'s home buckets?
    fn is_home(&self, bucket: usize, replica: usize) -> bool {
        bucket % self.replicas == replica % self.replicas
    }

    /// Non-blocking push of a request with affinity signature `sig`;
    /// `Err` when the router is full or closed (caller sheds load).
    pub fn try_push(&self, sig: u64, item: T) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(Error::serving("queue closed"));
        }
        if g.len >= self.depth {
            return Err(Error::serving("queue full"));
        }
        let b = bucket_of(sig, g.buckets.len());
        g.touched[b] = true;
        g.buckets[b].push_back((sig, item));
        g.len += 1;
        g.pushes += 1;
        // notify_all, not notify_one: pop_timeout waiters and
        // wait_newer_push waiters share this condvar, and a single wakeup
        // delivered to a batcher whose home buckets don't cover the pushed
        // item would strand it for another replica's waiter.
        self.not_empty.notify_all();
        Ok(())
    }

    /// Blocking push of a request with affinity signature `sig` (waits
    /// for space); `Err` when closed.
    pub fn push(&self, sig: u64, item: T) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(Error::serving("queue closed"));
            }
            if g.len < self.depth {
                let b = bucket_of(sig, g.buckets.len());
                g.touched[b] = true;
                g.buckets[b].push_back((sig, item));
                g.len += 1;
                g.pushes += 1;
                // See try_push for why this is notify_all.
                self.not_empty.notify_all();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Take one request for `replica` under the lock: the next non-empty
    /// home bucket in rotation order first (round-robin, so a sparse home
    /// bucket cannot be starved by a hot sibling that keeps refilling);
    /// otherwise steal from the fullest bucket overall (the replica is
    /// idle — leaving work queued would strand it under skewed traffic;
    /// the stolen bucket's own home replica round-robins over it, so
    /// fullest-first is safe here). Returns the source bucket with the
    /// item.
    fn take_locked(&self, g: &mut Inner<T>, replica: usize)
        -> Option<(usize, T)> {
        let nb = g.buckets.len();
        let r = replica % self.replicas;
        let start = g.next_home[r];
        let mut home: Option<usize> = None;
        for k in 0..nb {
            let b = (start + k) % nb;
            if self.is_home(b, replica) && !g.buckets[b].is_empty() {
                home = Some(b);
                break;
            }
        }
        let (bucket, stolen) = match home {
            Some(b) => {
                g.next_home[r] = (b + 1) % nb;
                (b, false)
            }
            None => {
                let mut best: Option<usize> = None;
                for b in 0..nb {
                    if !g.buckets[b].is_empty()
                        && best.map_or(true, |x| {
                            g.buckets[b].len() > g.buckets[x].len()
                        })
                    {
                        best = Some(b);
                    }
                }
                (best?, true)
            }
        };
        let (_sig, item) = g.buckets[bucket].pop_front()?;
        g.len -= 1;
        g.window_pops += 1;
        if stolen {
            self.steals.fetch_add(1, Ordering::Relaxed);
            g.window_steals += 1;
        }
        self.maybe_resize(g);
        Some((bucket, item))
    }

    /// Adaptive re-bucketing check, run after every counted pop. The
    /// returned bucket index of the pop that triggered a resize refers to
    /// the pre-resize numbering; `drain_affine` guards with a modulo, so
    /// the worst case is one batch drained from a re-mapped bucket.
    ///
    /// Two hysteresis rules damp oscillation under mixed traffic (a
    /// freshly doubled space trivially satisfies the shrink test, so the
    /// naive controller ping-pongs): after any resize the controller sits
    /// out [`RESIZE_COOLDOWN_WINDOWS`] whole windows, and — asymmetric
    /// with the one-window grow reaction — a shrink additionally needs
    /// the over-partitioned signal to persist for
    /// [`SHRINK_STREAK_WINDOWS`] consecutive evaluated windows.
    fn maybe_resize(&self, g: &mut Inner<T>) {
        if !self.adaptive || g.window_pops < RESIZE_WINDOW {
            return;
        }
        let nb = g.buckets.len();
        let steal_heavy = g.window_steals * GROW_STEAL_DIV > g.window_pops;
        let touched = g.touched.iter().filter(|&&t| t).count();
        let over_partitioned = !steal_heavy
            && touched > 0
            && touched * SHRINK_TOUCH_DIV <= nb
            && nb >= 2;
        if g.cooldown > 0 {
            // Sitting out a post-resize window: observe, don't act — and
            // don't let this window count toward a shrink streak either.
            g.cooldown -= 1;
            g.shrink_streak = 0;
        } else if steal_heavy && nb * 2 <= self.max_buckets {
            // Replicas were routinely idle-stealing: the partition is too
            // coarse, concentrating traffic on too few home buckets.
            self.rebucket_locked(g, nb * 2);
            g.cooldown = RESIZE_COOLDOWN_WINDOWS;
            g.shrink_streak = 0;
        } else if over_partitioned {
            // The window's pushes touched a small corner of the bucket
            // space: over-partitioned — but only halve (re-concentrating
            // sparse buckets into fuller, more batchable ones) once the
            // signal has persisted across consecutive windows.
            g.shrink_streak += 1;
            if g.shrink_streak >= SHRINK_STREAK_WINDOWS {
                self.rebucket_locked(g, nb / 2);
                g.cooldown = RESIZE_COOLDOWN_WINDOWS;
                g.shrink_streak = 0;
            }
        } else {
            g.shrink_streak = 0;
        }
        g.window_pops = 0;
        g.window_steals = 0;
        g.touched.fill(false);
    }

    /// Drain-and-requeue resize epoch (caller holds the lock): every
    /// queued request is re-mapped from its stored signature into the new
    /// bucket space. Old buckets are drained in index order and each
    /// signature maps to one bucket deterministically, so the FIFO order
    /// of any pair of equal-signature requests is preserved and no
    /// request is dropped (`len` is untouched).
    fn rebucket_locked(&self, g: &mut Inner<T>, new_buckets: usize) {
        let new_buckets = new_buckets.max(1);
        if new_buckets == g.buckets.len() {
            return;
        }
        let old = std::mem::take(&mut g.buckets);
        g.buckets = (0..new_buckets).map(|_| VecDeque::new()).collect();
        for q in old {
            for (sig, item) in q {
                let b = bucket_of(sig, new_buckets);
                g.buckets[b].push_back((sig, item));
            }
        }
        g.touched = vec![false; new_buckets];
        g.next_home.fill(0);
        g.resizes += 1;
    }

    /// Force a resize epoch to `new_buckets` sub-queues (operational
    /// escape hatch + tests; the adaptive path calls the same mechanics).
    pub fn rebucket(&self, new_buckets: usize) {
        let mut g = self.inner.lock().unwrap();
        self.rebucket_locked(&mut g, new_buckets);
    }

    /// Pop one request for `replica`, waiting up to `timeout`; `None` on
    /// timeout or when closed-and-drained. Returns the bucket the request
    /// came from so the batcher can keep draining it (bucket-homogeneous
    /// batches are the whole point).
    pub fn pop_timeout(&self, replica: usize, timeout: Duration)
        -> Option<(usize, T)> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(hit) = self.take_locked(&mut g, replica) {
                self.not_full.notify_one();
                return Some(hit);
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) =
                self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Drain up to `max` requests for `replica` without blocking,
    /// preferring `bucket` (the batch's affinity bucket — also drained
    /// when stolen, so a stolen batch stays bucket-homogeneous) and then
    /// the replica's other home buckets. Never steals: stealing is an
    /// idle-time decision made in [`AffinityRouter::pop_timeout`].
    pub fn drain_affine(&self, replica: usize, bucket: usize,
                        max: usize) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let bucket = bucket % g.buckets.len();
        let order: Vec<usize> = std::iter::once(bucket)
            .chain(
                (0..g.buckets.len())
                    .filter(|&b| b != bucket && self.is_home(b, replica)),
            )
            .collect();
        let mut out = Vec::new();
        for b in order {
            while out.len() < max {
                match g.buckets[b].pop_front() {
                    Some((_sig, x)) => {
                        g.len -= 1;
                        out.push(x);
                    }
                    None => break,
                }
            }
            if out.len() >= max {
                break;
            }
        }
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    /// Current value of the accepted-push counter. Snapshot it *before*
    /// checking the queue for work, then hand it to
    /// [`AffinityRouter::wait_newer_push`]: any push that raced the check
    /// has already advanced the counter, so the wait returns immediately
    /// instead of sleeping through available work.
    pub fn push_seq(&self) -> u64 {
        self.inner.lock().unwrap().pushes
    }

    /// Block until the push counter moves past `seen`, the router closes,
    /// or `timeout` elapses; returns the counter's current value. This is
    /// the batcher's straggler wait: parked on the `not_empty` condvar
    /// (woken by every push) rather than sleep-polling, so an idle
    /// batcher costs nothing and reacts to a push immediately.
    pub fn wait_newer_push(&self, seen: u64, timeout: Duration) -> u64 {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.pushes != seen || g.closed {
                return g.pushes;
            }
            let now = Instant::now();
            if now >= deadline {
                return g.pushes;
            }
            let (guard, _) =
                self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Total queued requests across buckets.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    /// Whether no requests are queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Per-bucket depths + bucket/steal/resize counts, all captured under
    /// one guard (the STATS affinity section). The steal counter is only
    /// ever written while the guard is held, so reading it here is
    /// consistent with the depths.
    pub fn stats(&self) -> RouterStats {
        let g = self.inner.lock().unwrap();
        RouterStats {
            buckets: g.buckets.len(),
            depths: g.buckets.iter().map(VecDeque::len).collect(),
            steals: self.steals.load(Ordering::Relaxed),
            resizes: g.resizes,
        }
    }

    /// Total pops that took a request from a non-home bucket.
    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    /// Completed resize epochs since construction.
    pub fn resizes(&self) -> u64 {
        self.inner.lock().unwrap().resizes
    }

    /// Close the router; producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Whether [`AffinityRouter::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;
    use std::sync::Arc;

    #[test]
    fn signature_ignores_padding_and_is_stable() {
        let a = [1, 5, 6, 9, 2, 0, 0, 0];
        let b = [1, 5, 6, 9, 2, 0, 0, 0, 0, 0, 0, 0];
        assert_eq!(signature(&a, 32), signature(&b, 32),
                   "pad tail must not change the signature");
        assert_eq!(signature(&a, 32), signature(&a, 32));
        assert_eq!(signature(&[0, 0, 0], 32), 0, "all-pad sketches to 0");
        // Single-token requests still get a well-defined sketch.
        assert_ne!(signature(&[7, 0, 0], 32), signature(&[9, 0, 0], 32));
    }

    #[test]
    fn signature_prefix_len_is_a_knob() {
        // Pairs sharing their first 8 tokens: a short prefix cannot tell
        // them apart (always), a long one usually can — a single pair
        // keeps a ~|shared|/|union| chance of an honest min-hash
        // collision, so demand a clear majority across many pairs.
        let mut long_diverged = 0;
        for k in 0..16 {
            let a: Vec<i32> =
                (0..30).map(|j| 10 + 83 * k + j).collect();
            let mut b = a.clone();
            for t in b.iter_mut().skip(8) {
                *t += 500;
            }
            assert_eq!(signature(&a, 8), signature(&b, 8),
                       "identical 8-prefixes must sketch alike at len 8");
            if signature(&a, 30) != signature(&b, 30) {
                long_diverged += 1;
            }
        }
        assert!(long_diverged >= 10,
                "full-length signatures separated only \
                 {long_diverged}/16 pairs");
        // A zero length clamps to one token rather than panicking.
        let a = [7, 9, 11];
        assert_eq!(signature(&a, 0), signature(&[7], 1));
    }

    #[test]
    fn signature_separates_unrelated_prefixes() {
        let a: Vec<i32> = (10..30).collect();
        let b: Vec<i32> = (200..220).collect();
        assert_ne!(signature(&a, 32), signature(&b, 32));
        assert_eq!(bucket_for(&a, 1), 0);
        // Unrelated prefixes spread over the bucket space instead of
        // piling into one bucket.
        let used: std::collections::HashSet<usize> = (0..64)
            .map(|k| {
                let seq: Vec<i32> = (0..20).map(|j| 10 + 40 * k + j).collect();
                bucket_for(&seq, 8)
            })
            .collect();
        assert!(used.len() >= 3, "64 topics landed in {} bucket(s)",
                used.len());
    }

    #[test]
    fn signature_survives_small_tail_edits() {
        // Min-hash over 30 shared bigrams: editing the last token changes
        // one bigram, so the minimum (hence the signature) survives with
        // probability ≈ 29/30 per sequence. Demand a large majority across
        // many bases rather than betting on any single fixture.
        let survived = (0..16)
            .filter(|&k| {
                let a: Vec<i32> = (0..31).map(|j| 10 + 97 * k + j).collect();
                let mut b = a.clone();
                *b.last_mut().unwrap() = 7;
                signature(&a, 32) == signature(&b, 32)
            })
            .count();
        assert!(survived >= 10,
                "tail edits changed the signature in {}/16 cases",
                16 - survived);
    }

    /// Satellite fixture: paraphrases (same words, different order) must
    /// collide under the semantic signer where the prefix min-hash
    /// scatters them.
    #[test]
    fn semantic_signer_collides_on_paraphrases_where_prefix_does_not() {
        let mut rng = Pcg32::seeded(77);
        let vocab = 256usize;
        let dim = 16usize;
        let table: Vec<f32> =
            (0..vocab * dim).map(|_| rng.next_gaussian()).collect();
        let sem = Signer::semantic(
            SemanticSketcher::new(&table, vocab, dim, 32).unwrap());
        let pre = Signer::prefix(32);
        assert_eq!(sem.mode_name(), "semantic");
        assert_eq!(pre.mode_name(), "prefix");

        let mut prefix_diverged = 0;
        for k in 0..8 {
            let base: Vec<i32> =
                (0..20).map(|j| 4 + k * 24 + j).collect();
            let mut para = base.clone();
            rng.shuffle(&mut para);
            assert_eq!(sem.sign(&base), sem.sign(&para),
                       "paraphrase {k} broke the semantic signature");
            if pre.sign(&base) != pre.sign(&para) {
                prefix_diverged += 1;
            }
        }
        // A shuffled word order rewrites nearly every bigram, so the
        // min-hash almost always moves; demand a clear majority rather
        // than betting on all eight.
        assert!(prefix_diverged >= 6,
                "prefix min-hash matched {}/8 paraphrases",
                8 - prefix_diverged);
    }

    #[test]
    fn home_bucket_preferred_over_fuller_foreign_bucket() {
        // Buckets 0/2 are home to replica 0, buckets 1/3 to replica 1.
        let r: AffinityRouter<u32> = AffinityRouter::new(4, 2, 64);
        r.try_push(1, 10).unwrap();
        r.try_push(1, 11).unwrap();
        r.try_push(2, 20).unwrap();
        // Replica 0 has home work in bucket 2 — no steal, even though
        // bucket 1 is fuller.
        let (b, x) = r.pop_timeout(0, Duration::from_millis(10)).unwrap();
        assert_eq!((b, x), (2, 20));
        assert_eq!(r.steals(), 0);
        // Replica 1 drains its own bucket.
        let (b, x) = r.pop_timeout(1, Duration::from_millis(10)).unwrap();
        assert_eq!((b, x), (1, 10));
        assert_eq!(r.steals(), 0);
    }

    #[test]
    fn home_buckets_rotate_so_none_starves() {
        // One replica, two home buckets: a deep bucket 0 must not starve
        // the single request in bucket 1 — pops alternate between them.
        let r: AffinityRouter<u32> = AffinityRouter::new(2, 1, 64);
        for i in 0..8 {
            r.try_push(0, i).unwrap();
        }
        r.try_push(1, 100).unwrap();
        let (b1, x1) = r.pop_timeout(0, Duration::from_millis(10)).unwrap();
        let (b2, x2) = r.pop_timeout(0, Duration::from_millis(10)).unwrap();
        assert_eq!((b1, x1), (0, 0), "rotation starts at bucket 0");
        assert_eq!((b2, x2), (1, 100),
                   "the sparse bucket gets its turn next, not after 8 pops");
        let (b3, _) = r.pop_timeout(0, Duration::from_millis(10)).unwrap();
        assert_eq!(b3, 0);
        assert_eq!(r.steals(), 0);
    }

    #[test]
    fn idle_replica_steals_fullest_bucket() {
        let r: AffinityRouter<u32> = AffinityRouter::new(4, 2, 64);
        r.try_push(0, 1).unwrap(); // home of replica 0
        r.try_push(0, 2).unwrap();
        // Replica 1 has no home work: it must steal rather than starve.
        let (b, x) = r.pop_timeout(1, Duration::from_millis(10)).unwrap();
        assert_eq!((b, x), (0, 1));
        assert_eq!(r.steals(), 1);
    }

    #[test]
    fn drain_affine_prefers_hint_then_home_and_never_steals() {
        let r: AffinityRouter<u32> = AffinityRouter::new(4, 2, 64);
        r.try_push(0, 1).unwrap();
        r.try_push(0, 2).unwrap();
        r.try_push(2, 3).unwrap(); // replica 0's other home bucket
        r.try_push(1, 9).unwrap(); // replica 1's bucket: must stay queued
        let got = r.drain_affine(0, 0, 10);
        assert_eq!(got, vec![1, 2, 3], "hint bucket first, then home");
        assert_eq!(r.len(), 1, "foreign bucket must not be drained");
        assert_eq!(r.steals(), 0);
        // max is respected mid-bucket.
        r.try_push(2, 4).unwrap();
        r.try_push(2, 5).unwrap();
        assert_eq!(r.drain_affine(0, 2, 1), vec![4]);
    }

    #[test]
    fn global_backpressure_across_buckets() {
        let r: AffinityRouter<u32> = AffinityRouter::new(4, 2, 2);
        r.try_push(0, 1).unwrap();
        r.try_push(3, 2).unwrap();
        assert!(r.try_push(1, 3).is_err(), "capacity is global");
        r.drain_affine(0, 0, 1);
        r.try_push(1, 3).unwrap();
    }

    #[test]
    fn close_unblocks_and_rejects() {
        let r: Arc<AffinityRouter<u32>> = Arc::new(AffinityRouter::new(2, 1, 4));
        let r2 = r.clone();
        let h = std::thread::spawn(move || {
            r2.pop_timeout(0, Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(20));
        r.close();
        assert!(h.join().unwrap().is_none());
        assert!(r.try_push(0, 1).is_err());
        assert!(r.is_closed());
    }

    #[test]
    fn pop_timeout_expires() {
        let r: AffinityRouter<u32> = AffinityRouter::new(2, 2, 4);
        let t0 = Instant::now();
        assert!(r.pop_timeout(0, Duration::from_millis(30)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn skewed_traffic_starves_no_consumer() {
        // Everything lands in one bucket (home to replica 0 only); two
        // concurrent consumers must still drain the router completely —
        // anything replica 1 receives can only arrive via the steal path.
        let r: Arc<AffinityRouter<usize>> =
            Arc::new(AffinityRouter::new(4, 2, 1024));
        let mut handles = Vec::new();
        for replica in 0..2usize {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = 0usize;
                while r.pop_timeout(replica, Duration::from_millis(500))
                    .is_some()
                {
                    got += 1;
                }
                got
            }));
        }
        // Produce gradually so both consumers engage while items flow.
        for i in 0..200 {
            r.push(0, i).unwrap();
            if i % 16 == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        r.close();
        let counts: Vec<usize> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(counts.iter().sum::<usize>(), 200, "all items consumed");
        assert!(r.is_empty());
        assert!(r.steals() as usize >= counts[1],
                "replica 1 can only be fed by steals");
    }

    #[test]
    fn stats_snapshot_reports_depths() {
        let r: AffinityRouter<u32> = AffinityRouter::new(3, 1, 16);
        r.try_push(0, 1).unwrap();
        r.try_push(2, 2).unwrap();
        r.try_push(2, 3).unwrap();
        let s = r.stats();
        assert_eq!(s.buckets, 3);
        assert_eq!(s.depths, vec![1, 0, 2]);
        assert_eq!(s.steals, 0);
        assert_eq!(s.resizes, 0);
    }

    /// Satellite regression: a stats snapshot must be internally
    /// consistent — depths sliced under the same guard as the bucket
    /// count, nothing lost — even while resize epochs run concurrently.
    #[test]
    fn stats_snapshot_consistent_across_concurrent_resizes() {
        use std::sync::atomic::AtomicBool;

        let r: Arc<AffinityRouter<u32>> =
            Arc::new(AffinityRouter::new(4, 2, 4096));
        for i in 0..256u32 {
            r.try_push((i % 13) as u64, i).unwrap();
        }
        let stop = Arc::new(AtomicBool::new(false));
        let resizer = {
            let (r, stop) = (r.clone(), stop.clone());
            std::thread::spawn(move || {
                let mut n = 2usize;
                while !stop.load(Ordering::Relaxed) {
                    r.rebucket(n);
                    n = if n == 2 { 16 } else { 2 };
                }
            })
        };
        for _ in 0..2000 {
            let s = r.stats();
            assert_eq!(s.depths.len(), s.buckets,
                       "depths and bucket count torn across a resize");
            assert_eq!(s.depths.iter().sum::<usize>(), 256,
                       "every queued request visible in one snapshot");
        }
        stop.store(true, Ordering::Relaxed);
        resizer.join().unwrap();
    }

    /// Satellite regression: a resize epoch must preserve per-signature
    /// FIFO order and lose no queued request — grow and shrink both.
    #[test]
    fn rebucket_preserves_fifo_and_loses_nothing() {
        let r: AffinityRouter<(u64, u32)> = AffinityRouter::new(4, 1, 4096);
        // 7 signature streams over 4 buckets: some buckets hold several
        // streams (the interleavings a resize must not reorder).
        for i in 0..64u32 {
            let sig = (i % 7) as u64;
            r.try_push(sig, (sig, i)).unwrap();
        }
        r.rebucket(8); // grow
        assert_eq!(r.len(), 64, "grow lost requests");
        assert_eq!(r.num_buckets(), 8);
        r.rebucket(2); // shrink
        assert_eq!(r.len(), 64, "shrink lost requests");
        assert_eq!(r.num_buckets(), 2);
        assert_eq!(r.resizes(), 2);

        // Drain everything; within each signature stream the values must
        // come out in push order.
        let mut last: std::collections::HashMap<u64, u32> =
            std::collections::HashMap::new();
        let mut got = 0usize;
        while let Some((_, (sig, v))) =
            r.pop_timeout(0, Duration::from_millis(10))
        {
            if let Some(&prev) = last.get(&sig) {
                assert!(v > prev,
                        "signature {sig} reordered: {v} after {prev}");
            }
            last.insert(sig, v);
            got += 1;
        }
        assert_eq!(got, 64, "drain lost requests");
    }

    /// Adaptive growth: a steal-heavy window (one hot bucket, an idle
    /// replica feeding off it) must double the bucket space up to the cap.
    #[test]
    fn adaptive_grows_under_steal_pressure() {
        let r: AffinityRouter<u32> =
            AffinityRouter::new(2, 2, 4096).with_adaptive(true, 16);
        assert_eq!(r.num_buckets(), 2);
        // All traffic in bucket 0 (home to replica 0); replica 1 can only
        // steal, so every window is ~50% steals.
        for i in 0..400u32 {
            r.try_push(0, i).unwrap();
            let replica = (i % 2) as usize;
            assert!(r.pop_timeout(replica, Duration::from_millis(10))
                .is_some());
        }
        assert!(r.resizes() >= 1, "steal pressure never triggered a grow");
        assert!(r.num_buckets() > 2,
                "bucket space did not grow: {}", r.num_buckets());
        assert!(r.num_buckets() <= 16, "growth exceeded the cap");
    }

    /// Adaptive shrink: when pushes only ever touch a corner of the
    /// bucket space and nobody steals, the space halves — patiently (a
    /// shrink needs the signal to persist for two evaluated windows, and
    /// every resize is followed by a cooldown window).
    #[test]
    fn adaptive_shrinks_overpartitioned_space() {
        let r: AffinityRouter<u32> =
            AffinityRouter::new(16, 1, 4096).with_adaptive(true, 16);
        // One replica (pops are never steals), traffic in 2 of 16 buckets.
        // Window schedule: streak, shrink 16→8, cooldown, streak,
        // shrink 8→4, cooldown, floor — 7+ windows of 128 pops.
        for i in 0..1024u32 {
            r.try_push((i % 2) as u64, i).unwrap();
            assert!(r.pop_timeout(0, Duration::from_millis(10)).is_some());
        }
        assert_eq!(r.resizes(), 2,
                   "over-partitioning must trigger exactly the two shrinks");
        assert_eq!(r.num_buckets(), 4,
                   "16 → 8 → 4, then 2 touched × 4 > 4 holds the floor");
    }

    /// Satellite regression: mixed traffic that alternates steal-heavy
    /// and over-concentrated windows made the naive controller ping-pong
    /// double→halve every window (a freshly doubled space trivially
    /// satisfies the shrink test). With cooldown + asymmetric shrink
    /// damping, resizes are bounded by the monotone growth path.
    #[test]
    fn adaptive_damps_oscillating_mixed_traffic() {
        let r: AffinityRouter<u32> =
            AffinityRouter::new(2, 2, 8192).with_adaptive(true, 16);
        // 16 alternating 128-pop phases, all traffic in bucket 0 (home to
        // replica 0). Odd phases pop from replica 1 only — pure steals
        // (the grow trigger); even phases pop from replica 0 only — no
        // steals and one touched bucket (the shrink trigger).
        for phase in 0..16 {
            let replica = phase % 2;
            for i in 0..128u32 {
                r.try_push(0, i).unwrap();
                assert!(r
                    .pop_timeout(replica, Duration::from_millis(10))
                    .is_some());
            }
        }
        // Unbounded ping-pong would resize ~once per phase (≈14 here);
        // the damped controller only walks the growth path 2→4→8→16.
        assert!(r.resizes() <= 3,
                "hysteresis failed to damp ping-pong: {} resizes",
                r.resizes());
        assert!(r.num_buckets() >= 2 && r.num_buckets() <= 16);
        assert!(r.is_empty(), "phases must drain completely");
    }

    /// `with_adaptive(false, …)` keeps the fixed-bucket behaviour.
    #[test]
    fn non_adaptive_router_never_resizes() {
        let r: AffinityRouter<u32> =
            AffinityRouter::new(2, 2, 4096).with_adaptive(false, 16);
        for i in 0..300u32 {
            r.try_push(0, i).unwrap();
            let replica = (i % 2) as usize;
            assert!(r.pop_timeout(replica, Duration::from_millis(10))
                .is_some());
        }
        assert_eq!(r.resizes(), 0);
        assert_eq!(r.num_buckets(), 2);
    }
}
