//! Similarity-affinity request routing: bucketed sub-queues in front of
//! the batchers, so semantically similar requests land in the *same*
//! batch instead of being scattered across replicas by a single MPMC
//! queue.
//!
//! The paper's core observation (and AttnCache's, at LLM-prefill scale)
//! is that inference traffic is semantically clustered. PR 2's
//! intra-batch dedup and the online tier's locality only pay off when a
//! cluster's requests actually ride in one batch — this module makes that
//! happen without any model forward:
//!
//! * [`signature`] — a cheap min-hash sketch over token-bigram n-grams of
//!   the request's non-pad prefix. Two requests sharing most of their
//!   prefix bigrams share the minimum with high probability (classic
//!   min-wise LSH), so near-duplicate prompts sketch to the same value
//!   while unrelated prompts scatter uniformly.
//! * [`bucket_for`] — signature → bucket index (re-mixed so the min-hash
//!   skew doesn't bias low buckets).
//! * [`AffinityRouter`] — a bounded set of per-bucket FIFO sub-queues
//!   behind one mutex/condvar pair. Bucket `b` is *home* to replica
//!   `b % replicas`; a batcher round-robins over its non-empty home
//!   buckets (so a hot bucket cannot starve a sparse sibling) and, when
//!   it has no home work, **steals** from the fullest bucket overall so
//!   skewed traffic never starves a replica (or leaves one idle).
//!   Capacity is global across buckets — the admission-control semantics
//!   of the old `BoundedQueue` are preserved.
//!
//! With `buckets = 1` the router degenerates to the plain shared FIFO
//! queue (`--no-affinity`): bucket 0 is home to replica 0 and every other
//! replica's pop counts as a steal, which is exactly what "no affinity"
//! means operationally.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::{Error, Result};

/// Non-pad prefix tokens fed into the signature sketch. Long enough to
/// tell topics apart, short enough that signing is O(1) per request.
const SIG_PREFIX: usize = 32;

/// SplitMix64 finalizer: cheap, well-distributed 64-bit mixing.
fn mix(h: u64) -> u64 {
    let mut z = h.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Cheap request signature: the min-hash of the token-bigram set of the
/// first `SIG_PREFIX` (32) non-pad tokens. No model forward, no float
/// math — O(prefix) integer hashing at enqueue time.
///
/// Property (min-wise hashing): for two requests the probability that
/// their signatures collide equals the Jaccard similarity of their bigram
/// sets, so small edits (a word changed near the tail) usually preserve
/// the signature while unrelated prompts diverge.
pub fn signature(ids: &[i32]) -> u64 {
    let mut prev: Option<u64> = None;
    let mut min = u64::MAX;
    let mut taken = 0usize;
    for &t in ids {
        if t == crate::data::tokenizer::PAD {
            continue;
        }
        let tok = t as u32 as u64;
        if let Some(p) = prev {
            min = min.min(mix((p << 32) | tok));
        }
        prev = Some(tok);
        taken += 1;
        if taken >= SIG_PREFIX {
            break;
        }
    }
    match (min, prev) {
        (u64::MAX, Some(only)) => mix(only), // single-token request
        (u64::MAX, None) => 0,               // all-pad request
        (m, _) => m,
    }
}

/// Affinity bucket for a request's token ids: `signature` re-mixed modulo
/// the bucket count (a raw min-hash is a minimum, hence skewed small —
/// the extra mix spreads it uniformly over buckets).
pub fn bucket_for(ids: &[i32], buckets: usize) -> usize {
    if buckets <= 1 {
        return 0;
    }
    (mix(signature(ids)) % buckets as u64) as usize
}

struct Inner<T> {
    buckets: Vec<VecDeque<T>>,
    len: usize,
    closed: bool,
    /// Per-replica rotation cursor over home buckets: the next pop scans
    /// from here, so every non-empty home bucket gets a turn (a deepest-
    /// first policy would let one hot bucket starve a sparse sibling
    /// indefinitely under sustained skew).
    next_home: Vec<usize>,
}

/// Snapshot of the router's observable state (for STATS reporting).
#[derive(Debug, Clone)]
pub struct RouterStats {
    /// Queue depth per bucket at snapshot time.
    pub depths: Vec<usize>,
    /// Total pops that took a request from a non-home bucket.
    pub steals: u64,
}

/// Bounded affinity-bucketed request queue shared between connection
/// handlers (producers) and the per-replica batcher threads (consumers).
///
/// All operations run under one mutex, so any number of producers and
/// consumers is safe; the capacity (`depth`) is global across buckets, so
/// backpressure behaves exactly like the old single `BoundedQueue`.
pub struct AffinityRouter<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    depth: usize,
    replicas: usize,
    num_buckets: usize,
    steals: AtomicU64,
}

impl<T> AffinityRouter<T> {
    /// Router with `buckets` sub-queues serving `replicas` batchers and a
    /// global capacity of `depth` requests (each clamped to at least 1).
    pub fn new(buckets: usize, replicas: usize, depth: usize) -> Self {
        let buckets = buckets.max(1);
        let replicas = replicas.max(1);
        AffinityRouter {
            inner: Mutex::new(Inner {
                buckets: (0..buckets).map(|_| VecDeque::new()).collect(),
                len: 0,
                closed: false,
                next_home: vec![0; replicas],
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            depth: depth.max(1),
            replicas,
            num_buckets: buckets,
            steals: AtomicU64::new(0),
        }
    }

    /// Number of affinity buckets (fixed at construction; lock-free —
    /// the request handlers read it on every enqueue).
    pub fn num_buckets(&self) -> usize {
        self.num_buckets
    }

    /// Is `bucket` one of `replica`'s home buckets?
    fn is_home(&self, bucket: usize, replica: usize) -> bool {
        bucket % self.replicas == replica % self.replicas
    }

    /// Non-blocking push into `bucket` (modulo the bucket count); `Err`
    /// when the router is full or closed (caller sheds load).
    pub fn try_push(&self, bucket: usize, item: T) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(Error::serving("queue closed"));
        }
        if g.len >= self.depth {
            return Err(Error::serving("queue full"));
        }
        let nb = g.buckets.len();
        g.buckets[bucket % nb].push_back(item);
        g.len += 1;
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push into `bucket` (waits for space); `Err` when closed.
    pub fn push(&self, bucket: usize, item: T) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(Error::serving("queue closed"));
            }
            if g.len < self.depth {
                let nb = g.buckets.len();
                g.buckets[bucket % nb].push_back(item);
                g.len += 1;
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Take one request for `replica` under the lock: the next non-empty
    /// home bucket in rotation order first (round-robin, so a sparse home
    /// bucket cannot be starved by a hot sibling that keeps refilling);
    /// otherwise steal from the fullest bucket overall (the replica is
    /// idle — leaving work queued would strand it under skewed traffic;
    /// the stolen bucket's own home replica round-robins over it, so
    /// fullest-first is safe here). Returns the source bucket with the
    /// item.
    fn take_locked(&self, g: &mut Inner<T>, replica: usize)
        -> Option<(usize, T)> {
        let nb = g.buckets.len();
        let r = replica % self.replicas;
        let start = g.next_home[r];
        let mut home: Option<usize> = None;
        for k in 0..nb {
            let b = (start + k) % nb;
            if self.is_home(b, replica) && !g.buckets[b].is_empty() {
                home = Some(b);
                break;
            }
        }
        let (bucket, stolen) = match home {
            Some(b) => {
                g.next_home[r] = (b + 1) % nb;
                (b, false)
            }
            None => {
                let mut best: Option<usize> = None;
                for b in 0..nb {
                    if !g.buckets[b].is_empty()
                        && best.map_or(true, |x| {
                            g.buckets[b].len() > g.buckets[x].len()
                        })
                    {
                        best = Some(b);
                    }
                }
                (best?, true)
            }
        };
        let item = g.buckets[bucket].pop_front()?;
        g.len -= 1;
        if stolen {
            self.steals.fetch_add(1, Ordering::Relaxed);
        }
        Some((bucket, item))
    }

    /// Pop one request for `replica`, waiting up to `timeout`; `None` on
    /// timeout or when closed-and-drained. Returns the bucket the request
    /// came from so the batcher can keep draining it (bucket-homogeneous
    /// batches are the whole point).
    pub fn pop_timeout(&self, replica: usize, timeout: Duration)
        -> Option<(usize, T)> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(hit) = self.take_locked(&mut g, replica) {
                self.not_full.notify_one();
                return Some(hit);
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) =
                self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Drain up to `max` requests for `replica` without blocking,
    /// preferring `bucket` (the batch's affinity bucket — also drained
    /// when stolen, so a stolen batch stays bucket-homogeneous) and then
    /// the replica's other home buckets. Never steals: stealing is an
    /// idle-time decision made in [`AffinityRouter::pop_timeout`].
    pub fn drain_affine(&self, replica: usize, bucket: usize,
                        max: usize) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let bucket = bucket % g.buckets.len();
        let order: Vec<usize> = std::iter::once(bucket)
            .chain(
                (0..g.buckets.len())
                    .filter(|&b| b != bucket && self.is_home(b, replica)),
            )
            .collect();
        let mut out = Vec::new();
        for b in order {
            while out.len() < max {
                match g.buckets[b].pop_front() {
                    Some(x) => {
                        g.len -= 1;
                        out.push(x);
                    }
                    None => break,
                }
            }
            if out.len() >= max {
                break;
            }
        }
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    /// Total queued requests across buckets.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    /// Whether no requests are queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Per-bucket depths + steal count (the STATS affinity section).
    pub fn stats(&self) -> RouterStats {
        let g = self.inner.lock().unwrap();
        RouterStats {
            depths: g.buckets.iter().map(VecDeque::len).collect(),
            steals: self.steals.load(Ordering::Relaxed),
        }
    }

    /// Total pops that took a request from a non-home bucket.
    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    /// Close the router; producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Whether [`AffinityRouter::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn signature_ignores_padding_and_is_stable() {
        let a = [1, 5, 6, 9, 2, 0, 0, 0];
        let b = [1, 5, 6, 9, 2, 0, 0, 0, 0, 0, 0, 0];
        assert_eq!(signature(&a), signature(&b),
                   "pad tail must not change the signature");
        assert_eq!(signature(&a), signature(&a));
        assert_eq!(signature(&[0, 0, 0]), 0, "all-pad sketches to 0");
        // Single-token requests still get a well-defined sketch.
        assert_ne!(signature(&[7, 0, 0]), signature(&[9, 0, 0]));
    }

    #[test]
    fn signature_separates_unrelated_prefixes() {
        let a: Vec<i32> = (10..30).collect();
        let b: Vec<i32> = (200..220).collect();
        assert_ne!(signature(&a), signature(&b));
        assert_eq!(bucket_for(&a, 1), 0);
        // Unrelated prefixes spread over the bucket space instead of
        // piling into one bucket.
        let used: std::collections::HashSet<usize> = (0..64)
            .map(|k| {
                let seq: Vec<i32> = (0..20).map(|j| 10 + 40 * k + j).collect();
                bucket_for(&seq, 8)
            })
            .collect();
        assert!(used.len() >= 3, "64 topics landed in {} bucket(s)",
                used.len());
    }

    #[test]
    fn signature_survives_small_tail_edits() {
        // Min-hash over 30 shared bigrams: editing the last token changes
        // one bigram, so the minimum (hence the signature) survives with
        // probability ≈ 29/30 per sequence. Demand a large majority across
        // many bases rather than betting on any single fixture.
        let survived = (0..16)
            .filter(|&k| {
                let a: Vec<i32> = (0..31).map(|j| 10 + 97 * k + j).collect();
                let mut b = a.clone();
                *b.last_mut().unwrap() = 7;
                signature(&a) == signature(&b)
            })
            .count();
        assert!(survived >= 10,
                "tail edits changed the signature in {}/16 cases",
                16 - survived);
    }

    #[test]
    fn home_bucket_preferred_over_fuller_foreign_bucket() {
        // Buckets 0/2 are home to replica 0, buckets 1/3 to replica 1.
        let r: AffinityRouter<u32> = AffinityRouter::new(4, 2, 64);
        r.try_push(1, 10).unwrap();
        r.try_push(1, 11).unwrap();
        r.try_push(2, 20).unwrap();
        // Replica 0 has home work in bucket 2 — no steal, even though
        // bucket 1 is fuller.
        let (b, x) = r.pop_timeout(0, Duration::from_millis(10)).unwrap();
        assert_eq!((b, x), (2, 20));
        assert_eq!(r.steals(), 0);
        // Replica 1 drains its own bucket.
        let (b, x) = r.pop_timeout(1, Duration::from_millis(10)).unwrap();
        assert_eq!((b, x), (1, 10));
        assert_eq!(r.steals(), 0);
    }

    #[test]
    fn home_buckets_rotate_so_none_starves() {
        // One replica, two home buckets: a deep bucket 0 must not starve
        // the single request in bucket 1 — pops alternate between them.
        let r: AffinityRouter<u32> = AffinityRouter::new(2, 1, 64);
        for i in 0..8 {
            r.try_push(0, i).unwrap();
        }
        r.try_push(1, 100).unwrap();
        let (b1, x1) = r.pop_timeout(0, Duration::from_millis(10)).unwrap();
        let (b2, x2) = r.pop_timeout(0, Duration::from_millis(10)).unwrap();
        assert_eq!((b1, x1), (0, 0), "rotation starts at bucket 0");
        assert_eq!((b2, x2), (1, 100),
                   "the sparse bucket gets its turn next, not after 8 pops");
        let (b3, _) = r.pop_timeout(0, Duration::from_millis(10)).unwrap();
        assert_eq!(b3, 0);
        assert_eq!(r.steals(), 0);
    }

    #[test]
    fn idle_replica_steals_fullest_bucket() {
        let r: AffinityRouter<u32> = AffinityRouter::new(4, 2, 64);
        r.try_push(0, 1).unwrap(); // home of replica 0
        r.try_push(0, 2).unwrap();
        // Replica 1 has no home work: it must steal rather than starve.
        let (b, x) = r.pop_timeout(1, Duration::from_millis(10)).unwrap();
        assert_eq!((b, x), (0, 1));
        assert_eq!(r.steals(), 1);
    }

    #[test]
    fn drain_affine_prefers_hint_then_home_and_never_steals() {
        let r: AffinityRouter<u32> = AffinityRouter::new(4, 2, 64);
        r.try_push(0, 1).unwrap();
        r.try_push(0, 2).unwrap();
        r.try_push(2, 3).unwrap(); // replica 0's other home bucket
        r.try_push(1, 9).unwrap(); // replica 1's bucket: must stay queued
        let got = r.drain_affine(0, 0, 10);
        assert_eq!(got, vec![1, 2, 3], "hint bucket first, then home");
        assert_eq!(r.len(), 1, "foreign bucket must not be drained");
        assert_eq!(r.steals(), 0);
        // max is respected mid-bucket.
        r.try_push(2, 4).unwrap();
        r.try_push(2, 5).unwrap();
        assert_eq!(r.drain_affine(0, 2, 1), vec![4]);
    }

    #[test]
    fn global_backpressure_across_buckets() {
        let r: AffinityRouter<u32> = AffinityRouter::new(4, 2, 2);
        r.try_push(0, 1).unwrap();
        r.try_push(3, 2).unwrap();
        assert!(r.try_push(1, 3).is_err(), "capacity is global");
        r.drain_affine(0, 0, 1);
        r.try_push(1, 3).unwrap();
    }

    #[test]
    fn close_unblocks_and_rejects() {
        let r: Arc<AffinityRouter<u32>> = Arc::new(AffinityRouter::new(2, 1, 4));
        let r2 = r.clone();
        let h = std::thread::spawn(move || {
            r2.pop_timeout(0, Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(20));
        r.close();
        assert!(h.join().unwrap().is_none());
        assert!(r.try_push(0, 1).is_err());
        assert!(r.is_closed());
    }

    #[test]
    fn pop_timeout_expires() {
        let r: AffinityRouter<u32> = AffinityRouter::new(2, 2, 4);
        let t0 = Instant::now();
        assert!(r.pop_timeout(0, Duration::from_millis(30)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn skewed_traffic_starves_no_consumer() {
        // Everything lands in one bucket (home to replica 0 only); two
        // concurrent consumers must still drain the router completely —
        // anything replica 1 receives can only arrive via the steal path.
        let r: Arc<AffinityRouter<usize>> =
            Arc::new(AffinityRouter::new(4, 2, 1024));
        let mut handles = Vec::new();
        for replica in 0..2usize {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = 0usize;
                while r.pop_timeout(replica, Duration::from_millis(500))
                    .is_some()
                {
                    got += 1;
                }
                got
            }));
        }
        // Produce gradually so both consumers engage while items flow.
        for i in 0..200 {
            r.push(0, i).unwrap();
            if i % 16 == 0 {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        r.close();
        let counts: Vec<usize> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(counts.iter().sum::<usize>(), 200, "all items consumed");
        assert!(r.is_empty());
        assert!(r.steals() as usize >= counts[1],
                "replica 1 can only be fed by steals");
    }

    #[test]
    fn stats_snapshot_reports_depths() {
        let r: AffinityRouter<u32> = AffinityRouter::new(3, 1, 16);
        r.try_push(0, 1).unwrap();
        r.try_push(2, 2).unwrap();
        r.try_push(2, 3).unwrap();
        let s = r.stats();
        assert_eq!(s.depths, vec![1, 0, 2]);
        assert_eq!(s.steals, 0);
    }
}
