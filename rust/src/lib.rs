//! AttMemo: accelerating transformer self-attention with memoization on big
//! memory systems.
//!
//! Reproduction of Feng et al., *AttMemo* (2023) as a three-layer
//! Rust + JAX + Pallas serving stack: Pallas kernels (L1) and JAX model
//! graphs (L2) are AOT-lowered to HLO text at build time; this crate (L3)
//! loads the artifacts through PJRT and owns the entire request path —
//! routing, dynamic batching, the attention/index databases, selective
//! memoization, and metrics. Python never runs at request time.

pub mod bench_support;
pub mod cli;
pub mod config;
pub mod data;
pub mod error;
pub mod eval;
pub mod kernels;
pub mod memo;
pub mod memtier;
pub mod model;
pub mod runtime;
pub mod serving;
pub mod tensor;
pub mod util;

pub use error::{Error, Result};

/// CLI entrypoint used by `rust/src/main.rs` and integration tests.
pub fn run_cli(args: &[String]) -> Result<()> {
    cli::run(args)
}
