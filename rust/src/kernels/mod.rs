//! The unified host kernel layer.
//!
//! Every scalar inner loop the request path leans on — index distances,
//! Eq. 1 similarity, signature pooling, and the host-side attention
//! fallback — routes through this module so there is exactly one place
//! where vector width, dispatch and tiling decisions live.
//!
//! * [`simd`] holds runtime-dispatched vector primitives (dot, squared
//!   L2, L1, axpy, max/sum reductions). On x86_64 an explicit AVX2 path
//!   is selected when the CPU supports it; a portable scalar fallback is
//!   always available and can be *forced* for A/B runs via
//!   [`set_scalar_kernels`], the `--scalar-kernels` CLI flag, or the
//!   `ATTMEMO_SCALAR_KERNELS=1` environment variable (read once at first
//!   kernel use; the setter overrides it afterwards).
//! * [`attention`] holds the blocked, online-softmax host attention
//!   kernel (FlashAttention-style tiling) used by the miss-path
//!   fallback in `model::forward` and the cold-workload benches.
//!
//! Dispatch is a process-global switch rather than a per-call parameter:
//! the primitives sit under loops too hot to thread a flag through, and
//! A/B consumers (benches, the CI scalar leg) want to flip *every* call
//! site at once.

#![warn(missing_docs)]

pub mod attention;
pub mod simd;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Once;

static ENV_INIT: Once = Once::new();
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Environment variable that forces the scalar fallback at process
/// start (any non-empty value other than `0`/`false` counts).
pub const SCALAR_KERNELS_ENV: &str = "ATTMEMO_SCALAR_KERNELS";

fn ensure_env_init() {
    ENV_INIT.call_once(|| {
        if let Ok(v) = std::env::var(SCALAR_KERNELS_ENV) {
            let v = v.trim();
            let on = !v.is_empty()
                && !v.eq_ignore_ascii_case("0")
                && !v.eq_ignore_ascii_case("false");
            FORCE_SCALAR.store(on, Ordering::Relaxed);
        }
    });
}

/// Force (or un-force) the scalar fallback for every dispatched
/// primitive in this process. Used by `MemoConfig::scalar_kernels`
/// plumbing and by the bench A/B arms.
pub fn set_scalar_kernels(force: bool) {
    ensure_env_init();
    FORCE_SCALAR.store(force, Ordering::Relaxed);
}

/// Whether the scalar fallback is currently forced (flag or env).
pub fn scalar_forced() -> bool {
    ensure_env_init();
    FORCE_SCALAR.load(Ordering::Relaxed)
}

/// Whether the AVX2 fast paths exist *and* the running CPU supports
/// them. `false` on non-x86_64 targets.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Whether dispatched calls will take the vector path right now.
pub fn vectorized_active() -> bool {
    avx2_available() && !scalar_forced()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_switch_round_trips() {
        let before = scalar_forced();
        set_scalar_kernels(true);
        assert!(scalar_forced());
        assert!(!vectorized_active());
        set_scalar_kernels(false);
        assert!(!scalar_forced());
        set_scalar_kernels(before);
    }
}
