//! Runtime-dispatched vector primitives.
//!
//! Each primitive comes in three forms:
//!
//! * the dispatched entry point (`dot`, `l2_sq`, …) — picks the AVX2
//!   path when [`crate::kernels::vectorized_active`] says so, else the
//!   scalar fallback;
//! * an always-available `_scalar` variant (4-way unrolled so the
//!   autovectorizer can still use the SSE2 baseline);
//! * on x86_64, a safe `_avx2` probe returning `None` when the CPU
//!   lacks AVX2, so differential tests can pin the vector path without
//!   toggling the process-global switch.
//!
//! Length handling matches the historical `tensor::ops` kernels: binary
//! primitives operate over `min(a.len(), b.len())` elements, and every
//! path handles remainder lanes (lengths not a multiple of the vector
//! width) with a scalar tail.

// ---------------------------------------------------------------- dot --

/// Dot product `Σ a[i]·b[i]` over the common prefix of `a` and `b`.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if crate::kernels::vectorized_active() {
            // SAFETY: AVX2 support verified by `vectorized_active`.
            return unsafe { avx2::dot(a, b) };
        }
    }
    dot_scalar(a, b)
}

/// Scalar dot product (4-way unrolled).
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// AVX2 dot product; `None` when the CPU lacks AVX2.
#[cfg(target_arch = "x86_64")]
pub fn dot_avx2(a: &[f32], b: &[f32]) -> Option<f32> {
    if !crate::kernels::avx2_available() {
        return None;
    }
    // SAFETY: AVX2 support checked just above.
    Some(unsafe { avx2::dot(a, b) })
}

// -------------------------------------------------------------- l2_sq --

/// Squared L2 distance `Σ (a[i] − b[i])²` (index hot loop).
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if crate::kernels::vectorized_active() {
            // SAFETY: AVX2 support verified by `vectorized_active`.
            return unsafe { avx2::l2_sq(a, b) };
        }
    }
    l2_sq_scalar(a, b)
}

/// Scalar squared L2 distance (4-way unrolled).
#[inline]
pub fn l2_sq_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j];
        let d1 = a[j + 1] - b[j + 1];
        let d2 = a[j + 2] - b[j + 2];
        let d3 = a[j + 3] - b[j + 3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// AVX2 squared L2 distance; `None` when the CPU lacks AVX2.
#[cfg(target_arch = "x86_64")]
pub fn l2_sq_avx2(a: &[f32], b: &[f32]) -> Option<f32> {
    if !crate::kernels::avx2_available() {
        return None;
    }
    // SAFETY: AVX2 support checked just above.
    Some(unsafe { avx2::l2_sq(a, b) })
}

// -------------------------------------------------------- l1_distance --

/// L1 distance `Σ |a[i] − b[i]|` (Eq. 1 total-variation inner loop).
#[inline]
pub fn l1_distance(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if crate::kernels::vectorized_active() {
            // SAFETY: AVX2 support verified by `vectorized_active`.
            return unsafe { avx2::l1(a, b) };
        }
    }
    l1_distance_scalar(a, b)
}

/// Scalar L1 distance (4-way unrolled).
#[inline]
pub fn l1_distance_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        s0 += (a[j] - b[j]).abs();
        s1 += (a[j + 1] - b[j + 1]).abs();
        s2 += (a[j + 2] - b[j + 2]).abs();
        s3 += (a[j + 3] - b[j + 3]).abs();
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += (a[j] - b[j]).abs();
    }
    s
}

/// AVX2 L1 distance; `None` when the CPU lacks AVX2.
#[cfg(target_arch = "x86_64")]
pub fn l1_distance_avx2(a: &[f32], b: &[f32]) -> Option<f32> {
    if !crate::kernels::avx2_available() {
        return None;
    }
    // SAFETY: AVX2 support checked just above.
    Some(unsafe { avx2::l1(a, b) })
}

// --------------------------------------------------------------- axpy --

/// `y[i] += alpha · x[i]` over the common prefix (pooling / attention
/// accumulate).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        if crate::kernels::vectorized_active() {
            // SAFETY: AVX2 support verified by `vectorized_active`.
            unsafe { avx2::axpy(alpha, x, y) };
            return;
        }
    }
    axpy_scalar(alpha, x, y)
}

/// Scalar axpy.
#[inline]
pub fn axpy_scalar(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yv, xv) in y.iter_mut().zip(x.iter()) {
        *yv += alpha * *xv;
    }
}

/// AVX2 axpy; returns `false` (leaving `y` untouched) when the CPU
/// lacks AVX2.
#[cfg(target_arch = "x86_64")]
pub fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) -> bool {
    if !crate::kernels::avx2_available() {
        return false;
    }
    // SAFETY: AVX2 support checked just above.
    unsafe { avx2::axpy(alpha, x, y) };
    true
}

// --------------------------------------------------------- reductions --

/// Running maximum of a slice (`NEG_INFINITY` for empty input).
#[inline]
pub fn max_reduce(xs: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if crate::kernels::vectorized_active() {
            // SAFETY: AVX2 support verified by `vectorized_active`.
            return unsafe { avx2::max_reduce(xs) };
        }
    }
    max_reduce_scalar(xs)
}

/// Scalar running maximum.
#[inline]
pub fn max_reduce_scalar(xs: &[f32]) -> f32 {
    xs.iter().copied().fold(f32::NEG_INFINITY, f32::max)
}

/// AVX2 running maximum; `None` when the CPU lacks AVX2.
#[cfg(target_arch = "x86_64")]
pub fn max_reduce_avx2(xs: &[f32]) -> Option<f32> {
    if !crate::kernels::avx2_available() {
        return None;
    }
    // SAFETY: AVX2 support checked just above.
    Some(unsafe { avx2::max_reduce(xs) })
}

/// Running sum of a slice (0 for empty input).
#[inline]
pub fn sum_reduce(xs: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if crate::kernels::vectorized_active() {
            // SAFETY: AVX2 support verified by `vectorized_active`.
            return unsafe { avx2::sum_reduce(xs) };
        }
    }
    sum_reduce_scalar(xs)
}

/// Scalar running sum (4-way unrolled).
#[inline]
pub fn sum_reduce_scalar(xs: &[f32]) -> f32 {
    let n = xs.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        s0 += xs[j];
        s1 += xs[j + 1];
        s2 += xs[j + 2];
        s3 += xs[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for x in &xs[chunks * 4..] {
        s += *x;
    }
    s
}

/// AVX2 running sum; `None` when the CPU lacks AVX2.
#[cfg(target_arch = "x86_64")]
pub fn sum_reduce_avx2(xs: &[f32]) -> Option<f32> {
    if !crate::kernels::avx2_available() {
        return None;
    }
    // SAFETY: AVX2 support checked just above.
    Some(unsafe { avx2::sum_reduce(xs) })
}

// ----------------------------------------------------- AVX2 internals --

/// Raw `#[target_feature(enable = "avx2")]` loops. Callers must have
/// verified AVX2 support; every function handles remainder lanes with a
/// scalar tail and matches its `_scalar` twin up to float reassociation.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Horizontal sum of the 8 lanes.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let q = _mm_add_ps(lo, hi);
        let q = _mm_add_ps(q, _mm_movehl_ps(q, q));
        let q = _mm_add_ss(q, _mm_shuffle_ps(q, q, 0b01));
        _mm_cvtss_f32(q)
    }

    /// Horizontal max of the 8 lanes.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hmax(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let q = _mm_max_ps(lo, hi);
        let q = _mm_max_ps(q, _mm_movehl_ps(q, q));
        let q = _mm_max_ss(q, _mm_shuffle_ps(q, q, 0b01));
        _mm_cvtss_f32(q)
    }

    /// AVX2 dot product over the common prefix.
    ///
    /// # Safety
    ///
    /// The caller must have verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 16 <= n {
            let p0 = _mm256_mul_ps(
                _mm256_loadu_ps(a.as_ptr().add(i)),
                _mm256_loadu_ps(b.as_ptr().add(i)),
            );
            let p1 = _mm256_mul_ps(
                _mm256_loadu_ps(a.as_ptr().add(i + 8)),
                _mm256_loadu_ps(b.as_ptr().add(i + 8)),
            );
            acc0 = _mm256_add_ps(acc0, p0);
            acc1 = _mm256_add_ps(acc1, p1);
            i += 16;
        }
        if i + 8 <= n {
            let p = _mm256_mul_ps(
                _mm256_loadu_ps(a.as_ptr().add(i)),
                _mm256_loadu_ps(b.as_ptr().add(i)),
            );
            acc0 = _mm256_add_ps(acc0, p);
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// AVX2 squared L2 distance over the common prefix.
    ///
    /// # Safety
    ///
    /// The caller must have verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 16 <= n {
            let d0 = _mm256_sub_ps(
                _mm256_loadu_ps(a.as_ptr().add(i)),
                _mm256_loadu_ps(b.as_ptr().add(i)),
            );
            let d1 = _mm256_sub_ps(
                _mm256_loadu_ps(a.as_ptr().add(i + 8)),
                _mm256_loadu_ps(b.as_ptr().add(i + 8)),
            );
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(d0, d0));
            acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(d1, d1));
            i += 16;
        }
        if i + 8 <= n {
            let d = _mm256_sub_ps(
                _mm256_loadu_ps(a.as_ptr().add(i)),
                _mm256_loadu_ps(b.as_ptr().add(i)),
            );
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(d, d));
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            let d = a[i] - b[i];
            s += d * d;
            i += 1;
        }
        s
    }

    /// AVX2 L1 distance over the common prefix.
    ///
    /// # Safety
    ///
    /// The caller must have verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn l1(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        // Clearing the sign bit (andnot with -0.0) computes |x|.
        let sign = _mm256_set1_ps(-0.0);
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 16 <= n {
            let d0 = _mm256_sub_ps(
                _mm256_loadu_ps(a.as_ptr().add(i)),
                _mm256_loadu_ps(b.as_ptr().add(i)),
            );
            let d1 = _mm256_sub_ps(
                _mm256_loadu_ps(a.as_ptr().add(i + 8)),
                _mm256_loadu_ps(b.as_ptr().add(i + 8)),
            );
            acc0 = _mm256_add_ps(acc0, _mm256_andnot_ps(sign, d0));
            acc1 = _mm256_add_ps(acc1, _mm256_andnot_ps(sign, d1));
            i += 16;
        }
        if i + 8 <= n {
            let d = _mm256_sub_ps(
                _mm256_loadu_ps(a.as_ptr().add(i)),
                _mm256_loadu_ps(b.as_ptr().add(i)),
            );
            acc0 = _mm256_add_ps(acc0, _mm256_andnot_ps(sign, d));
            i += 8;
        }
        let mut s = hsum(_mm256_add_ps(acc0, acc1));
        while i < n {
            s += (a[i] - b[i]).abs();
            i += 1;
        }
        s
    }

    /// AVX2 `y += alpha·x` over the common prefix.
    ///
    /// # Safety
    ///
    /// The caller must have verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len().min(y.len());
        let va = _mm256_set1_ps(alpha);
        let mut i = 0;
        while i + 8 <= n {
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let r = _mm256_add_ps(vy, _mm256_mul_ps(va, vx));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), r);
            i += 8;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    /// AVX2 running maximum (`NEG_INFINITY` for empty input).
    ///
    /// # Safety
    ///
    /// The caller must have verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn max_reduce(xs: &[f32]) -> f32 {
        let n = xs.len();
        let mut i = 0;
        let mut m = f32::NEG_INFINITY;
        if n >= 8 {
            let mut acc = _mm256_loadu_ps(xs.as_ptr());
            i = 8;
            while i + 8 <= n {
                acc =
                    _mm256_max_ps(acc, _mm256_loadu_ps(xs.as_ptr().add(i)));
                i += 8;
            }
            m = hmax(acc);
        }
        while i < n {
            m = m.max(xs[i]);
            i += 1;
        }
        m
    }

    /// AVX2 running sum (0 for empty input).
    ///
    /// # Safety
    ///
    /// The caller must have verified AVX2 support.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sum_reduce(xs: &[f32]) -> f32 {
        let n = xs.len();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(xs.as_ptr().add(i)));
            i += 8;
        }
        let mut s = hsum(acc);
        while i < n {
            s += xs[i];
            i += 1;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn vecs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg32::seeded(seed);
        let a = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let b = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        (a, b)
    }

    fn close(x: f32, y: f32, n: usize) -> bool {
        (x - y).abs() <= 1e-4 * (1.0 + n as f32) * (1.0 + y.abs())
    }

    #[test]
    fn scalar_matches_naive_all_lengths() {
        // Remainder-lane coverage: every length around the 8/16 widths.
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 33, 100] {
            let (a, b) = vecs(n, 7 + n as u64);
            let nd: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let nl2: f32 =
                a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            let nl1: f32 =
                a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
            assert!(close(dot_scalar(&a, &b), nd, n));
            assert!(close(l2_sq_scalar(&a, &b), nl2, n));
            assert!(close(l1_distance_scalar(&a, &b), nl1, n));
            assert!(close(sum_reduce_scalar(&a), a.iter().sum(), n));
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_matches_scalar_all_lengths() {
        if !crate::kernels::avx2_available() {
            eprintln!("SKIP: no AVX2 on this host");
            return;
        }
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 24, 31, 33, 64, 100] {
            let (a, b) = vecs(n, 31 + n as u64);
            assert!(close(dot_avx2(&a, &b).unwrap(), dot_scalar(&a, &b), n));
            assert!(close(
                l2_sq_avx2(&a, &b).unwrap(),
                l2_sq_scalar(&a, &b),
                n
            ));
            assert!(close(
                l1_distance_avx2(&a, &b).unwrap(),
                l1_distance_scalar(&a, &b),
                n
            ));
            assert!(close(
                sum_reduce_avx2(&a).unwrap(),
                sum_reduce_scalar(&a),
                n
            ));
            assert_eq!(max_reduce_avx2(&a).unwrap(), max_reduce_scalar(&a));
            let mut y1 = b.clone();
            let mut y2 = b.clone();
            assert!(axpy_avx2(0.7, &a, &mut y1));
            axpy_scalar(0.7, &a, &mut y2);
            for (v1, v2) in y1.iter().zip(&y2) {
                assert!(close(*v1, *v2, n));
            }
        }
    }

    #[test]
    fn mismatched_lengths_use_common_prefix() {
        let (a, b) = vecs(20, 5);
        let d_short = dot(&a[..13], &b);
        let d_ref = dot_scalar(&a[..13], &b[..13]);
        assert!(close(d_short, d_ref, 13));
        let mut y = b.clone();
        axpy(1.5, &a[..13], &mut y);
        assert_eq!(&y[13..], &b[13..]);
    }

    #[test]
    fn reductions_edge_cases() {
        assert_eq!(max_reduce_scalar(&[]), f32::NEG_INFINITY);
        assert_eq!(max_reduce(&[]), f32::NEG_INFINITY);
        assert_eq!(sum_reduce(&[]), 0.0);
        assert_eq!(max_reduce(&[-3.0]), -3.0);
    }
}
