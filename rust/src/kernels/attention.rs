//! Blocked, online-softmax host attention.
//!
//! FlashAttention-style structure adapted to the host fallback: keys are
//! processed in [`KEY_BLOCK`]-wide tiles so the working set (one query
//! row, one key tile, the running accumulators) stays cache-resident,
//! and the softmax is fused into the score pass with the online
//! recurrence
//!
//! ```text
//! m_next = max(m, max(tile))        // running row maximum
//! alpha  = exp(m − m_next)          // correction for the old prefix
//! l_next = alpha·l + Σ exp(s − m_next)
//! acc    = alpha·acc + Σ exp(s − m_next)·v
//! ```
//!
//! so no unnormalised score row is ever revisited. Statistics are kept
//! in f32 against finite inputs; the final normalisation uses a safe
//! division (an all-`−inf` row yields zeros, not NaN).
//!
//! Two kernels are exposed:
//!
//! * [`apm_blocked`] materialises the attention probability matrix —
//!   the APM the memo tier stores — row by row;
//! * [`attention_blocked`] is the fused `softmax(Q·Kᵀ·scale)·V` that
//!   never materialises a full score row.
//!
//! `_strided` variants take a row pitch per operand so callers can
//! point directly into a `[L, H]` hidden-state batch (head slices are
//! contiguous within a row but stride `H` between rows). Straightforward
//! scalar references ([`apm_reference`], [`attention_reference`]) back
//! the differential tests and the A/B benches.

use crate::kernels::simd;

/// Number of key columns per tile. 64 columns × 4 B keeps a tile of
/// scores plus a key row well inside L1 for head dims up to ~128.
pub const KEY_BLOCK: usize = 64;

/// Row `i` of a strided matrix: `d` values at pitch `stride`.
#[inline]
fn row(m: &[f32], stride: usize, d: usize, i: usize) -> &[f32] {
    &m[i * stride..i * stride + d]
}

// ------------------------------------------------------------ APM path --

/// `out[i·l + j] = softmax_j(scale · q_i · k_j)` over contiguous
/// `[l, d]` operands.
pub fn apm_blocked(
    q: &[f32], k: &[f32], l: usize, d: usize, scale: f32, out: &mut [f32],
) {
    apm_blocked_strided(q, d, k, d, l, d, scale, out)
}

/// [`apm_blocked`] with independent row pitches for `q` and `k`
/// (`q_stride`, `k_stride` ≥ `d`); `out` is contiguous `[l, l]`.
#[allow(clippy::too_many_arguments)]
pub fn apm_blocked_strided(
    q: &[f32], q_stride: usize, k: &[f32], k_stride: usize, l: usize,
    d: usize, scale: f32, out: &mut [f32],
) {
    debug_assert!(q_stride >= d && k_stride >= d);
    debug_assert!(out.len() >= l * l);
    for i in 0..l {
        let q_i = row(q, q_stride, d, i);
        let out_row = &mut out[i * l..(i + 1) * l];
        let mut m = f32::NEG_INFINITY;
        let mut denom = 0.0f32;
        let mut j0 = 0;
        while j0 < l {
            let j1 = (j0 + KEY_BLOCK).min(l);
            for j in j0..j1 {
                out_row[j] = scale * simd::dot(q_i, row(k, k_stride, d, j));
            }
            let tile_max = simd::max_reduce(&out_row[j0..j1]);
            let m_next = m.max(tile_max);
            if m_next > m && m != f32::NEG_INFINITY {
                // The running max grew: rescale the already-written
                // prefix and the running denominator.
                let alpha = (m - m_next).exp();
                denom *= alpha;
                for v in &mut out_row[..j0] {
                    *v *= alpha;
                }
            }
            for v in &mut out_row[j0..j1] {
                *v = (*v - m_next).exp();
            }
            denom += simd::sum_reduce(&out_row[j0..j1]);
            m = m_next;
            j0 = j1;
        }
        // Safe division: a degenerate row normalises to zeros, not NaN.
        let inv = if denom > 0.0 { 1.0 / denom } else { 0.0 };
        for v in out_row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Naive three-pass scalar reference for [`apm_blocked`].
pub fn apm_reference(
    q: &[f32], k: &[f32], l: usize, d: usize, scale: f32, out: &mut [f32],
) {
    for i in 0..l {
        let q_i = &q[i * d..(i + 1) * d];
        let out_row = &mut out[i * l..(i + 1) * l];
        for j in 0..l {
            let k_j = &k[j * d..(j + 1) * d];
            out_row[j] = scale * simd::dot_scalar(q_i, k_j);
        }
        let m = simd::max_reduce_scalar(out_row);
        let mut sum = 0.0f32;
        for v in out_row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        let inv = if sum > 0.0 { 1.0 / sum } else { 0.0 };
        for v in out_row.iter_mut() {
            *v *= inv;
        }
    }
}

// ---------------------------------------------------------- fused path --

/// Fused `out = softmax(scale · Q·Kᵀ) · V` over contiguous `[l, d]`
/// operands; `out` is `[l, d]`. Never materialises a full score row.
pub fn attention_blocked(
    q: &[f32], k: &[f32], v: &[f32], l: usize, d: usize, scale: f32,
    out: &mut [f32],
) {
    attention_blocked_strided(q, d, k, d, v, d, l, d, scale, out)
}

/// [`attention_blocked`] with independent row pitches for the three
/// operands; `out` is contiguous `[l, d]`.
#[allow(clippy::too_many_arguments)]
pub fn attention_blocked_strided(
    q: &[f32], q_stride: usize, k: &[f32], k_stride: usize, v: &[f32],
    v_stride: usize, l: usize, d: usize, scale: f32, out: &mut [f32],
) {
    debug_assert!(q_stride >= d && k_stride >= d && v_stride >= d);
    debug_assert!(out.len() >= l * d);
    let mut scores = [0.0f32; KEY_BLOCK];
    let mut acc = vec![0.0f32; d];
    for i in 0..l {
        let q_i = row(q, q_stride, d, i);
        acc.iter_mut().for_each(|a| *a = 0.0);
        let mut m = f32::NEG_INFINITY;
        let mut denom = 0.0f32;
        let mut j0 = 0;
        while j0 < l {
            let j1 = (j0 + KEY_BLOCK).min(l);
            let nb = j1 - j0;
            for (t, j) in (j0..j1).enumerate() {
                scores[t] = scale * simd::dot(q_i, row(k, k_stride, d, j));
            }
            let tile_max = simd::max_reduce(&scores[..nb]);
            let m_next = m.max(tile_max);
            if m_next > m && m != f32::NEG_INFINITY {
                let alpha = (m - m_next).exp();
                denom *= alpha;
                for a in acc.iter_mut() {
                    *a *= alpha;
                }
            }
            for (t, j) in (j0..j1).enumerate() {
                let p = (scores[t] - m_next).exp();
                simd::axpy(p, row(v, v_stride, d, j), &mut acc);
                denom += p;
            }
            m = m_next;
            j0 = j1;
        }
        let inv = if denom > 0.0 { 1.0 / denom } else { 0.0 };
        let out_row = &mut out[i * d..(i + 1) * d];
        for (o, a) in out_row.iter_mut().zip(acc.iter()) {
            *o = *a * inv;
        }
    }
}

/// Naive scalar reference for [`attention_blocked`].
pub fn attention_reference(
    q: &[f32], k: &[f32], v: &[f32], l: usize, d: usize, scale: f32,
    out: &mut [f32],
) {
    let mut probs = vec![0.0f32; l * l];
    apm_reference(q, k, l, d, scale, &mut probs);
    for i in 0..l {
        let out_row = &mut out[i * d..(i + 1) * d];
        out_row.iter_mut().for_each(|o| *o = 0.0);
        for j in 0..l {
            let p = probs[i * l + j];
            let v_j = &v[j * d..(j + 1) * d];
            for (o, x) in out_row.iter_mut().zip(v_j.iter()) {
                *o += p * *x;
            }
        }
    }
}

// ------------------------------------------------------- head batching --

/// [`apm_blocked`] over `heads` contiguous `[l, d]` blocks; `out` is
/// `[heads, l, l]`.
pub fn apm_heads(
    q: &[f32], k: &[f32], heads: usize, l: usize, d: usize, scale: f32,
    out: &mut [f32],
) {
    for h in 0..heads {
        let qh = &q[h * l * d..(h + 1) * l * d];
        let kh = &k[h * l * d..(h + 1) * l * d];
        apm_blocked(qh, kh, l, d, scale, &mut out[h * l * l..(h + 1) * l * l]);
    }
}

/// [`attention_blocked`] over `heads` contiguous `[l, d]` blocks; `out`
/// is `[heads, l, d]`.
#[allow(clippy::too_many_arguments)]
pub fn attention_heads(
    q: &[f32], k: &[f32], v: &[f32], heads: usize, l: usize, d: usize,
    scale: f32, out: &mut [f32],
) {
    for h in 0..heads {
        let s = h * l * d..(h + 1) * l * d;
        attention_blocked(
            &q[s.clone()],
            &k[s.clone()],
            &v[s.clone()],
            l,
            d,
            scale,
            &mut out[s],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::rows_stochastic;
    use crate::util::Pcg32;

    fn randn(n: usize, rng: &mut Pcg32) -> Vec<f32> {
        (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() <= tol * (1.0 + y.abs()),
                "lane {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn apm_matches_reference_across_shapes() {
        let mut rng = Pcg32::seeded(11);
        // Shapes straddling KEY_BLOCK and the SIMD widths.
        for (l, d) in [(1, 4), (3, 5), (16, 8), (63, 10), (64, 16), (65, 7)]
        {
            let q = randn(l * d, &mut rng);
            let k = randn(l * d, &mut rng);
            let scale = 1.0 / (d as f32).sqrt();
            let mut got = vec![0.0f32; l * l];
            let mut want = vec![0.0f32; l * l];
            apm_blocked(&q, &k, l, d, scale, &mut got);
            apm_reference(&q, &k, l, d, scale, &mut want);
            assert_close(&got, &want, 1e-4);
            assert!(rows_stochastic(&got, l, l, 1e-4));
        }
    }

    #[test]
    fn fused_matches_reference_across_shapes() {
        let mut rng = Pcg32::seeded(13);
        for (l, d) in [(1, 3), (7, 9), (32, 16), (65, 8), (130, 12)] {
            let q = randn(l * d, &mut rng);
            let k = randn(l * d, &mut rng);
            let v = randn(l * d, &mut rng);
            let scale = 1.0 / (d as f32).sqrt();
            let mut got = vec![0.0f32; l * d];
            let mut want = vec![0.0f32; l * d];
            attention_blocked(&q, &k, &v, l, d, scale, &mut got);
            attention_reference(&q, &k, &v, l, d, scale, &mut want);
            assert_close(&got, &want, 1e-4);
        }
    }

    #[test]
    fn strided_operands_match_packed() {
        // Head slices of a [l, H] batch: contiguous d within a row,
        // pitch H between rows.
        let mut rng = Pcg32::seeded(17);
        let (l, d, heads) = (20, 6, 3);
        let h_total = d * heads;
        let hidden = randn(l * h_total, &mut rng);
        let scale = 1.0 / (d as f32).sqrt();
        for h in 0..heads {
            // Packed copy of head h.
            let mut packed = Vec::with_capacity(l * d);
            for i in 0..l {
                let off = i * h_total + h * d;
                packed.extend_from_slice(&hidden[off..off + d]);
            }
            let mut want = vec![0.0f32; l * l];
            apm_blocked(&packed, &packed, l, d, scale, &mut want);
            let mut got = vec![0.0f32; l * l];
            let head = &hidden[h * d..];
            apm_blocked_strided(
                head, h_total, head, h_total, l, d, scale, &mut got,
            );
            assert_close(&got, &want, 1e-5);

            let mut want_o = vec![0.0f32; l * d];
            attention_blocked(&packed, &packed, &packed, l, d, scale,
                              &mut want_o);
            let mut got_o = vec![0.0f32; l * d];
            attention_blocked_strided(
                head, h_total, head, h_total, head, h_total, l, d, scale,
                &mut got_o,
            );
            assert_close(&got_o, &want_o, 1e-5);
        }
    }

    #[test]
    fn extreme_scores_stay_finite_and_stochastic() {
        // Large scale drives raw scores far past exp overflow; the
        // online max subtraction must keep everything finite.
        let mut rng = Pcg32::seeded(19);
        let (l, d) = (70, 8);
        let q = randn(l * d, &mut rng);
        let k = randn(l * d, &mut rng);
        let mut out = vec![0.0f32; l * l];
        apm_blocked(&q, &k, l, d, 200.0, &mut out);
        assert!(out.iter().all(|v| v.is_finite()));
        assert!(rows_stochastic(&out, l, l, 1e-3));
    }

    #[test]
    fn head_batching_matches_per_head_calls() {
        let mut rng = Pcg32::seeded(23);
        let (heads, l, d) = (2, 9, 5);
        let q = randn(heads * l * d, &mut rng);
        let k = randn(heads * l * d, &mut rng);
        let scale = 0.5;
        let mut batched = vec![0.0f32; heads * l * l];
        apm_heads(&q, &k, heads, l, d, scale, &mut batched);
        for h in 0..heads {
            let mut single = vec![0.0f32; l * l];
            apm_blocked(
                &q[h * l * d..(h + 1) * l * d],
                &k[h * l * d..(h + 1) * l * d],
                l,
                d,
                scale,
                &mut single,
            );
            // Non-zero tolerance: another test may flip the dispatch
            // switch between the two calls.
            assert_close(&batched[h * l * l..(h + 1) * l * l], &single,
                         1e-5);
        }
    }
}
