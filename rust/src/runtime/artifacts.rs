//! Artifact manifest: the single index written by `python/compile/aot.py`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::config::json::Json;
use crate::config::ModelConfig;
use crate::{Error, Result};

/// Identifies one lowered HLO graph.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct GraphKey {
    pub family: String,
    pub kind: String,
    pub batch: usize,
    pub seq_len: usize,
}

impl GraphKey {
    pub fn new(family: &str, kind: &str, batch: usize, seq_len: usize) -> Self {
        GraphKey {
            family: family.into(),
            kind: kind.into(),
            batch,
            seq_len,
        }
    }
}

/// One lowered graph's manifest entry.
#[derive(Debug, Clone)]
pub struct GraphInfo {
    pub family: String,
    pub kind: String,
    pub batch: usize,
    pub seq_len: usize,
    pub path: String,
    /// Parameter names in HLO argument order (activations first).
    pub params: Vec<String>,
}

/// One tensor inside a weights/fixtures bin.
#[derive(Debug, Clone)]
pub struct TensorEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize, // elements
    pub len: usize,    // elements
    pub dtype: String, // "f32" | "i32"
}

/// A pruned variant of a family (§6.8).
#[derive(Debug, Clone)]
pub struct SparseVariant {
    pub tag: String,
    pub sparsity: f64,
    pub weights: String,
    pub tensors: Vec<TensorEntry>,
    pub accuracy: f64,
}

/// A family's manifest entry.
#[derive(Debug, Clone)]
pub struct FamilyInfo {
    pub config: ModelConfig,
    pub weights: String,
    pub tensors: Vec<TensorEntry>,
    pub accuracy: f64,
    pub sparse_variants: Vec<SparseVariant>,
    pub fixtures: Option<FixtureInfo>,
}

/// Cross-language numeric test vectors.
#[derive(Debug, Clone)]
pub struct FixtureInfo {
    pub path: String,
    pub tensors: Vec<TensorEntry>,
    pub batch: usize,
    pub seq_len: usize,
}

/// A dataset exported by datagen.
#[derive(Debug, Clone)]
pub struct DatasetInfo {
    pub path: String,
    pub n: usize,
    pub seq_len: usize,
}

/// Parsed `manifest.json` plus the artifacts root directory.
pub struct Artifacts {
    root: PathBuf,
    pub vocab_size: usize,
    pub serving_seq_len: usize,
    pub serving_batches: Vec<usize>,
    pub sweep_seq_lens: Vec<usize>,
    families: HashMap<String, FamilyInfo>,
    graphs: Vec<GraphInfo>,
    graph_index: HashMap<GraphKey, usize>,
    datasets: HashMap<String, DatasetInfo>,
}

fn parse_tensor_entries(v: &[Json]) -> Result<Vec<TensorEntry>> {
    v.iter()
        .map(|t| {
            Ok(TensorEntry {
                name: t.req_str("name")?.to_string(),
                shape: t.usize_vec("shape")?,
                offset: t.req_usize("offset")?,
                len: t.req_usize("len")?,
                dtype: t.req_str("dtype")?.to_string(),
            })
        })
        .collect()
}

impl Artifacts {
    /// Load and validate `<root>/manifest.json`.
    pub fn load(root: PathBuf) -> Result<Self> {
        let manifest_path = root.join("manifest.json");
        if !manifest_path.exists() {
            return Err(Error::config(format!(
                "no manifest at {} — run `make artifacts` first",
                manifest_path.display()
            )));
        }
        let m = Json::from_file(&manifest_path)?;
        let vocab_size = m.req_usize("vocab_size")?;
        let serving_seq_len = m.req_usize("serving_seq_len")?;
        let serving_batches = m.usize_vec("serving_batches")?;
        let sweep_seq_lens = m.usize_vec("sweep_seq_lens")?;

        let mut families = HashMap::new();
        for (name, f) in m
            .req("families")?
            .as_obj()
            .ok_or_else(|| Error::Json("families not an object".into()))?
        {
            let mut sparse_variants = Vec::new();
            if let Some(svs) = f.get("sparse_variants").and_then(Json::as_arr)
            {
                for sv in svs {
                    sparse_variants.push(SparseVariant {
                        tag: sv.req_str("tag")?.to_string(),
                        sparsity: sv.req_f64("sparsity")?,
                        weights: sv.req_str("weights")?.to_string(),
                        tensors: parse_tensor_entries(sv.req_arr("tensors")?)?,
                        accuracy: sv.req_f64("accuracy")?,
                    });
                }
            }
            let fixtures = match f.get("fixtures") {
                Some(fx) => Some(FixtureInfo {
                    path: fx.req_str("path")?.to_string(),
                    tensors: parse_tensor_entries(fx.req_arr("tensors")?)?,
                    batch: fx.req_usize("batch")?,
                    seq_len: fx.req_usize("seq_len")?,
                }),
                None => None,
            };
            families.insert(
                name.clone(),
                FamilyInfo {
                    config: ModelConfig::from_json(f.req("config")?)?,
                    weights: f.req_str("weights")?.to_string(),
                    tensors: parse_tensor_entries(f.req_arr("tensors")?)?,
                    accuracy: f.req_f64("accuracy")?,
                    sparse_variants,
                    fixtures,
                },
            );
        }

        let mut graphs = Vec::new();
        let mut graph_index = HashMap::new();
        for g in m.req_arr("graphs")? {
            let info = GraphInfo {
                family: g.req_str("family")?.to_string(),
                kind: g.req_str("kind")?.to_string(),
                batch: g.req_usize("batch")?,
                seq_len: g.req_usize("seq_len")?,
                path: g.req_str("path")?.to_string(),
                params: g
                    .req_arr("params")?
                    .iter()
                    .map(|p| {
                        p.as_str().map(str::to_string).ok_or_else(|| {
                            Error::Json("graph params: non-string".into())
                        })
                    })
                    .collect::<Result<_>>()?,
            };
            let key = GraphKey::new(&info.family, &info.kind, info.batch,
                                    info.seq_len);
            graph_index.insert(key, graphs.len());
            graphs.push(info);
        }

        let mut datasets = HashMap::new();
        if let Some(ds) = m.get("datasets").and_then(Json::as_obj) {
            for (name, d) in ds {
                datasets.insert(
                    name.clone(),
                    DatasetInfo {
                        path: d.req_str("path")?.to_string(),
                        n: d.req_usize("n")?,
                        seq_len: d.req_usize("seq_len")?,
                    },
                );
            }
        }

        Ok(Artifacts {
            root,
            vocab_size,
            serving_seq_len,
            serving_batches,
            sweep_seq_lens,
            families,
            graphs,
            graph_index,
            datasets,
        })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn family(&self, name: &str) -> Result<&FamilyInfo> {
        self.families.get(name).ok_or_else(|| {
            Error::config(format!("family {name:?} not in manifest"))
        })
    }

    pub fn family_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.families.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn graphs(&self) -> &[GraphInfo] {
        &self.graphs
    }

    pub fn graph(&self, key: &GraphKey) -> Result<&GraphInfo> {
        self.graph_index
            .get(key)
            .map(|&i| &self.graphs[i])
            .ok_or_else(|| Error::config(format!("graph {key:?} not lowered")))
    }

    pub fn dataset(&self, name: &str) -> Result<&DatasetInfo> {
        self.datasets.get(name).ok_or_else(|| {
            Error::config(format!("dataset {name:?} not in manifest"))
        })
    }

    /// Load a dataset (ATDS format): returns (ids [n, seq], labels [n]).
    pub fn load_dataset(&self, name: &str) -> Result<(crate::tensor::tensor::IdTensor, Vec<i32>)> {
        let info = self.dataset(name)?;
        let bytes = std::fs::read(self.root.join(&info.path))?;
        if bytes.len() < 12 || &bytes[0..4] != b"ATDS" {
            return Err(Error::config(format!("bad dataset file {}", info.path)));
        }
        let n = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        let seq = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let want = 12 + n * seq * 4 + n * 4;
        if bytes.len() != want {
            return Err(Error::config(format!(
                "dataset {} truncated: {} != {want}",
                info.path,
                bytes.len()
            )));
        }
        let mut ids = Vec::with_capacity(n * seq);
        for i in 0..n * seq {
            let o = 12 + i * 4;
            ids.push(i32::from_le_bytes(bytes[o..o + 4].try_into().unwrap()));
        }
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let o = 12 + n * seq * 4 + i * 4;
            labels.push(i32::from_le_bytes(bytes[o..o + 4].try_into().unwrap()));
        }
        Ok((crate::tensor::tensor::IdTensor::new(vec![n, seq], ids)?, labels))
    }
}
