//! PJRT client wrapper with a lazy, cached executable registry.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

use crate::runtime::artifacts::{Artifacts, GraphKey};
use crate::runtime::executable::Executable;
use crate::{Error, Result};

/// Owns the PJRT CPU client, the parsed artifact manifest, and a cache of
/// compiled executables keyed by (family, graph kind, batch, seq-len).
///
/// Compilation is lazy: the first request for a graph pays the PJRT compile
/// once; everything after hits the cache. Executables are reference-shared.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: Artifacts,
    cache: Mutex<HashMap<GraphKey, std::sync::Arc<Executable>>>,
}

// SAFETY: the `xla` crate wraps PJRT handles in `Rc` + raw pointers, which
// makes them `!Send`/`!Sync` even though the underlying PJRT CPU client is
// thread-safe (PJRT serialises CPU execution internally). The coordinator
// shares `Runtime` behind `Arc` and mutates only the `Mutex`-guarded
// compile cache; `PjRtClient` `Rc` clones happen only inside `compile`,
// which this crate always reaches through the cache mutex (see
// `executable()`), so refcount updates are never concurrent.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Open the artifacts directory and start a PJRT CPU client.
    pub fn open(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let artifacts = Artifacts::load(artifacts_dir.into())?;
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "runtime: PJRT platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { client, artifacts, cache: Mutex::new(HashMap::new()) })
    }

    pub fn artifacts(&self) -> &Artifacts {
        &self.artifacts
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Fetch (compiling on first use) the executable for a graph.
    pub fn executable(&self, key: &GraphKey) -> Result<std::sync::Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(key) {
            return Ok(exe.clone());
        }
        let info = self.artifacts.graph(key)?;
        let path = self.artifacts.root().join(&info.path);
        let t0 = std::time::Instant::now();
        let exe = std::sync::Arc::new(Executable::compile_hlo_file(
            &self.client,
            &path,
            info.params.clone(),
        )?);
        log::debug!(
            "runtime: compiled {key:?} in {:.0} ms",
            t0.elapsed().as_secs_f64() * 1e3
        );
        self.cache
            .lock()
            .unwrap()
            .entry(key.clone())
            .or_insert_with(|| exe.clone());
        Ok(exe)
    }

    /// Pick the smallest lowered batch size >= `want` for a family/kind/seq.
    pub fn fit_batch(&self, family: &str, kind: &str, seq_len: usize,
                     want: usize) -> Result<usize> {
        let mut best: Option<usize> = None;
        for g in self.artifacts.graphs() {
            if g.family == family && g.kind == kind && g.seq_len == seq_len {
                if g.batch >= want {
                    best = Some(best.map_or(g.batch, |b: usize| b.min(g.batch)));
                }
            }
        }
        best.ok_or_else(|| {
            Error::config(format!(
                "no lowered {family}/{kind} graph with batch >= {want} at \
                 seq_len {seq_len}"
            ))
        })
    }

    /// All batch sizes lowered for a family/kind/seq (ascending).
    pub fn available_batches(&self, family: &str, kind: &str,
                             seq_len: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .graphs()
            .iter()
            .filter(|g| {
                g.family == family && g.kind == kind && g.seq_len == seq_len
            })
            .map(|g| g.batch)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}
