//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them on
//! the request path. Python never runs here.

pub mod artifacts;
pub mod client;
pub mod executable;
pub mod weights;

pub use artifacts::{Artifacts, GraphKey};
pub use client::Runtime;
pub use executable::Executable;
pub use weights::WeightSet;
