//! A compiled PJRT executable plus its parameter-name signature.

use std::path::Path;

use crate::tensor::Tensor;
use crate::{Error, Result};

/// One compiled HLO graph. Executables are immutable and thread-safe to
/// share; PJRT serialises execution internally on the CPU client.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Parameter names in argument order (from the manifest).
    params: Vec<String>,
}

// SAFETY: `PjRtLoadedExecutable` is `!Send` only because the wrapper holds
// an `Rc<PjRtClientInternal>` and raw pointers; PJRT itself allows
// concurrent Execute calls on the CPU client. Executables here are
// compiled once, shared via `Arc`, and never cloned after construction,
// so the inner `Rc` refcount is only touched at drop — which happens on
// whichever thread drops the last `Arc`, strictly after all use.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Load HLO **text** (see aot.py for why text, not proto) and compile.
    pub fn compile_hlo_file(client: &xla::PjRtClient, path: &Path,
                            params: Vec<String>) -> Result<Self> {
        let path_str = path.to_str().ok_or_else(|| {
            Error::config(format!("non-utf8 path {}", path.display()))
        })?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Executable { exe, params })
    }

    pub fn params(&self) -> &[String] {
        &self.params
    }

    /// Execute with literal inputs; returns the tuple elements as literals.
    ///
    /// aot.py lowers with `return_tuple=True`, so the single output is a
    /// tuple even for one result.
    pub fn run_literals(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.params.len() {
            return Err(Error::shape(format!(
                "executable wants {} args {:?}, got {}",
                self.params.len(),
                self.params,
                inputs.len()
            )));
        }
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute and convert the single output to a host tensor.
    pub fn run_one(&self, inputs: &[xla::Literal]) -> Result<Tensor> {
        let outs = self.run_literals(inputs)?;
        let first = outs.into_iter().next().ok_or_else(|| {
            Error::shape("executable returned empty tuple")
        })?;
        Tensor::from_literal(&first)
    }

    /// Execute with device buffers (§Perf: weights stay resident on the
    /// device; only activations are uploaded per call).
    pub fn run_buffers(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Tensor> {
        if inputs.len() != self.params.len() {
            return Err(Error::shape(format!(
                "executable wants {} args, got {}",
                self.params.len(),
                inputs.len()
            )));
        }
        let result = self.exe.execute_b(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        let first = lit.to_tuple()?.into_iter().next().ok_or_else(|| {
            Error::shape("executable returned empty tuple")
        })?;
        Tensor::from_literal(&first)
    }
}
