//! Weight loading: raw f32 bins + manifest tensor entries → cached literals.
//!
//! Weights are converted to `xla::Literal`s once at load; graph argument
//! lists are assembled per call by name. Per-layer tensors are stored under
//! their manifest names (`l<idx>_<short>`); graphs reference the short name
//! and the caller supplies the layer index.

use std::collections::HashMap;
use std::path::Path;

use crate::runtime::artifacts::TensorEntry;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// All tensors of one weight bin (a family, or one sparse variant), as
/// host tensors plus pre-built literals.
pub struct WeightSet {
    tensors: HashMap<String, Tensor>,
    literals: HashMap<String, xla::Literal>,
}

// SAFETY: `xla::Literal` holds a raw pointer to immutable host data; after
// `load` the set is read-only (`literal()` clones via the XLA C++ copy
// constructor from an immutable source). Shared behind `Arc` with all
// mutation confined to construction.
unsafe impl Send for WeightSet {}
unsafe impl Sync for WeightSet {}

impl WeightSet {
    /// Read `<root>/<relpath>` with its manifest entries.
    pub fn load(root: &Path, relpath: &str, entries: &[TensorEntry]) -> Result<Self> {
        let bytes = std::fs::read(root.join(relpath))?;
        let mut tensors = HashMap::new();
        let mut literals = HashMap::new();
        for e in entries {
            if e.dtype != "f32" {
                // Weight bins are all-f32; ids appear only in fixtures.
                continue;
            }
            let start = e.offset * 4;
            let end = start + e.len * 4;
            if end > bytes.len() {
                return Err(Error::config(format!(
                    "weights {relpath}: tensor {} out of range",
                    e.name
                )));
            }
            let mut data = Vec::with_capacity(e.len);
            for i in 0..e.len {
                let o = start + i * 4;
                data.push(f32::from_le_bytes(
                    bytes[o..o + 4].try_into().unwrap(),
                ));
            }
            let t = Tensor::new(e.shape.clone(), data)?;
            literals.insert(e.name.clone(), t.to_literal()?);
            tensors.insert(e.name.clone(), t);
        }
        Ok(WeightSet { tensors, literals })
    }

    /// Host copy of a tensor.
    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| Error::config(format!("no weight tensor {name:?}")))
    }

    /// Literal for a tensor (cloning an `xla::Literal` copies host data —
    /// cheap relative to execution at these sizes).
    pub fn literal(&self, name: &str) -> Result<xla::Literal> {
        self.literals
            .get(name)
            .cloned()
            .ok_or_else(|| Error::config(format!("no weight literal {name:?}")))
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.tensors.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Resolve a graph-parameter name to a weight literal, given an optional
    /// layer index. Activation names must be handled by the caller first.
    pub fn resolve(&self, param: &str, layer: Option<usize>) -> Result<xla::Literal> {
        if let Some(li) = layer {
            let layered = format!("l{li}_{param}");
            if self.literals.contains_key(&layered) {
                return self.literal(&layered);
            }
        }
        self.literal(param)
    }

    /// Assemble the full argument vector for an executable: `activations`
    /// supplies the leading non-weight parameters (by name), the rest are
    /// resolved from this weight set.
    pub fn assemble_args(
        &self,
        params: &[String],
        activations: &[(&str, xla::Literal)],
        layer: Option<usize>,
    ) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::with_capacity(params.len());
        for p in params {
            if let Some((_, lit)) =
                activations.iter().find(|(n, _)| n == p)
            {
                out.push(lit.clone());
            } else {
                out.push(self.resolve(p, layer)?);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_entries() -> (Vec<u8>, Vec<TensorEntry>) {
        let data: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let bytes: Vec<u8> =
            data.iter().flat_map(|f| f.to_le_bytes()).collect();
        let entries = vec![
            TensorEntry {
                name: "l0_wq".into(),
                shape: vec![2, 2],
                offset: 0,
                len: 4,
                dtype: "f32".into(),
            },
            TensorEntry {
                name: "bias".into(),
                shape: vec![2],
                offset: 4,
                len: 2,
                dtype: "f32".into(),
            },
        ];
        (bytes, entries)
    }

    #[test]
    fn load_and_resolve() {
        let dir = std::env::temp_dir().join("attmemo_wtest");
        std::fs::create_dir_all(&dir).unwrap();
        let (bytes, entries) = mk_entries();
        std::fs::write(dir.join("w.bin"), &bytes).unwrap();
        let ws = WeightSet::load(&dir, "w.bin", &entries).unwrap();
        assert_eq!(ws.tensor("l0_wq").unwrap().shape(), &[2, 2]);
        assert_eq!(ws.tensor("bias").unwrap().data(), &[5.0, 6.0]);
        // short-name resolution through the layer index
        assert!(ws.resolve("wq", Some(0)).is_ok());
        assert!(ws.resolve("wq", Some(1)).is_err());
        assert!(ws.resolve("bias", None).is_ok());
    }

    #[test]
    fn out_of_range_entry_errors() {
        let dir = std::env::temp_dir().join("attmemo_wtest2");
        std::fs::create_dir_all(&dir).unwrap();
        let (bytes, mut entries) = mk_entries();
        std::fs::write(dir.join("w.bin"), &bytes).unwrap();
        entries[1].len = 100;
        assert!(WeightSet::load(&dir, "w.bin", &entries).is_err());
    }
}
