//! Hand-rolled CLI (clap is not in the offline registry).
//!
//! Subcommands:
//!   info                         — manifest summary
//!   serve                        — start the TCP serving loop
//!   client                       — fire requests at a server
//!   build-db                     — populate a DB, print Table-3-style stats
//!   eval                         — accuracy/latency/memo-rate over the test set
//!
//! Common flags: `--artifacts DIR`, `--family NAME`, `--level LEVEL`,
//! `--db-seqs N`, `--batch N`, `--no-selective`, `--set key=value`.

use std::collections::HashMap;
use std::sync::Arc;

use crate::bench_support::workload;
use crate::config::{MemoConfig, MemoLevel, ServingConfig, SignatureMode};
use crate::data::tokenizer::Vocab;
use crate::eval::evaluate;
use crate::memo::tier::MemoTier;
use crate::serving::server::{Client, Server};
use crate::{Error, Result};

/// Parsed flags: positional subcommand + `--key value` options
/// (bare `--flag` toggles).
pub struct Args {
    pub command: String,
    opts: HashMap<String, String>,
    flags: Vec<String>,
    sets: Vec<(String, String)>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut command = String::new();
        let mut opts = HashMap::new();
        let mut flags = Vec::new();
        let mut sets = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if name == "set" {
                    let kv = argv.get(i + 1).ok_or_else(|| {
                        Error::config("--set needs key=value")
                    })?;
                    let (k, v) = kv.split_once('=').ok_or_else(|| {
                        Error::config(format!("--set {kv:?}: want key=value"))
                    })?;
                    sets.push((k.to_string(), v.to_string()));
                    i += 2;
                    continue;
                }
                // Option with a value unless the next token is a flag/end.
                match argv.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        opts.insert(name.to_string(), v.clone());
                        i += 2;
                    }
                    _ => {
                        flags.push(name.to_string());
                        i += 1;
                    }
                }
            } else if command.is_empty() {
                command = a.clone();
                i += 1;
            } else {
                return Err(Error::config(format!("unexpected argument {a:?}")));
            }
        }
        Ok(Args { command, opts, flags, sets })
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::config(format!("--{name}: bad number {v:?}"))
            }),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

const USAGE: &str = "\
attmemo — AttMemo serving coordinator

USAGE: attmemo <command> [flags]

COMMANDS
  info       print the artifact manifest summary
  serve      start the TCP server (flags: --family, --level, --db-seqs,
             --no-selective, --set max_batch=N, --set bind=ADDR, ...)
  client     send requests (--addr HOST:PORT, --n COUNT, --text \"...\")
  build-db   populate an attention database and print its stats
             (--save FILE persists it; eval/serve take --load-db FILE)
  eval       accuracy/latency/memo-rate on the test set
             (--family, --level off|conservative|moderate|aggressive,
              --batch N, --db-seqs N, --n N, --no-selective)

ONLINE MEMOIZATION (serve/eval)
  --online-admission    admit miss APMs into a serve-time database
  --cold-db             start with an empty database (implies
                        --online-admission; the engine warms from traffic)
  --db-capacity N       per-layer entry budget for the online database
                        (0 = unbounded; reuse-aware eviction at the cap)
  --admission-warmup N  per-layer attempts before the Eq. 3 admission
                        gate activates (default 64)
  --no-dedup            disable intra-batch dedup on the admission path
                        (near-identical rows in one batch then all admit)
  --no-dedup-prepass    disable the publish-skip fast path (a batch whose
                        rows all dedup against the published snapshot is
                        normally served by reuse marks alone — no
                        copy-on-write clone, no publish); every batch
                        then pays the full write path (A/B measurement)
  --cold-tier-dir DIR   spill clock-evicted entries into a file-backed
                        cold tier rooted at DIR instead of dropping
                        them (implies --online-admission): hot misses
                        fall through to a cold lookup and cold hits
                        promote back into the hot tier; the cold tier
                        survives restarts (see docs/PERSISTENCE.md)
  --cold-capacity N     per-layer entry budget of the cold tier
                        (required with --cold-tier-dir; the oldest cold
                        entries fall off FIFO past it)
  --scalar-kernels      force the scalar fallback in the unified kernel
                        layer (index distances, Eq. 1 similarity,
                        pooling, host attention) instead of the
                        runtime-dispatched AVX2 paths — the A/B
                        baseline for SIMD speedup measurements; also
                        settable via ATTMEMO_SCALAR_KERNELS=1

AFFINITY ROUTING (serve)
  --affinity-buckets N  similarity-affinity buckets in front of the
                        batchers (default 8; also --set
                        affinity_buckets=N): requests that sketch alike
                        land in one bucket and batch together, raising
                        the intra-batch dedup yield; idle batchers steal
                        from the fullest bucket so skewed traffic
                        starves no replica
  --no-affinity         single FIFO bucket (affinity routing off; also
                        pins the bucket count — overrides
                        --adaptive-buckets)
  --signature-mode M    how requests sketch into buckets: `prefix`
                        (token min-hash, the default) or `semantic`
                        (SimHash over mean-pooled embedding-table rows,
                        so paraphrases share a bucket). Explicitly
                        requesting `semantic` with no embedding table
                        loaded is a startup error; only a semantic
                        *config default* warns and falls back to prefix
  --signature-prefix-len N
                        non-pad prefix tokens both signature modes
                        sketch over (default 32; also --set
                        signature_prefix_len=N)
  --adaptive-buckets    let the router grow/shrink the bucket space
                        (power-of-two, drain-and-requeue epochs) when
                        the steal rate or occupancy skew shows the
                        partition fighting the traffic
                        (--set affinity_max_buckets=N caps growth,
                        default 64)

CONTINUOUS BATCHING (serve)
  --continuous-batching
                        replace the one-shot fixed-batch loop with the
                        iteration-level scheduler: sequences join and
                        leave the in-flight batch at every step
                        boundary and responses stream back as chunks
                        (STREAM protocol verb) with per-client
                        backpressure — a slow reader stalls only its
                        own slot, never the batch
  --no-continuous-batching
                        force the legacy fixed-batch loop (the
                        default; overrides --set
                        continuous_batching=on for A/B runs)
  --max-inflight N      in-flight sequence slots per replica under
                        continuous batching (default 32)
  --client-stall-ms N   stall budget before a backpressured sequence
                        yields its slot and parks (default 50); it
                        rejoins once its client drains a chunk
  --chunk-depth N       bounded per-client response channel depth
                        (default 4): the backpressure window between
                        the scheduler and a streaming reader

SHARED MEMO TIER (serve/eval)
  --replicas N          engine replicas pulling from one request queue;
                        all replicas share one online memo tier, so a
                        miss warmed by one is a hit for every other
                        (serve only; also settable via --set replicas=N)
  --load-warm FILE      restore the online tier's warm state from an
                        ATWM snapshot before serving (see
                        docs/PERSISTENCE.md)
  --save-warm FILE      persist the online tier's warm state: eval saves
                        once after the run; serve snapshots periodically
  --warm-snapshot-secs N  interval between periodic serve snapshots
                        (default 60; needs --save-warm)

COMMON FLAGS
  --artifacts DIR   artifacts directory (default ./artifacts or
                    $ATTMEMO_ARTIFACTS)
";

/// CLI entrypoint (also driven by integration tests).
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    if let Some(dir) = args.opt("artifacts") {
        std::env::set_var("ATTMEMO_ARTIFACTS", dir);
    }
    match args.command.as_str() {
        "" | "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        "info" => cmd_info(),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "build-db" => cmd_build_db(&args),
        "eval" => cmd_eval(&args),
        other => Err(Error::config(format!(
            "unknown command {other:?} (try `attmemo help`)"
        ))),
    }
}

fn cmd_info() -> Result<()> {
    let rt = workload::open_runtime()?;
    let a = rt.artifacts();
    println!("artifacts: {}", a.root().display());
    println!("vocab_size: {}", a.vocab_size);
    println!("serving_seq_len: {}  batches: {:?}", a.serving_seq_len,
             a.serving_batches);
    for fam in a.family_names() {
        let f = a.family(fam)?;
        println!(
            "  {fam:<8} layers={} hidden={} heads={} acc={:.3} sparse={:?}",
            f.config.layers,
            f.config.hidden,
            f.config.heads,
            f.accuracy,
            f.sparse_variants.iter().map(|s| s.tag.as_str()).collect::<Vec<_>>()
        );
    }
    println!("graphs lowered: {}", a.graphs().len());
    Ok(())
}

fn parse_level(args: &Args) -> Result<MemoLevel> {
    MemoLevel::parse(&args.opt_or("level", "moderate"))
}

/// Memoization options shared by `serve` and `eval`: level + selective
/// policy + the online-admission knobs.
fn parse_memo(args: &Args, level: MemoLevel) -> Result<MemoConfig> {
    let defaults = MemoConfig::default();
    // The kernel-dispatch switch is process-global (the primitives sit
    // under loops too hot for a per-call flag); apply it as soon as the
    // config is parsed so every later code path agrees.
    if args.flag("scalar-kernels") {
        crate::kernels::set_scalar_kernels(true);
    }
    Ok(MemoConfig {
        level,
        selective: !args.flag("no-selective"),
        // The warm-state and cold-tier flags imply an online tier:
        // loading restores into one, saving without one would silently
        // write nothing, and a spill directory without a tier to spill
        // from would silently do nothing.
        online_admission: args.flag("online-admission")
            || args.flag("cold-db")
            || args.opt("load-warm").is_some()
            || args.opt("save-warm").is_some()
            || args.opt("cold-tier-dir").is_some(),
        max_db_entries: args.opt_usize("db-capacity",
                                       defaults.max_db_entries)?,
        admission_min_attempts: args.opt_usize(
            "admission-warmup",
            defaults.admission_min_attempts as usize,
        )? as u64,
        intra_batch_dedup: !args.flag("no-dedup"),
        dedup_prepass: !args.flag("no-dedup-prepass"),
        cold_tier_dir: args
            .opt("cold-tier-dir")
            .map(std::path::PathBuf::from),
        cold_capacity: args.opt_usize("cold-capacity",
                                      defaults.cold_capacity)?,
        scalar_kernels: args.flag("scalar-kernels"),
        ..defaults
    })
}

/// The shared online tier for `serve`/`eval`: `None` when online
/// memoization is off, a warm-state restore when `--load-warm` is given,
/// a cold tier otherwise.
fn parse_online_tier(args: &Args, rt: &Arc<crate::runtime::Runtime>,
                     family: &str, seq_len: usize, level: MemoLevel,
                     memo: &MemoConfig) -> Result<Option<Arc<MemoTier>>> {
    if !memo.online_admission || level == MemoLevel::Off {
        return Ok(None);
    }
    let cfg = rt.artifacts().family(family)?.config.clone();
    let mut tier = match args.opt("load-warm") {
        Some(path) => {
            let (tier, saved_thr) = crate::memo::persist::load_warm(
                std::path::Path::new(path), &cfg, memo, Default::default())?;
            println!(
                "loaded warm state from {path}: {} entries \
                 (saved at threshold {saved_thr:.4})",
                tier.total_entries()
            );
            tier
        }
        None => MemoTier::new(&cfg, seq_len, Default::default(), memo),
    };
    if memo.cold_tier_dir.is_some() {
        // Works for both the fresh and the warm-restored tier: the cold
        // shards take their dimensions from the hot tier.
        tier.attach_cold_tier(memo)?;
        println!(
            "cold tier: {} spilled entries recovered (budget {}/layer)",
            tier.cold_entries(),
            memo.cold_capacity
        );
    }
    Ok(Some(Arc::new(tier)))
}

/// The offline database for `serve`/`eval`: none when cold or off,
/// loaded from `--load-db`, or built from `--db-seqs` training sequences.
fn load_or_build_db(args: &Args, rt: &Arc<crate::runtime::Runtime>,
                    family: &str, seq_len: usize, level: MemoLevel)
    -> Result<Option<Arc<crate::memo::builder::BuiltDb>>> {
    if level == MemoLevel::Off || args.flag("cold-db") {
        return Ok(None);
    }
    if let Some(path) = args.opt("load-db") {
        let cfg = rt.artifacts().family(family)?.config.clone();
        let built = crate::memo::persist::load(
            std::path::Path::new(path), &cfg, Default::default())?;
        return Ok(Some(Arc::new(built)));
    }
    let db_seqs = args.opt_usize("db-seqs", 256)?;
    log::info!("building attention database ({db_seqs} seqs)…");
    Ok(Some(Arc::new(workload::build_db(rt, family, seq_len, db_seqs)?)))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let rt = workload::open_runtime()?;
    let family = args.opt_or("family", "bert");
    let level = parse_level(args)?;
    let mut cfg = ServingConfig {
        seq_len: rt.artifacts().serving_seq_len,
        ..ServingConfig::default()
    };
    for (k, v) in &args.sets {
        cfg.set(k, v)?;
    }
    cfg.replicas = args.opt_usize("replicas", cfg.replicas)?.max(1);
    cfg.affinity_buckets = args
        .opt_usize("affinity-buckets", cfg.affinity_buckets)?
        .max(1);
    if let Some(mode) = args.opt("signature-mode") {
        cfg.signature_mode = SignatureMode::parse(mode)?;
        // An explicit flag must not silently degrade: the server errors
        // at startup when semantic mode is requested without a usable
        // embedding table (a config *default* still warns + falls back).
        cfg.signature_explicit = true;
    }
    cfg.signature_prefix_len = args
        .opt_usize("signature-prefix-len", cfg.signature_prefix_len)?
        .max(1);
    if args.flag("adaptive-buckets") {
        cfg.affinity_adaptive = true;
    }
    if args.flag("no-affinity") {
        // The documented contract is a single shared FIFO: pin the
        // bucket count too, or adaptive growth would quietly re-enable
        // affinity routing after one steal-heavy window.
        cfg.affinity_buckets = 1;
        cfg.affinity_adaptive = false;
    }
    if args.flag("continuous-batching") {
        cfg.continuous_batching = true;
    }
    if args.flag("no-continuous-batching") {
        // The explicit off-switch wins over --set for easy A/B runs.
        cfg.continuous_batching = false;
    }
    cfg.max_inflight =
        args.opt_usize("max-inflight", cfg.max_inflight)?.max(1);
    cfg.client_stall_ms = args
        .opt_usize("client-stall-ms", cfg.client_stall_ms as usize)?
        as u64;
    cfg.chunk_depth =
        args.opt_usize("chunk-depth", cfg.chunk_depth)?.max(1);
    let memo = parse_memo(args, level)?;
    let built = load_or_build_db(args, &rt, &family, cfg.seq_len, level)?;
    let tier =
        parse_online_tier(args, &rt, &family, cfg.seq_len, level, &memo)?;

    // N engine replicas: one model runner each, one shared memo tier.
    let mut engines = Vec::with_capacity(cfg.replicas);
    for _ in 0..cfg.replicas {
        engines.push(match &tier {
            Some(t) => workload::engine_with_tier(
                &rt, &family, cfg.seq_len, memo.clone(), built.clone(),
                t.clone())?,
            None => workload::engine_with_memo(
                &rt, &family, cfg.seq_len, memo.clone(), built.clone())?,
        });
    }
    let threshold = engines[0].threshold();

    // Periodic warm snapshots keep restarts warm even without a clean
    // shutdown path (the serve loop runs until killed).
    if let (Some(t), Some(path)) = (&tier, args.opt("save-warm")) {
        let every = args.opt_usize("warm-snapshot-secs", 60)?.max(1) as u64;
        let t = t.clone();
        let path = std::path::PathBuf::from(path);
        std::thread::Builder::new()
            .name("attmemo-warm-snapshot".into())
            .spawn(move || loop {
                std::thread::sleep(std::time::Duration::from_secs(every));
                match crate::memo::persist::save_warm(&t, threshold, &path) {
                    Ok(()) => log::info!(
                        "warm snapshot: {} entries → {}",
                        t.total_entries(),
                        path.display()
                    ),
                    Err(e) => log::error!("warm snapshot failed: {e}"),
                }
            })
            .expect("spawn warm-snapshot thread");
    }

    let vocab = Arc::new(Vocab::load(&rt.artifacts().root().join("vocab.json"))?);
    let server = Server::start(engines, vocab, cfg.clone())?;
    println!(
        "serving {family} (level={}, replicas={}) on {}",
        level.name(),
        cfg.replicas,
        server.addr
    );
    println!("press ctrl-c to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.opt_or("addr", "127.0.0.1:7191");
    let n = args.opt_usize("n", 10)?;
    let text = args.opt_or("text", "the film was great");
    let mut client = Client::connect(&addr)?;
    for i in 0..n {
        let (label, hits, ms) = client.infer(&text)?;
        println!("[{i}] label={label} memo_hits={hits} latency={ms:.2} ms");
    }
    println!("{}", client.stats()?);
    client.quit()
}

fn cmd_build_db(args: &Args) -> Result<()> {
    let rt = workload::open_runtime()?;
    let family = args.opt_or("family", "bert");
    let seq_len = rt.artifacts().serving_seq_len;
    let db_seqs = args.opt_usize("db-seqs", 256)?;
    let built = workload::build_db(&rt, &family, seq_len, db_seqs)?;
    if let Some(path) = args.opt("save") {
        crate::memo::persist::save(&built, std::path::Path::new(path))?;
        println!("saved database to {path}");
    }
    println!("family: {family}");
    println!("sequences ingested: {}", built.sequences);
    println!("entries: {}", built.db.total_entries());
    println!(
        "db size: {:.1} MiB",
        built.db.resident_bytes() as f64 / (1 << 20) as f64
    );
    println!("indexing time: {:.2} s", built.indexing_seconds);
    println!("build time: {:.2} s", built.build_seconds);
    println!(
        "thresholds: cons={:.4} mod={:.4} aggr={:.4}",
        built.thresholds.conservative,
        built.thresholds.moderate,
        built.thresholds.aggressive
    );
    for (li, p) in built.profiles.iter().enumerate() {
        println!(
            "  layer {li}: t_attn={:.3}s t_overhead={:.3}s alpha={:.3}",
            p.t_attn, p.t_overhead, p.alpha
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let rt = workload::open_runtime()?;
    let family = args.opt_or("family", "bert");
    let level = parse_level(args)?;
    let seq_len = rt.artifacts().serving_seq_len;
    let batch = args.opt_usize("batch", 8)?;
    let n = args.opt_usize("n", 64)?;
    let (ids, labels) = workload::test_workload(&rt, &family, seq_len, n)?;
    let memo = parse_memo(args, level)?;
    let built = load_or_build_db(args, &rt, &family, seq_len, level)?;
    let tier = parse_online_tier(args, &rt, &family, seq_len, level, &memo)?;
    let mut engine = match &tier {
        Some(t) => workload::engine_with_tier(
            &rt, &family, seq_len, memo.clone(), built, t.clone())?,
        None => workload::engine_with_memo(&rt, &family, seq_len, memo,
                                           built)?,
    };
    let baseline = level == MemoLevel::Off;
    let r = evaluate(&mut engine, &ids, &labels, batch, baseline)?;
    if let (Some(t), Some(path)) = (&tier, args.opt("save-warm")) {
        crate::memo::persist::save_warm(
            t, engine.threshold(), std::path::Path::new(path))?;
        println!("saved warm state ({} entries) to {path}",
                 t.total_entries());
    }
    println!(
        "family={family} level={} n={} acc={:.4} time={:.2}s \
         throughput={:.2} seq/s memo_rate={:.3}",
        level.name(),
        r.sequences,
        r.accuracy(),
        r.seconds,
        r.throughput(),
        r.memo_rate
    );
    if args.flag("stages") {
        let st = &mut engine.stats.stages;
        println!(
            "stages (ms, mean per batch-layer): embed={:.2} search={:.2} \
             map={:.2} scores={:.2} apply={:.2}",
            st.embedding_ms.mean(),
            st.search_ms.mean(),
            st.mapping_ms.mean(),
            st.scores_ms.mean(),
            st.apply_ms.mean()
        );
        for (li, l) in engine.stats.layers.iter().enumerate() {
            println!(
                "  layer {li}: total={} attempts={} hits={} skipped={} \
                 reverted={} admitted={} evicted={} deduped={} demoted={}",
                l.total, l.attempts, l.hits, l.skipped, l.reverted,
                l.admitted, l.evicted, l.deduped, l.demoted
            );
        }
        if let Some(t) = engine.online() {
            println!(
                "  online tier: entries={} capacity/layer={} deduped={} \
                 publishes={} publish_skips={} forced_reclaims={}",
                t.total_entries(),
                t.capacity(),
                t.deduped(),
                t.publishes(),
                t.publish_skips(),
                t.forced_reclaims()
            );
            if let Some(c) = t.cold() {
                println!(
                    "  cold tier: entries={} capacity/layer={} \
                     cold_hits={} promotions={} demotions={} \
                     resident={:.1} MiB",
                    t.cold_entries(),
                    c.capacity(),
                    t.cold_hits(),
                    t.promotions(),
                    t.demotions(),
                    t.cold_resident_bytes() as f64 / (1 << 20) as f64
                );
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_options_flags_and_sets() {
        let a = Args::parse(&argv(&[
            "eval", "--family", "bert", "--no-selective", "--set",
            "max_batch=8", "--n", "32",
        ]))
        .unwrap();
        assert_eq!(a.command, "eval");
        assert_eq!(a.opt("family"), Some("bert"));
        assert!(a.flag("no-selective"));
        assert_eq!(a.sets, vec![("max_batch".into(), "8".into())]);
        assert_eq!(a.opt_usize("n", 0).unwrap(), 32);
    }

    #[test]
    fn rejects_bad_numbers_and_args() {
        let a = Args::parse(&argv(&["eval", "--n", "xyz"])).unwrap();
        assert!(a.opt_usize("n", 0).is_err());
        assert!(Args::parse(&argv(&["eval", "stray"])).is_err());
        assert!(Args::parse(&argv(&["x", "--set", "novalue"])).is_err());
    }

    #[test]
    fn affinity_flags_parse() {
        let a = Args::parse(&argv(&[
            "serve", "--affinity-buckets", "4", "--no-affinity",
        ]))
        .unwrap();
        assert_eq!(a.opt_usize("affinity-buckets", 8).unwrap(), 4);
        assert!(a.flag("no-affinity"));
    }

    #[test]
    fn signature_flags_parse() {
        let a = Args::parse(&argv(&[
            "serve", "--signature-mode", "semantic",
            "--signature-prefix-len", "16", "--adaptive-buckets",
        ]))
        .unwrap();
        assert_eq!(a.opt("signature-mode"), Some("semantic"));
        assert_eq!(
            SignatureMode::parse(a.opt("signature-mode").unwrap()).unwrap(),
            SignatureMode::Semantic
        );
        assert_eq!(a.opt_usize("signature-prefix-len", 32).unwrap(), 16);
        assert!(a.flag("adaptive-buckets"));
    }

    #[test]
    fn continuous_batching_flags_parse() {
        let a = Args::parse(&argv(&[
            "serve", "--continuous-batching", "--max-inflight", "16",
            "--client-stall-ms", "20", "--chunk-depth", "2",
        ]))
        .unwrap();
        assert!(a.flag("continuous-batching"));
        assert!(!a.flag("no-continuous-batching"));
        assert_eq!(a.opt_usize("max-inflight", 32).unwrap(), 16);
        assert_eq!(a.opt_usize("client-stall-ms", 50).unwrap(), 20);
        assert_eq!(a.opt_usize("chunk-depth", 4).unwrap(), 2);
    }

    #[test]
    fn no_continuous_batching_is_a_bare_flag() {
        let a =
            Args::parse(&argv(&["serve", "--no-continuous-batching"]))
                .unwrap();
        assert!(a.flag("no-continuous-batching"));
    }

    #[test]
    fn cold_tier_flags_parse() {
        let a = Args::parse(&argv(&[
            "eval", "--cold-tier-dir", "/tmp/attmemo-cold",
            "--cold-capacity", "512",
        ]))
        .unwrap();
        let memo = parse_memo(&a, MemoLevel::Moderate).unwrap();
        assert_eq!(
            memo.cold_tier_dir,
            Some(std::path::PathBuf::from("/tmp/attmemo-cold"))
        );
        assert_eq!(memo.cold_capacity, 512);
        assert!(memo.online_admission,
                "a spill directory implies the online tier");
    }

    #[test]
    fn scalar_kernels_flag_parses_and_forces_fallback() {
        let before = crate::kernels::scalar_forced();
        let a = Args::parse(&argv(&["eval", "--scalar-kernels"])).unwrap();
        let memo = parse_memo(&a, MemoLevel::Moderate).unwrap();
        assert!(memo.scalar_kernels);
        assert!(crate::kernels::scalar_forced(),
                "parse_memo must apply the process-global switch");
        // Restore: the switch is global to the test process (it may
        // have been forced by the environment, e.g. the CI scalar leg).
        crate::kernels::set_scalar_kernels(before);
        let a = Args::parse(&argv(&["eval"])).unwrap();
        assert!(!parse_memo(&a, MemoLevel::Moderate).unwrap().scalar_kernels);
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&argv(&["definitely-not-a-command"])).is_err());
    }

    #[test]
    fn help_succeeds() {
        run(&argv(&["help"])).unwrap();
    }
}
