//! Host-side numeric helpers used by the coordinator.
//!
//! The heavy inner loops (Eq. 1 total variation, index distances, the
//! softmax reductions) route through the unified kernel layer in
//! [`crate::kernels`], which owns SIMD dispatch and the scalar A/B
//! fallback; this module keeps the shape-aware wrappers and the odd
//! small utility.

use crate::kernels::simd;

/// Paper Eq. 1 over a single pair of attention matrices, flattened
/// `[heads * rows, cols]`: `1 − mean_row(0.5 · ‖a_row − b_row‖₁)`.
///
/// Both inputs must hold row-stochastic rows (softmax outputs), which keeps
/// the result in `[0, 1]`.
pub fn similarity_score(a: &[f32], b: &[f32], rows: usize, cols: usize) -> f32 {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert_eq!(b.len(), rows * cols);
    let mut tv_sum = 0.0f64;
    for r in 0..rows {
        let ra = &a[r * cols..(r + 1) * cols];
        let rb = &b[r * cols..(r + 1) * cols];
        tv_sum += 0.5 * l1_distance(ra, rb) as f64;
    }
    (1.0 - tv_sum / rows as f64) as f32
}

/// L1 distance (dispatched kernel; see `kernels::simd::l1_distance`).
#[inline]
pub fn l1_distance(a: &[f32], b: &[f32]) -> f32 {
    simd::l1_distance(a, b)
}

/// Squared L2 distance (dispatched kernel; HNSW hot loop).
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    simd::l2_sq(a, b)
}

/// Row-wise softmax in place over `[rows, cols]`, reductions through
/// the kernel layer.
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let m = simd::max_reduce(row);
        for v in row.iter_mut() {
            *v = (*v - m).exp();
        }
        let sum = simd::sum_reduce(row);
        let inv = if sum > 0.0 { 1.0 / sum } else { 0.0 };
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Argmax of a slice.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// Check every row of `[rows, cols]` sums to ~1 (APM sanity).
pub fn rows_stochastic(x: &[f32], rows: usize, cols: usize, tol: f32) -> bool {
    (0..rows).all(|r| {
        let s: f32 = x[r * cols..(r + 1) * cols].iter().sum();
        (s - 1.0).abs() <= tol
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l1_and_l2_match_naive() {
        let a: Vec<f32> = (0..13).map(|x| x as f32 * 0.3).collect();
        let b: Vec<f32> = (0..13).map(|x| (13 - x) as f32 * 0.2).collect();
        let naive1: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        let naive2: f32 =
            a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!((l1_distance(&a, &b) - naive1).abs() < 1e-4);
        assert!((l2_sq(&a, &b) - naive2).abs() < 1e-4);
    }

    #[test]
    fn similarity_identity_is_one() {
        let mut x = vec![0.2f32; 20];
        softmax_rows(&mut x, 4, 5);
        assert!((similarity_score(&x, &x, 4, 5) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn similarity_disjoint_is_zero() {
        // Two one-hot distributions with disjoint support: TV = 1.
        let a = vec![1.0, 0.0, 0.0, 1.0, 0.0, 0.0];
        let b = vec![0.0, 1.0, 0.0, 0.0, 0.0, 1.0];
        let s = similarity_score(&a, &b, 2, 3);
        assert!(s.abs() < 1e-6, "{s}");
    }

    #[test]
    fn similarity_in_unit_interval() {
        let mut rng = crate::util::Pcg32::seeded(3);
        for _ in 0..20 {
            let mut a: Vec<f32> = (0..32).map(|_| rng.next_f32()).collect();
            let mut b: Vec<f32> = (0..32).map(|_| rng.next_f32()).collect();
            softmax_rows(&mut a, 4, 8);
            softmax_rows(&mut b, 4, 8);
            let s = similarity_score(&a, &b, 4, 8);
            assert!((0.0..=1.0).contains(&s), "{s}");
        }
    }

    #[test]
    fn softmax_rows_are_stochastic() {
        let mut x: Vec<f32> = (0..24).map(|i| (i % 7) as f32).collect();
        softmax_rows(&mut x, 4, 6);
        assert!(rows_stochastic(&x, 4, 6, 1e-5));
    }

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[1.0, 5.0, 5.0, 2.0]), 1);
        assert_eq!(argmax(&[3.0]), 0);
    }
}
