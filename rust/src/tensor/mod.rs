//! Host-side f32 tensors and the numeric helpers the coordinator needs.

pub mod ops;
#[allow(clippy::module_inception)]
pub mod tensor;

pub use tensor::Tensor;
