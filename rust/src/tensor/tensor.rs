//! A minimal dense row-major f32 tensor.
//!
//! The coordinator moves activations between artifacts, the attention
//! database, and PJRT literals; it needs shapes, slicing along the leading
//! axis, and conversion to/from `xla::Literal` — nothing close to a full
//! ndarray.

use crate::{Error, Result};

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from parts; validates element count.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::shape(format!(
                "shape {shape:?} wants {n} elems, got {}",
                data.len()
            )));
        }
        Ok(Tensor { shape, data })
    }

    /// All-zero tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Filled with a PCG stream (tests / synthetic workloads).
    pub fn random(shape: &[usize], rng: &mut crate::util::Pcg32) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.next_gaussian()).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::shape(format!(
                "reshape {:?} -> {shape:?}",
                self.shape
            )));
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Slice `count` items starting at `start` along axis 0 (copying).
    pub fn slice0(&self, start: usize, count: usize) -> Result<Tensor> {
        if self.shape.is_empty() || start + count > self.shape[0] {
            return Err(Error::shape(format!(
                "slice0 [{start}, {}) of shape {:?}",
                start + count,
                self.shape
            )));
        }
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = count;
        Ok(Tensor {
            shape,
            data: self.data[start * row..(start + count) * row].to_vec(),
        })
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let cols = self.shape[self.shape.len() - 1];
        &self.data[i * cols..(i + 1) * cols]
    }

    /// Concatenate along axis 0; shapes beyond axis 0 must agree.
    pub fn concat0(parts: &[&Tensor]) -> Result<Tensor> {
        let first = parts
            .first()
            .ok_or_else(|| Error::shape("concat0 of nothing"))?;
        let tail = &first.shape[1..];
        let mut n0 = 0;
        for p in parts {
            if &p.shape[1..] != tail {
                return Err(Error::shape(format!(
                    "concat0 mismatch {:?} vs {:?}",
                    p.shape, first.shape
                )));
            }
            n0 += p.shape[0];
        }
        let mut shape = first.shape.clone();
        shape[0] = n0;
        let mut data = Vec::with_capacity(shape.iter().product());
        for p in parts {
            data.extend_from_slice(&p.data);
        }
        Ok(Tensor { shape, data })
    }

    /// Convert to an `xla::Literal` (f32).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    /// Convert from an `xla::Literal` (f32, any rank).
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Tensor::new(dims, data)
    }

    /// Maximum absolute difference against another tensor of equal shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            return Err(Error::shape(format!(
                "diff {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max))
    }
}

/// i32 ids tensor (token ids); kept separate from the f32 `Tensor`.
#[derive(Debug, Clone, PartialEq)]
pub struct IdTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IdTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::shape(format!(
                "ids shape {shape:?} wants {n}, got {}",
                data.len()
            )));
        }
        Ok(IdTensor { shape, data })
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    /// Rows [start, start+count) of a [N, L] id matrix.
    pub fn slice0(&self, start: usize, count: usize) -> Result<IdTensor> {
        let row: usize = self.shape[1..].iter().product();
        if start + count > self.shape[0] {
            return Err(Error::shape("ids slice0 out of range"));
        }
        let mut shape = self.shape.clone();
        shape[0] = count;
        IdTensor::new(
            shape,
            self.data[start * row..(start + count) * row].to_vec(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_count() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn slice0_and_row() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let s = t.slice0(1, 2).unwrap();
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[3., 4., 5., 6.]);
        assert_eq!(t.row(2), &[5., 6.]);
        assert!(t.slice0(2, 2).is_err());
    }

    #[test]
    fn concat0_roundtrip() {
        let a = Tensor::new(vec![1, 2], vec![1., 2.]).unwrap();
        let b = Tensor::new(vec![2, 2], vec![3., 4., 5., 6.]).unwrap();
        let c = Tensor::concat0(&[&a, &b]).unwrap();
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.data(), &[1., 2., 3., 4., 5., 6.]);
        let bad = Tensor::new(vec![1, 3], vec![0.; 3]).unwrap();
        assert!(Tensor::concat0(&[&a, &bad]).is_err());
    }

    #[test]
    fn reshape_checks() {
        let t = Tensor::zeros(&[4, 2]);
        assert!(t.clone().reshape(&[2, 4]).is_ok());
        assert!(t.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Tensor::new(vec![2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(vec![2], vec![1.5, 1.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b).unwrap(), 1.0);
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect())
            .unwrap();
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }
}
