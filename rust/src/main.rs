//! AttMemo CLI entrypoint (leader process).

fn main() {
    attmemo::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    match attmemo::run_cli(&args) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
}
