//! The cold spill tier: file-backed overflow storage under the hot
//! seqlock shards.
//!
//! The paper's premise is an attention database *larger than DRAM*
//! served from big memory. [`ColdTier`] takes that seriously: when the
//! hot tier's clock evicts an entry, the tier demotes it here instead
//! of dropping it — payload APMs move into a *file-backed* [`ApmArena`]
//! (the same page-aligned slot/epoch discipline as the hot memfd store,
//! but on a regular file an operator points at NVMe), while the feature
//! vectors stay DRAM-resident so the nearest-neighbour probe never
//! touches the disk path. A hot-snapshot miss falls through to a cold
//! probe (`MemoTier::lookup_fetch`); a qualifying cold hit is
//! *promoted*: its payload is served, the entry leaves the cold shard,
//! and it re-enters the hot tier through the ordinary admission path —
//! an entry is never live in both tiers.
//!
//! **Concurrency.** Each layer shard is an `RwLock`: probes share the
//! read lock; demotions and promotions take the write lock (demotions
//! run on the hot tier's writer path, which already serializes per
//! shard). On top of the lock, every payload read revalidates the same
//! tenancy-epoch stamps the hot tier uses ([`ApmArena::get_checked`] /
//! [`ApmArena::recheck`]) before *and* after the copy, so even a future
//! lock-free cold read path — or a bug that leaked a stale stamp — can
//! never serve a recycled slot's foreign bytes.
//!
//! **Recovery.** Payload bytes alone are not a database: each shard
//! pairs its arena file (`cold-layerN.apm`) with an append-only *index
//! log* (`cold-layerN.idx`, magic `ATCD` — versioned in `memo::persist`
//! alongside the other on-disk formats, layout in
//! `docs/PERSISTENCE.md`) recording id→slot mappings, per-payload
//! checksums and the DRAM-resident features. A demotion writes the
//! payload bytes through the shared mapping first and appends its ADD
//! record second, so opening a directory can replay the log and drop
//! every record whose payload bytes are missing, out of range or fail
//! their checksum — a crash mid-demotion truncates to a clean miss,
//! never a torn entry — then rewrite both files compacted.

use std::collections::VecDeque;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::RwLock;

use crate::memo::arena::{page_align, ApmArena, ApmId};
use crate::memo::persist::{COLD_COMPAT_VERSIONS, COLD_MAGIC, COLD_VERSION};
use crate::{Error, Result};

/// FNV-1a over the little-endian bytes of a payload — the per-record
/// integrity check that turns torn cold slots into clean misses.
fn fnv1a_f32s(xs: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Index-log record tags.
const TAG_ADD: u8 = 1;
const TAG_DEL: u8 = 2;

/// Index-log header: magic, version, embed_dim, apm_elems.
const IDX_HEADER: usize = 16;

fn write_header(f: &mut std::fs::File, embed_dim: usize,
                apm_elems: usize) -> Result<()> {
    f.write_all(COLD_MAGIC)?;
    f.write_all(&COLD_VERSION.to_le_bytes())?;
    f.write_all(&(embed_dim as u32).to_le_bytes())?;
    f.write_all(&(apm_elems as u32).to_le_bytes())?;
    Ok(())
}

/// Serialized ADD record: tag, cold id, physical slot, payload
/// checksum, feature vector.
fn add_record(id: u64, slot: u32, apm: &[f32],
              feature: &[f32]) -> Vec<u8> {
    let mut rec = Vec::with_capacity(21 + feature.len() * 4);
    rec.push(TAG_ADD);
    rec.extend_from_slice(&id.to_le_bytes());
    rec.extend_from_slice(&slot.to_le_bytes());
    rec.extend_from_slice(&fnv1a_f32s(apm).to_le_bytes());
    for x in feature {
        rec.extend_from_slice(&x.to_le_bytes());
    }
    rec
}

/// A qualifying cold hit taken out of the cold shard for promotion: the
/// entry's stored feature vector (the hot tier re-admits under it) and
/// its similarity to the probe.
#[derive(Debug, Clone)]
pub struct ColdPromotion {
    /// Feature vector the entry was stored under.
    pub feature: Vec<f32>,
    /// Similarity `1 − ‖e(q) − e(x)‖₂` of the probe to that feature.
    pub similarity: f32,
}

/// One live cold entry: payload in the file-backed arena; id, feature
/// and the arena's tenancy-epoch stamp in DRAM.
struct ColdEntry {
    id: u64,
    apm: ApmId,
    stamp: u64,
    feature: Vec<f32>,
}

/// Mutable state of one cold layer shard.
struct ColdInner {
    arena: ApmArena,
    /// Live entries in FIFO (ascending cold-id) order; the front is the
    /// eviction victim when the shard is at its budget.
    entries: VecDeque<ColdEntry>,
    next_id: u64,
    log: std::fs::File,
    idx_path: PathBuf,
    /// Records appended since the log was created or last rewritten;
    /// past `4 × capacity + 64` the log is compacted in place.
    log_writes: usize,
}

impl ColdInner {
    /// Append one ADD record (best ordering: the caller already wrote
    /// the payload bytes through the arena's shared mapping, so a crash
    /// between the two leaves an unreferenced payload, never a
    /// referenced hole).
    fn log_add(&mut self, id: u64, slot: u32, apm: &[f32],
               feature: &[f32]) -> Result<()> {
        self.log.write_all(&add_record(id, slot, apm, feature))?;
        self.log_writes += 1;
        Ok(())
    }

    fn log_del(&mut self, id: u64) -> Result<()> {
        let mut rec = [0u8; 9];
        rec[0] = TAG_DEL;
        rec[1..9].copy_from_slice(&id.to_le_bytes());
        self.log.write_all(&rec)?;
        self.log_writes += 1;
        Ok(())
    }

    /// Insert with a caller-chosen id (recovery preserves prior ids;
    /// live inserts pass `next_id`).
    fn insert_with_id(&mut self, id: u64, feature: &[f32],
                      apm: &[f32]) -> Result<()> {
        let apm_id = self.arena.push(apm)?;
        let stamp = self.arena.epoch(apm_id)?;
        let slot =
            (self.arena.file_offset(apm_id)? / self.arena.stride()) as u32;
        if let Err(e) = self.log_add(id, slot, apm, feature) {
            // Keep memory and log consistent: an unlogged entry would
            // survive in DRAM but vanish (or tear) across a restart.
            let _ = self.arena.remove(apm_id);
            return Err(e);
        }
        self.entries.push_back(ColdEntry {
            id,
            apm: apm_id,
            stamp,
            feature: feature.to_vec(),
        });
        Ok(())
    }

    /// Compact the append-only log once DEL/ADD churn dominates:
    /// rewrite it from the live entries (sibling temp file + rename, so
    /// a crash mid-rewrite keeps the previous good log) and reopen it
    /// for appending.
    fn maybe_rewrite_log(&mut self, capacity: usize,
                         embed_dim: usize) -> Result<()> {
        if self.log_writes <= 4 * capacity + 64 {
            return Ok(());
        }
        let mut tmp = self.idx_path.as_os_str().to_owned();
        tmp.push(".tmp");
        let tmp = PathBuf::from(tmp);
        {
            let mut f = std::fs::File::create(&tmp)?;
            write_header(&mut f, embed_dim, self.arena.entry_elems())?;
            for e in &self.entries {
                let apm = self.arena.get(e.apm)?;
                let slot = (self.arena.file_offset(e.apm)?
                    / self.arena.stride()) as u32;
                f.write_all(&add_record(e.id, slot, apm, &e.feature))?;
            }
            f.flush()?;
        }
        std::fs::rename(&tmp, &self.idx_path)?;
        self.log = std::fs::OpenOptions::new()
            .append(true)
            .open(&self.idx_path)?;
        self.log_writes = 0;
        Ok(())
    }
}

/// One per-layer cold shard plus its lock-free stat gauges.
struct ColdShard {
    inner: RwLock<ColdInner>,
    len: AtomicUsize,
    resident: AtomicUsize,
}

/// Index and squared distance of the nearest entry (linear scan — the
/// features are DRAM-resident and cold probes only run after a hot
/// miss, so the scan is off the hot path by construction). The
/// per-entry distance goes through the dispatched SIMD kernel, the same
/// primitive the hot index uses.
fn nearest(entries: &VecDeque<ColdEntry>,
           feature: &[f32]) -> Option<(usize, f32)> {
    let mut best: Option<(usize, f32)> = None;
    for (i, e) in entries.iter().enumerate() {
        let d2 = crate::kernels::simd::l2_sq(&e.feature, feature);
        if best.map_or(true, |(_, bd)| d2 < bd) {
            best = Some((i, d2));
        }
    }
    best.map(|(i, d2)| (i, 1.0 - d2.sqrt()))
}

/// Replay one shard's index log against its payload file: the surviving
/// `(id, feature, payload)` records, ascending by id, truncated to
/// `capacity` (newest kept). Missing files mean an empty shard; a short
/// or unknown-tag tail means the log was torn by a crash — replay stops
/// there. Records whose payload bytes are out of range or fail their
/// checksum are dropped (a torn demotion resolves as a clean miss).
/// Wrong magic, an unsupported version or mismatched dimensions are
/// hard errors: the directory belongs to another format or family.
fn recover(apm_path: &Path, idx_path: &Path, embed_dim: usize,
           apm_elems: usize, capacity: usize)
           -> Result<Vec<(u64, Vec<f32>, Vec<f32>)>> {
    let Ok(idx) = std::fs::read(idx_path) else {
        return Ok(Vec::new());
    };
    if idx.len() < IDX_HEADER {
        // A crash can truncate even the header: nothing durable yet.
        return Ok(Vec::new());
    }
    if &idx[0..4] != COLD_MAGIC {
        return Err(Error::memo(format!(
            "{}: not an ATCD cold index log",
            idx_path.display()
        )));
    }
    let version = u32::from_le_bytes(idx[4..8].try_into().unwrap());
    if !COLD_COMPAT_VERSIONS.contains(&version) {
        return Err(Error::memo(format!(
            "ATCD version {version} unsupported (this build reads \
             {COLD_COMPAT_VERSIONS:?}); clear the cold dir to start cold"
        )));
    }
    let dim = u32::from_le_bytes(idx[8..12].try_into().unwrap()) as usize;
    let elems =
        u32::from_le_bytes(idx[12..16].try_into().unwrap()) as usize;
    if dim != embed_dim || elems != apm_elems {
        return Err(Error::memo(format!(
            "ATCD dims (dim {dim}, elems {elems}) do not match the \
             configured family (dim {embed_dim}, elems {apm_elems})"
        )));
    }
    let payload = std::fs::read(apm_path).unwrap_or_default();
    let stride = page_align(apm_elems * 4);
    let mut live: std::collections::BTreeMap<u64, (Vec<f32>, Vec<f32>)> =
        std::collections::BTreeMap::new();
    let add_len = 21 + embed_dim * 4;
    let mut pos = IDX_HEADER;
    let mut torn = 0usize;
    loop {
        let Some(&tag) = idx.get(pos) else { break };
        match tag {
            TAG_ADD => {
                if pos + add_len > idx.len() {
                    break; // torn tail
                }
                let id = u64::from_le_bytes(
                    idx[pos + 1..pos + 9].try_into().unwrap(),
                );
                let slot = u32::from_le_bytes(
                    idx[pos + 9..pos + 13].try_into().unwrap(),
                ) as usize;
                let sum = u64::from_le_bytes(
                    idx[pos + 13..pos + 21].try_into().unwrap(),
                );
                let feature: Vec<f32> = idx[pos + 21..pos + add_len]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                pos += add_len;
                let off = slot * stride;
                let end = off + apm_elems * 4;
                if end > payload.len() {
                    torn += 1;
                    continue;
                }
                let apm: Vec<f32> = payload[off..end]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                if fnv1a_f32s(&apm) != sum {
                    torn += 1;
                    continue;
                }
                live.insert(id, (feature, apm));
            }
            TAG_DEL => {
                if pos + 9 > idx.len() {
                    break;
                }
                let id = u64::from_le_bytes(
                    idx[pos + 1..pos + 9].try_into().unwrap(),
                );
                live.remove(&id);
                pos += 9;
            }
            _ => break, // corrupt tag — stop trusting the stream
        }
    }
    if torn > 0 {
        log::warn!(
            "{}: dropped {torn} torn cold record(s) during recovery \
             (they resolve as clean misses)",
            idx_path.display()
        );
    }
    let mut out: Vec<(u64, Vec<f32>, Vec<f32>)> = live
        .into_iter()
        .map(|(id, (f, a))| (id, f, a))
        .collect();
    if out.len() > capacity {
        out.drain(..out.len() - capacity); // keep the newest
    }
    Ok(out)
}

/// The file-backed cold tier under a hot `MemoTier`: one shard per
/// layer, each a payload arena on disk plus DRAM-resident features.
/// See the module docs for the demotion/promotion protocol and the
/// recovery story.
pub struct ColdTier {
    shards: Vec<ColdShard>,
    capacity: usize,
    embed_dim: usize,
    apm_elems: usize,
    evictions: AtomicU64,
}

impl ColdTier {
    /// Open (or create) a cold tier rooted at `dir` with one shard per
    /// layer and a per-layer budget of `capacity` entries. Existing
    /// shard files are replayed (see the module docs): live entries
    /// survive a restart, torn ones resolve as misses, and both files
    /// are rewritten compacted.
    pub fn open(dir: &Path, layers: usize, embed_dim: usize,
                apm_elems: usize, capacity: usize) -> Result<ColdTier> {
        if capacity == 0 {
            return Err(Error::config(
                "cold tier capacity must be positive (--cold-capacity)",
            ));
        }
        if embed_dim == 0 || apm_elems == 0 {
            return Err(Error::memo("cold tier dims must be positive"));
        }
        std::fs::create_dir_all(dir)?;
        let mut shards = Vec::with_capacity(layers);
        for li in 0..layers {
            let apm_path = dir.join(format!("cold-layer{li}.apm"));
            let idx_path = dir.join(format!("cold-layer{li}.idx"));
            let survivors = recover(&apm_path, &idx_path, embed_dim,
                                    apm_elems, capacity)?;
            // Survivor payloads are in memory now; recreate both files
            // fresh (recovery doubles as compaction).
            let arena = ApmArena::new_file_backed(apm_elems, &apm_path)?;
            let mut log = std::fs::File::create(&idx_path)?;
            write_header(&mut log, embed_dim, apm_elems)?;
            let mut inner = ColdInner {
                arena,
                entries: VecDeque::new(),
                next_id: survivors.last().map_or(0, |s| s.0 + 1),
                log,
                idx_path,
                log_writes: 0,
            };
            for (id, feature, apm) in &survivors {
                inner.insert_with_id(*id, feature, apm)?;
            }
            let len = inner.entries.len();
            let resident = inner.arena.resident_bytes();
            shards.push(ColdShard {
                inner: RwLock::new(inner),
                len: AtomicUsize::new(len),
                resident: AtomicUsize::new(resident),
            });
        }
        Ok(ColdTier {
            shards,
            capacity,
            embed_dim,
            apm_elems,
            evictions: AtomicU64::new(0),
        })
    }

    /// Number of layer shards.
    pub fn num_layers(&self) -> usize {
        self.shards.len()
    }

    /// Per-layer entry budget.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Live entries in one shard (atomic gauge, no locks).
    pub fn layer_len(&self, layer: usize) -> usize {
        self.shards[layer].len.load(Ordering::Relaxed)
    }

    /// Total live entries across shards (atomic gauges, no locks).
    pub fn total_entries(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.len.load(Ordering::Relaxed))
            .sum()
    }

    /// Total bytes of the file-backed payload arenas (atomic gauges).
    pub fn resident_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.resident.load(Ordering::Relaxed))
            .sum()
    }

    /// Entries dropped off the cold end (FIFO) by the budget.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Demote one evicted hot entry into a cold shard, dropping the
    /// oldest cold entry first when the shard is at its budget (two
    /// demotions is the end of the line). Returns the entry's cold id.
    pub fn insert(&self, layer: usize, feature: &[f32],
                  apm: &[f32]) -> Result<u64> {
        if feature.len() != self.embed_dim
            || apm.len() != self.apm_elems
        {
            return Err(Error::memo(format!(
                "cold insert: want ({}, {}) values, got ({}, {})",
                self.embed_dim,
                self.apm_elems,
                feature.len(),
                apm.len()
            )));
        }
        let shard = &self.shards[layer];
        let mut inner = shard.inner.write().unwrap();
        let mut dropped = 0u64;
        while inner.entries.len() >= self.capacity {
            let e = inner.entries.pop_front().expect("len checked");
            let _ = inner.arena.remove(e.apm);
            // Best-effort DEL: if it never lands, recovery's newest-
            // first capacity truncation drops the entry anyway.
            let _ = inner.log_del(e.id);
            dropped += 1;
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner.insert_with_id(id, feature, apm)?;
        inner.maybe_rewrite_log(self.capacity, self.embed_dim)?;
        shard.len.store(inner.entries.len(), Ordering::Relaxed);
        shard
            .resident
            .store(inner.arena.resident_bytes(), Ordering::Relaxed);
        self.evictions.fetch_add(dropped, Ordering::Relaxed);
        Ok(id)
    }

    /// Nearest cold entry clearing `min_similarity`, without mutating
    /// the shard: `(cold id, similarity)`. Shares the read lock with
    /// other probes; the hot tier's lazy fetch uses this to avoid
    /// paying a batch-buffer allocation for a cold miss.
    pub fn probe(&self, layer: usize, feature: &[f32],
                 min_similarity: f32) -> Option<(u64, f32)> {
        let inner = self.shards[layer].inner.read().unwrap();
        let (i, sim) = nearest(&inner.entries, feature)?;
        if sim >= min_similarity {
            Some((inner.entries[i].id, sim))
        } else {
            None
        }
    }

    /// Take the nearest entry clearing `min_similarity` out of a cold
    /// shard (the promotion path): its payload is copied into `dst`
    /// (`apm_elems` values), its stored feature vector and the probe
    /// similarity are returned, and the entry leaves the cold tier —
    /// the caller re-admits it into the hot tier, so an entry is never
    /// live in both. The payload read is validated against the arena's
    /// tenancy-epoch stamp before *and* after the copy; a stamp failure
    /// drops the entry and reports a clean miss, never foreign bytes.
    pub fn take_nearest(&self, layer: usize, feature: &[f32],
                        min_similarity: f32,
                        dst: &mut [f32]) -> Option<ColdPromotion> {
        let shard = &self.shards[layer];
        let mut inner = shard.inner.write().unwrap();
        let (i, similarity) = nearest(&inner.entries, feature)?;
        if similarity < min_similarity {
            return None;
        }
        let e = inner.entries.remove(i).expect("index in range");
        let ok = match inner.arena.get_checked(e.apm, e.stamp) {
            Ok(apm) => {
                dst.copy_from_slice(apm);
                inner.arena.recheck(e.apm, e.stamp)
            }
            Err(_) => false,
        };
        let _ = inner.arena.remove(e.apm);
        let _ = inner.log_del(e.id);
        shard.len.store(inner.entries.len(), Ordering::Relaxed);
        shard
            .resident
            .store(inner.arena.resident_bytes(), Ordering::Relaxed);
        if !ok {
            // The epoch discipline tripped: never serve the bytes. The
            // entry is gone either way (it could not have been read
            // intact again).
            dst.fill(0.0);
            return None;
        }
        Some(ColdPromotion {
            feature: e.feature,
            similarity,
        })
    }

    /// Copies of one shard's live entries — `(cold id, stored feature,
    /// payload)` in FIFO (ascending-id) order. Diagnostics and tests;
    /// takes the read lock and copies everything.
    pub fn entries(&self,
                   layer: usize) -> Vec<(u64, Vec<f32>, Vec<f32>)> {
        let inner = self.shards[layer].inner.read().unwrap();
        inner
            .entries
            .iter()
            .filter_map(|e| {
                inner.arena.get(e.apm).ok().map(|apm| {
                    (e.id, e.feature.clone(), apm.to_vec())
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DIM: usize = 4;
    const ELEMS: usize = 8;

    fn dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn basis(k: usize) -> [f32; DIM] {
        let mut f = [0.0f32; DIM];
        f[k % DIM] = 1.0;
        f
    }

    #[test]
    fn insert_probe_take_roundtrip() {
        let d = dir("attmemo_cold_roundtrip");
        let cold = ColdTier::open(&d, 1, DIM, ELEMS, 8).unwrap();
        let f = basis(0);
        cold.insert(0, &f, &[7.0; ELEMS]).unwrap();
        assert_eq!(cold.layer_len(0), 1);
        assert_eq!(cold.total_entries(), 1);
        assert!(cold.resident_bytes() > 0);
        let (_, sim) = cold.probe(0, &f, 0.9).unwrap();
        assert!(sim > 0.999);
        assert!(cold.probe(0, &basis(1), 0.9).is_none(),
                "an orthogonal probe must not clear the floor");
        let mut dst = [0.0f32; ELEMS];
        let promo = cold.take_nearest(0, &f, 0.9, &mut dst).unwrap();
        assert_eq!(promo.feature, f);
        assert!(promo.similarity > 0.999);
        assert_eq!(dst, [7.0; ELEMS]);
        assert_eq!(cold.layer_len(0), 0,
                   "promotion takes the entry out of the cold tier");
        assert!(cold.take_nearest(0, &f, 0.9, &mut dst).is_none());
        assert!(cold.insert(0, &f, &[0.0; 3]).is_err(),
                "wrong payload size rejected");
    }

    #[test]
    fn fifo_eviction_bounds_occupancy() {
        let d = dir("attmemo_cold_fifo");
        let cold = ColdTier::open(&d, 1, DIM, ELEMS, 3).unwrap();
        for k in 0..5 {
            let mut f = [0.0f32; DIM];
            f[0] = k as f32;
            cold.insert(0, &f, &[k as f32; ELEMS]).unwrap();
        }
        assert_eq!(cold.layer_len(0), 3, "budget enforced");
        assert_eq!(cold.evictions(), 2, "oldest entries dropped");
        let ids: Vec<u64> =
            cold.entries(0).iter().map(|e| e.0).collect();
        assert_eq!(ids, [2, 3, 4], "FIFO keeps the newest");
    }

    #[test]
    fn reopen_recovers_entries_and_next_id() {
        let d = dir("attmemo_cold_reopen");
        {
            let cold = ColdTier::open(&d, 2, DIM, ELEMS, 8).unwrap();
            for k in 0..3 {
                cold.insert(0, &basis(k), &[k as f32; ELEMS])
                    .unwrap();
            }
            cold.insert(1, &basis(0), &[9.0; ELEMS]).unwrap();
            // Promote one out so a DEL record is replayed too.
            let mut dst = [0.0f32; ELEMS];
            cold.take_nearest(0, &basis(1), 0.9, &mut dst).unwrap();
        }
        let cold = ColdTier::open(&d, 2, DIM, ELEMS, 8).unwrap();
        assert_eq!(cold.layer_len(0), 2);
        assert_eq!(cold.layer_len(1), 1);
        let e = cold.entries(0);
        assert_eq!((e[0].0, e[0].2[0]), (0, 0.0));
        assert_eq!((e[1].0, e[1].2[0]), (2, 2.0));
        assert_eq!(e[1].1, basis(2), "features survive the restart");
        // New ids continue after the recovered ones.
        let id =
            cold.insert(0, &basis(3), &[5.0; ELEMS]).unwrap();
        assert_eq!(id, 3);
    }

    #[test]
    fn unsupported_version_and_dims_are_rejected() {
        let d = dir("attmemo_cold_version");
        {
            let cold = ColdTier::open(&d, 1, DIM, ELEMS, 4).unwrap();
            cold.insert(0, &basis(0), &[1.0; ELEMS]).unwrap();
        }
        let idx = d.join("cold-layer0.idx");
        let mut bytes = std::fs::read(&idx).unwrap();
        bytes[4..8].copy_from_slice(&(COLD_VERSION + 1).to_le_bytes());
        std::fs::write(&idx, &bytes).unwrap();
        let err = ColdTier::open(&d, 1, DIM, ELEMS, 4).unwrap_err();
        assert!(format!("{err}").contains("unsupported"), "{err}");
        bytes[4..8].copy_from_slice(&COLD_VERSION.to_le_bytes());
        std::fs::write(&idx, &bytes).unwrap();
        assert!(ColdTier::open(&d, 1, DIM + 1, ELEMS, 4).is_err(),
                "dimension mismatch must be rejected");
        assert_eq!(
            ColdTier::open(&d, 1, DIM, ELEMS, 4)
                .unwrap()
                .layer_len(0),
            1
        );
        assert!(ColdTier::open(&d, 1, DIM, ELEMS, 0).is_err(),
                "zero capacity is a configuration error");
    }

    /// Heavy churn must not grow the append-only index log without
    /// bound: the in-process rewrite compacts it to the live set.
    #[test]
    fn log_compaction_preserves_live_entries() {
        let d = dir("attmemo_cold_logcompact");
        let cap = 2usize;
        let cold = ColdTier::open(&d, 1, DIM, ELEMS, cap).unwrap();
        for k in 0..200 {
            let mut f = [0.0f32; DIM];
            f[0] = k as f32;
            cold.insert(0, &f, &[k as f32; ELEMS]).unwrap();
        }
        assert_eq!(cold.layer_len(0), cap);
        let idx_len = std::fs::metadata(d.join("cold-layer0.idx"))
            .unwrap()
            .len();
        assert!(idx_len < 4096,
                "log must compact under churn: {idx_len} bytes");
        let e = cold.entries(0);
        assert_eq!(e.len(), cap);
        assert_eq!((e[1].0, e[1].2[0]), (199, 199.0));
    }
}
