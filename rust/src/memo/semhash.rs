//! Semantic request signatures: SimHash over mean-pooled embedding rows.
//!
//! The paper's central observation is that *semantically* similar inputs
//! produce similar attention computation, found through an embedding of
//! the input — and AttnCache applies the same feature-space-lookup idea at
//! LLM-prefill scale. The serving router wants that property at *enqueue*
//! time, before any model forward exists: two paraphrases of one prompt
//! should land in the same affinity bucket so they meet in one batch.
//!
//! [`SemanticSketcher`] delivers a request-time approximation with no
//! graph execution:
//!
//! 1. **Mean-pool** the model's token-embedding-table rows for the first
//!    `prefix_len` non-pad tokens — a bag-of-words point in the model's
//!    own embedding space (`model/forward.rs::embed`'s `tok_emb` table,
//!    read host-side; the pooling is order-invariant by construction).
//! 2. **Project** through a fixed, seeded random matrix onto
//!    [`SIG_BITS`] Gaussian hyperplanes.
//! 3. **Sign-quantize** into a [`SIG_BITS`]-bit SimHash: requests whose
//!    pooled embeddings are close in cosine agree on most bits (classic
//!    SimHash LSH), so near-paraphrases share the low bits the router
//!    buckets by, while unrelated prompts differ in ~half the bits.
//!
//! Because pooling and projection commute, the sketcher precomputes each
//! token's projected row once (`vocab × SIG_BITS` floats); a request
//! sketch is then `O(prefix_len × SIG_BITS)` additions — comparable to
//! the min-hash it replaces.
//!
//! ```
//! use attmemo::memo::semhash::SemanticSketcher;
//!
//! // A tiny synthetic embedding table: 8 tokens × 4 dims.
//! let table: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
//! let sk = SemanticSketcher::new(&table, 8, 4, 16).unwrap();
//! // Word order does not change the bag, hence not the sketch.
//! assert_eq!(sk.sketch(&[3, 5, 1, 6, 0, 0]), sk.sketch(&[6, 1, 5, 3, 0]));
//! ```

use crate::tensor::Tensor;
use crate::util::Pcg32;
use crate::{Error, Result};

/// Bits in a semantic signature (one random hyperplane per bit).
pub const SIG_BITS: usize = 64;

/// Fixed projection seed: every process sketches identically, so replicas
/// (and a restarted server) agree on bucket assignments.
const PROJECTION_SEED: u64 = 0x5e3a_11c0_a77e_1105;

/// Request-time semantic sketcher over a token-embedding table.
pub struct SemanticSketcher {
    /// Per-token projected rows, `vocab × SIG_BITS`.
    proj: Vec<f32>,
    vocab: usize,
    prefix_len: usize,
}

impl SemanticSketcher {
    /// Build a sketcher from a flat `[vocab, dim]` embedding table.
    ///
    /// Construction projects every vocabulary row once
    /// (`O(vocab × dim × SIG_BITS)` — a startup cost, amortized over all
    /// requests); sketching is `O(prefix_len × SIG_BITS)` per request.
    pub fn new(table: &[f32], vocab: usize, dim: usize,
               prefix_len: usize) -> Result<Self> {
        if vocab == 0 || dim == 0 || table.len() != vocab * dim {
            return Err(Error::shape(format!(
                "embedding table is {} floats, want vocab {vocab} × dim \
                 {dim}",
                table.len()
            )));
        }
        // SIG_BITS Gaussian hyperplanes over the embedding space, from a
        // fixed seed (see PROJECTION_SEED).
        let mut rng = Pcg32::seeded(PROJECTION_SEED);
        let planes: Vec<f32> =
            (0..SIG_BITS * dim).map(|_| rng.next_gaussian()).collect();
        let mut proj = vec![0.0f32; vocab * SIG_BITS];
        for (t, prow) in proj.chunks_mut(SIG_BITS).enumerate() {
            let row = &table[t * dim..(t + 1) * dim];
            for (b, p) in prow.iter_mut().enumerate() {
                let plane = &planes[b * dim..(b + 1) * dim];
                *p = crate::kernels::simd::dot(row, plane);
            }
        }
        Ok(SemanticSketcher { proj, vocab, prefix_len: prefix_len.max(1) })
    }

    /// Build from the model's `[vocab, hidden]` embedding-table tensor
    /// (`ModelRunner::embedding_table`).
    pub fn from_embedding(table: &Tensor, prefix_len: usize) -> Result<Self> {
        if table.shape().len() != 2 {
            return Err(Error::shape(format!(
                "embedding table must be [vocab, dim], got {:?}",
                table.shape()
            )));
        }
        Self::new(table.data(), table.shape()[0], table.shape()[1],
                  prefix_len)
    }

    /// Vocabulary size the sketcher was built for.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Non-pad prefix tokens pooled into one sketch.
    pub fn prefix_len(&self) -> usize {
        self.prefix_len
    }

    /// SimHash of the request's token ids.
    ///
    /// Pads and out-of-vocabulary ids are skipped. The accumulation runs
    /// in canonical (sorted-token) order: float addition is not
    /// associative, so summing in arrival order would let two
    /// permutations of the same bag disagree in near-zero bits — sorting
    /// makes the sketch permutation-invariant bit-exactly (whenever the
    /// non-pad prefix fits within `prefix_len`). An all-pad request
    /// sketches to 0.
    pub fn sketch(&self, ids: &[i32]) -> u64 {
        let mut toks: Vec<usize> = Vec::with_capacity(self.prefix_len);
        for &t in ids {
            if t == crate::data::tokenizer::PAD {
                continue;
            }
            let Ok(ti) = usize::try_from(t) else { continue };
            if ti >= self.vocab {
                continue;
            }
            toks.push(ti);
            if toks.len() >= self.prefix_len {
                break;
            }
        }
        if toks.is_empty() {
            return 0;
        }
        toks.sort_unstable();
        let mut acc = [0.0f32; SIG_BITS];
        for &ti in &toks {
            let row = &self.proj[ti * SIG_BITS..(ti + 1) * SIG_BITS];
            crate::kernels::simd::axpy(1.0, row, &mut acc);
        }
        let mut sig = 0u64;
        for (b, &a) in acc.iter().enumerate() {
            if a > 0.0 {
                sig |= 1u64 << b;
            }
        }
        sig
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic synthetic embedding table.
    fn table(vocab: usize, dim: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..vocab * dim).map(|_| rng.next_gaussian()).collect()
    }

    #[test]
    fn rejects_bad_dimensions() {
        assert!(SemanticSketcher::new(&[0.0; 10], 3, 4, 8).is_err());
        assert!(SemanticSketcher::new(&[], 0, 4, 8).is_err());
        let t = Tensor::zeros(&[2, 3, 4]);
        assert!(SemanticSketcher::from_embedding(&t, 8).is_err());
    }

    #[test]
    fn sketch_is_deterministic_across_constructions() {
        let tab = table(64, 16, 3);
        let a = SemanticSketcher::new(&tab, 64, 16, 32).unwrap();
        let b = SemanticSketcher::new(&tab, 64, 16, 32).unwrap();
        let ids: Vec<i32> = (4..24).collect();
        assert_eq!(a.sketch(&ids), b.sketch(&ids));
    }

    #[test]
    fn sketch_ignores_pads_and_out_of_vocab() {
        let sk = SemanticSketcher::new(&table(32, 8, 5), 32, 8, 16).unwrap();
        let base = [4, 9, 17, 23];
        let padded = [4, 0, 9, 17, 0, 23, 0, 0];
        let noisy = [4, 9, 300, -7, 17, 23];
        assert_eq!(sk.sketch(&base), sk.sketch(&padded));
        assert_eq!(sk.sketch(&base), sk.sketch(&noisy));
        assert_eq!(sk.sketch(&[0, 0, 0]), 0, "all-pad sketches to 0");
    }

    #[test]
    fn sketch_is_permutation_invariant() {
        let sk =
            SemanticSketcher::new(&table(128, 16, 7), 128, 16, 32).unwrap();
        let mut rng = Pcg32::seeded(11);
        for k in 0..8u64 {
            let base: Vec<i32> =
                (0..20).map(|j| 4 + (k as i32) * 15 + j).collect();
            let mut shuffled = base.clone();
            rng.shuffle(&mut shuffled);
            assert_eq!(sk.sketch(&base), sk.sketch(&shuffled),
                       "permutation {k} changed the bag-of-words sketch");
        }
    }

    #[test]
    fn near_paraphrases_stay_close_unrelated_diverge() {
        let sk =
            SemanticSketcher::new(&table(256, 16, 9), 256, 16, 32).unwrap();
        let a: Vec<i32> = (10..30).collect();
        // One substituted word: most hyperplane signs survive.
        let mut b = a.clone();
        b[10] = 200;
        let near = (sk.sketch(&a) ^ sk.sketch(&b)).count_ones();
        assert!(near <= 24, "one-word edit flipped {near}/64 bits");
        // A disjoint token set lands ~half the bits away.
        let c: Vec<i32> = (100..120).collect();
        let far = (sk.sketch(&a) ^ sk.sketch(&c)).count_ones();
        assert!(far > 8, "unrelated bags differ in only {far}/64 bits");
    }
}
