//! Hidden-state embedding on the request path (paper §5.2).
//!
//! Thin wrapper around the family's `mlp_embed` executable plus feature
//! bookkeeping: splits batched features into per-sequence vectors and
//! exposes the similarity estimate used against the memoization threshold.

use crate::model::ModelRunner;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Batched embedding results, one feature vector per sequence.
pub struct Features {
    dim: usize,
    data: Vec<f32>,
}

impl Features {
    /// Wrap a `[n, d]` feature tensor.
    pub fn from_tensor(t: &Tensor) -> Result<Features> {
        if t.shape().len() != 2 {
            return Err(Error::shape(format!(
                "features must be [n, d], got {:?}",
                t.shape()
            )));
        }
        Ok(Features { dim: t.shape()[1], data: t.data().to_vec() })
    }

    /// Number of per-sequence feature vectors.
    pub fn len(&self) -> usize {
        if self.dim == 0 { 0 } else { self.data.len() / self.dim }
    }

    /// Whether there are no vectors at all.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Feature vector of sequence `i`.
    pub fn vector(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// The flat `[n × d]` data.
    pub fn raw(&self) -> &[f32] {
        &self.data
    }
}

/// Runs the embedding network for a hidden-state batch.
pub struct Embedder<'a> {
    runner: &'a ModelRunner,
}

impl<'a> Embedder<'a> {
    /// Embedder over a loaded model runner.
    pub fn new(runner: &'a ModelRunner) -> Self {
        Embedder { runner }
    }

    /// Embed `[n, L, H]` hidden states → `n` L2-normalised features.
    pub fn embed(&self, hidden: &Tensor) -> Result<Features> {
        let t = self.runner.mlp_embed(hidden)?;
        Features::from_tensor(&t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn features_split() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let f = Features::from_tensor(&t).unwrap();
        assert_eq!(f.len(), 2);
        assert_eq!(f.vector(1), &[4., 5., 6.]);
    }

    #[test]
    fn rejects_bad_rank() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert!(Features::from_tensor(&t).is_err());
    }
}
