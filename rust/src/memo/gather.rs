//! APM batch gathering: memory-copy baseline vs the paper's memory-mapping
//! technique (§5.3, Fig. 9, Table 6).
//!
//! * **Copy gather** slices each APM out of the arena and memcpy-s it into a
//!   fresh contiguous buffer — what an unmodified ML framework forces.
//! * **Mapped gather** reserves one contiguous virtual range and maps each
//!   APM's *pages* into consecutive slots with `mmap(MAP_FIXED)` over the
//!   arena's memfd. No data moves; the OS just writes PTEs. The virtual
//!   range is reserved once and remapped batch after batch, mirroring the
//!   paper's observation that PTEs are reused across layers.

use crate::memo::arena::{page_align, ApmArena, ApmId};
use crate::{Error, Result};

/// A reusable contiguous virtual window for mapped gathers.
///
/// `map_batch` binds `ids.len()` arena entries into the window and returns a
/// view; the window keeps its reservation between batches (PTE reuse), so
/// steady-state gathers cost only the remap syscalls.
pub struct GatherWindow {
    base: *mut u8,
    capacity_bytes: usize,
    slot_bytes: usize,
    mapped_slots: usize,
}

unsafe impl Send for GatherWindow {}

impl GatherWindow {
    /// Reserve a window for up to `max_batch` entries of `entry_elems` f32.
    pub fn new(entry_elems: usize, max_batch: usize) -> Result<Self> {
        let slot_bytes = page_align(entry_elems * 4);
        let capacity_bytes = slot_bytes * max_batch.max(1);
        let base = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                capacity_bytes,
                libc::PROT_NONE,
                libc::MAP_PRIVATE | libc::MAP_ANONYMOUS,
                -1,
                0,
            )
        };
        if base == libc::MAP_FAILED {
            return Err(Error::Io(std::io::Error::last_os_error()));
        }
        Ok(GatherWindow {
            base: base.cast(),
            capacity_bytes,
            slot_bytes,
            mapped_slots: 0,
        })
    }

    /// Map a batch of APMs into the window; returns a contiguous f32 view
    /// of `ids.len() * entry_elems` values (valid until the next map/drop).
    ///
    /// Requires a dense-mappable arena (payload exactly fills its pages);
    /// otherwise the gathered view would contain page padding.
    pub fn map_batch<'a>(&'a mut self, arena: &ApmArena,
                         ids: &[ApmId]) -> Result<&'a [f32]> {
        if !arena.dense_mappable() {
            return Err(Error::memo(
                "arena entries are not page-dense; use copy gather",
            ));
        }
        if arena.stride() != self.slot_bytes {
            return Err(Error::memo(format!(
                "window slot {} != arena stride {}",
                self.slot_bytes,
                arena.stride()
            )));
        }
        let need = ids.len() * self.slot_bytes;
        if need > self.capacity_bytes {
            return Err(Error::memo(format!(
                "gather window too small: need {need}, have {}",
                self.capacity_bytes
            )));
        }
        for (slot, id) in ids.iter().enumerate() {
            let file_off = arena.file_offset(*id)?;
            let addr = unsafe { self.base.add(slot * self.slot_bytes) };
            let mapped = unsafe {
                libc::mmap(
                    addr.cast(),
                    self.slot_bytes,
                    libc::PROT_READ,
                    libc::MAP_SHARED | libc::MAP_FIXED,
                    arena.fd(),
                    file_off as libc::off_t,
                )
            };
            if mapped == libc::MAP_FAILED {
                return Err(Error::Io(std::io::Error::last_os_error()));
            }
        }
        self.mapped_slots = self.mapped_slots.max(ids.len());
        let elems = ids.len() * self.slot_bytes / 4;
        Ok(unsafe { std::slice::from_raw_parts(self.base.cast::<f32>(), elems) })
    }

    /// Drop the page bindings (PROT_NONE anonymous again) but keep the
    /// reservation. Not required between batches — `map_batch` overwrites —
    /// but used by tests and by the engine when a batch's APMs must not
    /// outlive their request.
    pub fn unmap(&mut self) -> Result<()> {
        if self.mapped_slots == 0 {
            return Ok(());
        }
        let bytes = self.mapped_slots * self.slot_bytes;
        let r = unsafe {
            libc::mmap(
                self.base.cast(),
                bytes,
                libc::PROT_NONE,
                libc::MAP_PRIVATE | libc::MAP_ANONYMOUS | libc::MAP_FIXED,
                -1,
                0,
            )
        };
        if r == libc::MAP_FAILED {
            return Err(Error::Io(std::io::Error::last_os_error()));
        }
        self.mapped_slots = 0;
        Ok(())
    }
}

impl Drop for GatherWindow {
    fn drop(&mut self) {
        unsafe { libc::munmap(self.base.cast(), self.capacity_bytes) };
    }
}

/// Copy-based gather baseline: memcpy each APM into a fresh buffer.
pub fn copy_gather(arena: &ApmArena, ids: &[ApmId]) -> Result<Vec<f32>> {
    let elems = arena.entry_elems();
    let mut out = Vec::with_capacity(elems * ids.len());
    for id in ids {
        out.extend_from_slice(arena.get(*id)?);
    }
    Ok(out)
}

/// Eq. 1-score every gathered APM against a probe.
///
/// `batch` is a contiguous gather view — either [`copy_gather`]'s
/// buffer or [`GatherWindow::map_batch`]'s mapped window — holding one
/// `[rows, cols]` APM per `entry_elems` stride (`rows·cols ≤
/// entry_elems`; mapped windows may carry page padding past the
/// payload). The per-row total-variation loop runs through the
/// dispatched kernel layer ([`crate::kernels::simd`]), so the gather →
/// rescore pipeline inherits the AVX2/scalar A/B switch.
pub fn score_gathered(batch: &[f32], entry_elems: usize, probe: &[f32],
                      rows: usize, cols: usize) -> Vec<f32> {
    debug_assert!(rows * cols <= entry_elems);
    batch
        .chunks(entry_elems)
        .map(|e| {
            crate::tensor::ops::similarity_score(
                &e[..rows * cols],
                probe,
                rows,
                cols,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memo::arena::page_size;

    fn arena_with(n: usize, elems: usize) -> (ApmArena, Vec<ApmId>) {
        let mut a = ApmArena::new(elems).unwrap();
        let ids = (0..n)
            .map(|i| {
                let v: Vec<f32> =
                    (0..elems).map(|j| (i * 1000 + j) as f32).collect();
                a.push(&v).unwrap()
            })
            .collect();
        (a, ids)
    }

    #[test]
    fn mapped_equals_copy() {
        let elems = page_size() / 4; // one page per entry → dense
        let (arena, ids) = arena_with(8, elems);
        let picks = [ids[5], ids[0], ids[7], ids[2]];
        let copied = copy_gather(&arena, &picks).unwrap();
        let mut win = GatherWindow::new(elems, 4).unwrap();
        let mapped = win.map_batch(&arena, &picks).unwrap();
        assert_eq!(mapped, &copied[..]);
    }

    #[test]
    fn window_reuse_across_batches() {
        let elems = page_size() / 4;
        let (arena, ids) = arena_with(6, elems);
        let mut win = GatherWindow::new(elems, 3).unwrap();
        let first: Vec<f32> =
            win.map_batch(&arena, &[ids[0], ids[1], ids[2]]).unwrap().to_vec();
        let second = win.map_batch(&arena, &[ids[3], ids[4], ids[5]]).unwrap();
        assert_ne!(&first[..], second);
        assert_eq!(second[0], 3000.0);
    }

    #[test]
    fn oversized_batch_rejected() {
        let elems = page_size() / 4;
        let (arena, ids) = arena_with(4, elems);
        let mut win = GatherWindow::new(elems, 2).unwrap();
        assert!(win.map_batch(&arena, &ids).is_err());
    }

    #[test]
    fn non_dense_arena_rejected_for_mapping() {
        let mut a = ApmArena::new(10).unwrap(); // 40 bytes ≪ page
        let id = a.push(&[0.5; 10]).unwrap();
        let mut win = GatherWindow::new(a.stride() / 4, 1).unwrap();
        assert!(win.map_batch(&a, &[id]).is_err());
        // copy gather still works
        assert_eq!(copy_gather(&a, &[id]).unwrap(), vec![0.5; 10]);
    }

    #[test]
    fn score_gathered_identity_and_padding() {
        use crate::tensor::ops::softmax_rows;
        let (rows, cols) = (4, 8);
        let elems = rows * cols + 5; // trailing padding lanes
        let mut probe: Vec<f32> = (0..rows * cols)
            .map(|i| (i % 7) as f32 * 0.3)
            .collect();
        softmax_rows(&mut probe, rows, cols);
        let mut batch = vec![0.0f32; 2 * elems];
        batch[..rows * cols].copy_from_slice(&probe);
        // Second entry: a different stochastic matrix.
        let mut other: Vec<f32> = (0..rows * cols)
            .map(|i| (i % 3) as f32)
            .collect();
        softmax_rows(&mut other, rows, cols);
        batch[elems..elems + rows * cols].copy_from_slice(&other);
        let scores = score_gathered(&batch, elems, &probe, rows, cols);
        assert_eq!(scores.len(), 2);
        assert!((scores[0] - 1.0).abs() < 1e-5);
        assert!(scores[1] < scores[0]);
    }

    #[test]
    fn unmap_then_remap() {
        let elems = page_size() / 4;
        let (arena, ids) = arena_with(2, elems);
        let mut win = GatherWindow::new(elems, 2).unwrap();
        win.map_batch(&arena, &[ids[0]]).unwrap();
        win.unmap().unwrap();
        let v = win.map_batch(&arena, &[ids[1]]).unwrap();
        assert_eq!(v[0], 1000.0);
    }
}
