//! The AttMemo memoization engine — the paper's contribution.
//!
//! * [`arena`] / [`attdb`] — the attention database (pre-computed APMs in
//!   page-aligned big memory, per layer).
//! * [`tier`] — the shared online tier: seqlock-published copy-on-write
//!   shards, one per layer — admissions publish new snapshots while
//!   readers serve lock-free across engine replicas.
//! * [`cold`] — the file-backed cold spill tier under the hot shards:
//!   clock victims demote into it, hot misses fall through to it, and
//!   cold hits promote back through the normal admission path.
//! * [`gather`] — copy vs memory-mapped APM batch gathering (§5.3).
//! * [`index`] — the index database: HNSW over hidden-state embeddings.
//! * [`embedder`] — runs the MLP embedding executable (§5.2).
//! * [`semhash`] — request-time SimHash over mean-pooled embedding-table
//!   rows (the serving router's semantic affinity signature).
//! * [`thresholds`] — conservative/moderate/aggressive levels (Table 2).
//! * [`policy`] — selective memoization performance model (Eq. 3, §5.4).
//! * [`builder`] — offline DB population from the training set.
//! * [`persist`] — offline database + warm-state snapshot files.
//! * [`stats`] — reuse counters and hit-rate accounting (Fig. 11).

#![warn(missing_docs)]

pub mod arena;
pub mod attdb;
pub mod builder;
pub mod cold;
pub mod embedder;
pub mod gather;
pub mod index;
pub mod persist;
pub mod policy;
pub mod semhash;
pub mod stats;
pub mod thresholds;
pub mod tier;

pub use arena::{ApmArena, ApmId};
pub use attdb::{AdmitOutcome, AttentionDb};
pub use builder::DbBuilder;
pub use cold::{ColdPromotion, ColdTier};
pub use policy::{AdmissionPolicy, LayerProfile, SelectivePolicy};
pub use semhash::SemanticSketcher;
pub use stats::MemoStats;
pub use tier::{MemoTier, ShardReader, TierAdmitOutcome};
