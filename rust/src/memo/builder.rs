//! Offline attention-database population (paper §5.1 "pre-populated during
//! training") + threshold calibration + the Eq. 3 layer profiles.
//!
//! The builder replays the training set through the split forward path,
//! inserting every layer's (embedded hidden state → APM) pair. From the
//! second chunk on, it first *queries* the partial database, recording the
//! estimated similarity of each lookup — those samples calibrate the
//! conservative/moderate/aggressive thresholds and the per-layer hit rate
//! α used by selective memoization.

use std::time::Instant;

use crate::config::ModelConfig;
use crate::memo::attdb::AttentionDb;
use crate::memo::index::HnswParams;
use crate::memo::policy::{LayerProfile, SelectivePolicy};
use crate::memo::thresholds::Thresholds;
use crate::model::ModelRunner;
use crate::tensor::tensor::IdTensor;
use crate::Result;

/// Everything the engine needs, produced by one offline build.
pub struct BuiltDb {
    /// The populated per-layer attention + index database.
    pub db: AttentionDb,
    /// Calibrated similarity thresholds (Table 2 levels).
    pub thresholds: Thresholds,
    /// Per-layer similarity samples observed while building (threshold
    /// sweeps and the Fig. 3/12 distributions reuse these).
    pub similarity_samples: Vec<Vec<f32>>,
    /// Eq. 3 inputs measured during the build.
    pub profiles: Vec<LayerProfile>,
    /// Wall-clock seconds spent inserting into the HNSW indexes.
    pub indexing_seconds: f64,
    /// Wall-clock seconds of the whole build.
    pub build_seconds: f64,
    /// Sequences ingested.
    pub sequences: usize,
}

impl BuiltDb {
    /// Selective policy with α derived from the samples at `threshold`.
    pub fn policy(&self, threshold: f32, enabled: bool) -> SelectivePolicy {
        let layers = self
            .profiles
            .iter()
            .enumerate()
            .map(|(li, p)| LayerProfile {
                alpha: alpha_at(&self.similarity_samples[li], threshold),
                ..*p
            })
            .collect();
        SelectivePolicy::new(layers, enabled)
    }
}

/// Fraction of similarity samples clearing a threshold.
pub fn alpha_at(samples: &[f32], threshold: f32) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.iter().filter(|&&s| s >= threshold).count() as f64
        / samples.len() as f64
}

/// Offline builder.
pub struct DbBuilder<'a> {
    runner: &'a ModelRunner,
    /// HNSW construction parameters for the per-layer indexes.
    pub hnsw: HnswParams,
    /// Chunk size for replaying the training set.
    pub chunk: usize,
    /// Beam width for calibration lookups.
    pub ef: usize,
}

impl<'a> DbBuilder<'a> {
    /// Builder over a loaded model runner, with default parameters.
    pub fn new(runner: &'a ModelRunner) -> Self {
        DbBuilder { runner, hnsw: HnswParams::default(), chunk: 8, ef: 48 }
    }

    /// Ingest `ids` (shape `[n, L]`), returning the populated database.
    pub fn build(&self, ids: &IdTensor) -> Result<BuiltDb> {
        let t_start = Instant::now();
        let cfg: &ModelConfig = self.runner.config();
        let seq_len = ids.shape[1];
        let mut db = AttentionDb::new(cfg, seq_len, self.hnsw);
        let n = ids.shape[0];
        let mut samples: Vec<Vec<f32>> = vec![Vec::new(); cfg.layers];
        let mut t_attn = vec![0.0f64; cfg.layers];
        let mut t_overhead = vec![0.0f64; cfg.layers];
        let mut t_apply = vec![0.0f64; cfg.layers];
        let mut t_fused = vec![0.0f64; cfg.layers];
        let mut indexing = 0.0f64;
        let mut profiled_tokens = 0u64;

        let mut start = 0;
        while start < n {
            let count = self.chunk.min(n - start);
            let chunk_ids = ids.slice0(start, count)?;
            let mut h = self.runner.embed(&chunk_ids)?;
            for li in 0..cfg.layers {
                // Overhead side of Eq. 3: embedding + search.
                let t0 = Instant::now();
                let feats = crate::memo::embedder::Embedder::new(self.runner)
                    .embed(&h)?;
                if !db.layer(li).is_empty() {
                    for i in 0..feats.len() {
                        if let Some(hit) =
                            db.layer(li).lookup(feats.vector(i), self.ef)
                        {
                            samples[li].push(hit.similarity);
                        }
                    }
                }
                t_overhead[li] += t0.elapsed().as_secs_f64();

                // Attention side of Eq. 3: the score computation.
                let t1 = Instant::now();
                let apm = self.runner.attn_scores(&h, li)?;
                t_attn[li] += t1.elapsed().as_secs_f64();

                let t2 = Instant::now();
                db.insert_batch(li, feats.raw(), apm.data())?;
                indexing += t2.elapsed().as_secs_f64();

                // Fused-path reference cost for the extended Eq. 3 (the
                // result is discarded; the split path drives the build).
                let t3 = Instant::now();
                let _ = self.runner.layer_full(&h, li)?;
                t_fused[li] += t3.elapsed().as_secs_f64();

                let t4 = Instant::now();
                h = self.runner.attn_apply(&h, &apm, li)?;
                t_apply[li] += t4.elapsed().as_secs_f64();
            }
            profiled_tokens += (count * seq_len) as u64;
            start += count;
        }

        let mut all: Vec<f32> = samples.iter().flatten().copied().collect();
        // Clamp pathological negative estimates (distance > 1) out of the
        // calibration pool; they can never clear a sane threshold anyway.
        all.retain(|s| s.is_finite());
        let thresholds = Thresholds::calibrate(all);

        let profiles = (0..cfg.layers)
            .map(|li| LayerProfile {
                t_attn: t_attn[li],
                t_overhead: t_overhead[li],
                t_apply: t_apply[li],
                t_fused: t_fused[li],
                alpha: alpha_at(&samples[li], thresholds.moderate),
                profiled_tokens,
            })
            .collect();

        Ok(BuiltDb {
            db,
            thresholds,
            similarity_samples: samples,
            profiles,
            indexing_seconds: indexing,
            build_seconds: t_start.elapsed().as_secs_f64(),
            sequences: n,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_at_fractions() {
        let s = vec![0.1, 0.5, 0.9];
        assert!((alpha_at(&s, 0.5) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(alpha_at(&s, 1.0), 0.0);
        assert_eq!(alpha_at(&[], 0.5), 0.0);
    }
}
