//! Attention-database persistence.
//!
//! The paper's database is pre-populated once during training and then
//! served from big memory; rebuilding it per process (replaying the
//! training set through the model) is the expensive part. This module
//! saves a `BuiltDb` to one binary file and restores it without touching
//! the model: features + APM payloads per layer, the calibrated
//! thresholds, the Eq. 3 profiles, and the similarity samples. The HNSW
//! index is rebuilt deterministically from the stored features (same
//! seed ⇒ same graph), which keeps the format independent of the index's
//! in-memory layout.
//!
//! Format (little-endian): magic `ATDB`, u32 version, header numbers,
//! then per layer: entry count, features `[n, dim]` f32, APMs
//! `[n, elems]` f32, similarity samples, profile, reuse counters.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::config::ModelConfig;
use crate::memo::attdb::AttentionDb;
use crate::memo::builder::BuiltDb;
use crate::memo::index::HnswParams;
use crate::memo::policy::LayerProfile;
use crate::memo::thresholds::Thresholds;
use crate::memo::ApmId;
use crate::{Error, Result};

const MAGIC: &[u8; 4] = b"ATDB";
const VERSION: u32 = 2;

fn w_u32(w: &mut impl Write, x: u32) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn w_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn w_f64(w: &mut impl Write, x: f64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn w_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    w_u64(w, xs.len() as u64)?;
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn r_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn r_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn r_f64(r: &mut impl Read) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

fn r_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let n = r_u64(r)? as usize;
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Save a built database to `path`.
pub fn save(built: &BuiltDb, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w_u32(&mut w, VERSION)?;
    w_u32(&mut w, built.db.num_layers() as u32)?;
    w_u32(&mut w, built.db.seq_len as u32)?;
    w_u32(&mut w, built.db.apm_elems() as u32)?;
    w_u32(&mut w, built.db.embed_dim() as u32)?;
    w_u64(&mut w, built.sequences as u64)?;
    w_f64(&mut w, built.indexing_seconds)?;
    w_f64(&mut w, built.build_seconds)?;
    for t in [built.thresholds.conservative, built.thresholds.moderate,
              built.thresholds.aggressive] {
        w.write_all(&t.to_le_bytes())?;
    }
    for li in 0..built.db.num_layers() {
        let layer = built.db.layer(li);
        // Live ids only: a database warmed at serve time has holes where
        // entries were evicted; persisting compacts them away (ids are
        // reassigned densely on load, which is fine — the index is rebuilt
        // from the stored features anyway).
        let ids = layer.live_ids();
        w_u64(&mut w, ids.len() as u64)?;
        for &id in &ids {
            let f = layer.index_vector(id);
            w.write_all(
                unsafe {
                    std::slice::from_raw_parts(
                        f.as_ptr().cast::<u8>(),
                        f.len() * 4,
                    )
                },
            )?;
        }
        for &id in &ids {
            let apm = layer.arena().get(id)?;
            w.write_all(
                unsafe {
                    std::slice::from_raw_parts(
                        apm.as_ptr().cast::<u8>(),
                        apm.len() * 4,
                    )
                },
            )?;
        }
        w_f32s(&mut w, &built.similarity_samples[li])?;
        let p = &built.profiles[li];
        for x in [p.t_attn, p.t_overhead, p.t_apply, p.t_fused, p.alpha] {
            w_f64(&mut w, x)?;
        }
        w_u64(&mut w, p.profiled_tokens)?;
    }
    Ok(())
}

/// Load a database saved by [`save`]. `cfg` must match the family the DB
/// was built with (validated against the stored dimensions).
pub fn load(path: &Path, cfg: &ModelConfig,
            hnsw: HnswParams) -> Result<BuiltDb> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::memo(format!("{}: not an ATDB file",
                                       path.display())));
    }
    let version = r_u32(&mut r)?;
    if version != VERSION {
        return Err(Error::memo(format!("ATDB version {version} != {VERSION}")));
    }
    let layers = r_u32(&mut r)? as usize;
    let seq_len = r_u32(&mut r)? as usize;
    let apm_elems = r_u32(&mut r)? as usize;
    let embed_dim = r_u32(&mut r)? as usize;
    if layers != cfg.layers || apm_elems != cfg.apm_elems(seq_len)
        || embed_dim != cfg.embed_dim
    {
        return Err(Error::memo(format!(
            "ATDB dims (layers {layers}, elems {apm_elems}, dim {embed_dim}) \
             do not match family {:?}",
            cfg.family
        )));
    }
    let sequences = r_u64(&mut r)? as usize;
    let indexing_seconds = r_f64(&mut r)?;
    let build_seconds = r_f64(&mut r)?;
    let mut thr = [0f32; 3];
    for t in thr.iter_mut() {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        *t = f32::from_le_bytes(b);
    }
    let thresholds = Thresholds {
        conservative: thr[0],
        moderate: thr[1],
        aggressive: thr[2],
    };

    let mut db = AttentionDb::new(cfg, seq_len, hnsw);
    let mut similarity_samples = Vec::with_capacity(layers);
    let mut profiles = Vec::with_capacity(layers);
    for li in 0..layers {
        let n = r_u64(&mut r)? as usize;
        let mut feat_bytes = vec![0u8; n * embed_dim * 4];
        r.read_exact(&mut feat_bytes)?;
        let feats: Vec<f32> = feat_bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let mut apm_bytes = vec![0u8; n * apm_elems * 4];
        r.read_exact(&mut apm_bytes)?;
        let apms: Vec<f32> = apm_bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        db.insert_batch(li, &feats, &apms)?;
        similarity_samples.push(r_f32s(&mut r)?);
        let vals: Vec<f64> =
            (0..5).map(|_| r_f64(&mut r)).collect::<Result<_>>()?;
        profiles.push(LayerProfile {
            t_attn: vals[0],
            t_overhead: vals[1],
            t_apply: vals[2],
            t_fused: vals[3],
            alpha: vals[4],
            profiled_tokens: r_u64(&mut r)?,
        });
    }
    Ok(BuiltDb {
        db,
        thresholds,
        similarity_samples,
        profiles,
        indexing_seconds,
        build_seconds,
        sequences,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn cfg() -> ModelConfig {
        ModelConfig {
            family: "bert".into(),
            vocab_size: 64,
            hidden: 16,
            layers: 2,
            heads: 2,
            ffn: 32,
            max_len: 8,
            num_classes: 2,
            rel_pos_buckets: 4,
            embed_dim: 8,
            embed_hidden: 16,
            embed_segments: 4,
            causal: false,
        }
    }

    fn demo_built() -> BuiltDb {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 8, HnswParams::default());
        let mut rng = Pcg32::seeded(5);
        for li in 0..c.layers {
            for _ in 0..6 {
                let f: Vec<f32> =
                    (0..c.embed_dim).map(|_| rng.next_gaussian()).collect();
                let apm: Vec<f32> =
                    (0..c.apm_elems(8)).map(|_| rng.next_f32()).collect();
                db.layer_mut(li).insert(&f, &apm).unwrap();
            }
        }
        BuiltDb {
            db,
            thresholds: Thresholds {
                conservative: 0.9,
                moderate: 0.8,
                aggressive: 0.7,
            },
            similarity_samples: vec![vec![0.5, 0.9], vec![0.3]],
            profiles: vec![
                LayerProfile {
                    t_attn: 1.0,
                    t_overhead: 0.1,
                    t_apply: 0.2,
                    t_fused: 1.1,
                    alpha: 0.5,
                    profiled_tokens: 64,
                };
                2
            ],
            indexing_seconds: 0.5,
            build_seconds: 2.0,
            sequences: 6,
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let built = demo_built();
        let dir = std::env::temp_dir().join("attmemo_persist");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.atdb");
        save(&built, &path).unwrap();
        let loaded = load(&path, &cfg(), HnswParams::default()).unwrap();
        assert_eq!(loaded.db.total_entries(), built.db.total_entries());
        assert_eq!(loaded.sequences, 6);
        assert_eq!(loaded.thresholds.moderate, 0.8);
        assert_eq!(loaded.similarity_samples, built.similarity_samples);
        assert_eq!(loaded.profiles[0].profiled_tokens, 64);
        // Payloads survive byte-exactly.
        for li in 0..2 {
            for id in 0..6u32 {
                assert_eq!(
                    loaded.db.layer(li).arena().get(ApmId(id)).unwrap(),
                    built.db.layer(li).arena().get(ApmId(id)).unwrap()
                );
            }
        }
        // The rebuilt index finds the same nearest entry.
        let f = built.db.layer(0).index_vector(ApmId(3)).to_vec();
        let hit = loaded.db.layer(0).lookup(&f, 32).unwrap();
        assert_eq!(hit.id, ApmId(3));
    }

    #[test]
    fn load_rejects_wrong_family_dims() {
        let built = demo_built();
        let dir = std::env::temp_dir().join("attmemo_persist2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.atdb");
        save(&built, &path).unwrap();
        let mut other = cfg();
        other.embed_dim = 16;
        assert!(load(&path, &other, HnswParams::default()).is_err());
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("attmemo_persist3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.atdb");
        std::fs::write(&path, b"not a database").unwrap();
        assert!(load(&path, &cfg(), HnswParams::default()).is_err());
    }
}
