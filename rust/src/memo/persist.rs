//! Attention-database persistence: the offline `BuiltDb` file and the
//! serve-time warm-state snapshot.
//!
//! The paper's database is pre-populated once during training and then
//! served from big memory; rebuilding it per process (replaying the
//! training set through the model) is the expensive part. This module
//! saves a `BuiltDb` to one binary file and restores it without touching
//! the model: features + APM payloads per layer, the calibrated
//! thresholds, the Eq. 3 profiles, and the similarity samples. The HNSW
//! index is rebuilt deterministically from the stored features (same
//! seed ⇒ same graph), which keeps the format independent of the index's
//! in-memory layout.
//!
//! Format (little-endian): magic `ATDB`, u32 version, header numbers,
//! then per layer: entry count, features `[n, dim]` f32, APMs
//! `[n, elems]` f32, similarity samples, profile, reuse counters.
//!
//! [`save_warm`]/[`load_warm`] do the same for the *online*
//! [`MemoTier`]: the compacted live entries of every layer shard plus
//! their reuse counters and clock bits, so a restarted process starts at
//! the pre-restart warm hit rate instead of re-paying the cold start.
//! The warm format (magic `ATWM`) is documented in `docs/PERSISTENCE.md`
//! together with its versioning/compat policy.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::config::{MemoConfig, ModelConfig};
use crate::memo::attdb::AttentionDb;
use crate::memo::builder::BuiltDb;
use crate::memo::index::HnswParams;
use crate::memo::policy::LayerProfile;
use crate::memo::thresholds::Thresholds;
use crate::memo::tier::MemoTier;
use crate::memo::ApmId;
use crate::{Error, Result};

const MAGIC: &[u8; 4] = b"ATDB";
const VERSION: u32 = 2;

const WARM_MAGIC: &[u8; 4] = b"ATWM";
/// Current warm-snapshot format version. Version 2 kept version 1's
/// layout byte-for-byte but changed the producer: `save_warm` now ages
/// out entries that saw no admission or reuse since the previous
/// snapshot (the compaction policy), so the version records which policy
/// wrote the file. Compat policy: loaders accept exactly the versions
/// they know how to parse (see [`WARM_COMPAT_VERSIONS`]) and reject
/// anything newer with a clear error — a snapshot is a cache, so
/// "rebuild by serving traffic" is always a safe fallback.
pub const WARM_VERSION: u32 = 2;

/// Warm-snapshot versions this build can load (v1 and v2 share a
/// layout; see `docs/PERSISTENCE.md`).
pub const WARM_COMPAT_VERSIONS: [u32; 2] = [1, 2];

/// Magic of the cold tier's per-layer index log (`memo/cold.rs`): the
/// append-only id→slot record stream that makes the file-backed cold
/// arena recoverable across restarts. Versioned here, alongside the
/// other on-disk formats, under the same policy: bump on any layout or
/// producer change, loaders accept exactly the versions they parse, and
/// a rejected file recovers by starting the (cache) tier cold. The
/// layout itself is documented in `docs/PERSISTENCE.md`.
pub const COLD_MAGIC: &[u8; 4] = b"ATCD";

/// Current cold index-log format version.
pub const COLD_VERSION: u32 = 1;

/// Cold index-log versions this build can replay.
pub const COLD_COMPAT_VERSIONS: [u32; 1] = [1];

fn w_u32(w: &mut impl Write, x: u32) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn w_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn w_f64(w: &mut impl Write, x: f64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn w_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    w_u64(w, xs.len() as u64)?;
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn r_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn r_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn r_f64(r: &mut impl Read) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

fn r_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let n = r_u64(r)? as usize;
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Write a raw f32 slice (no length prefix; the caller's header carries
/// the counts), explicitly little-endian so the on-disk format matches
/// its spec on any host.
fn w_f32_raw(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

fn r_f32_raw(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Save a built database to `path`.
pub fn save(built: &BuiltDb, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w_u32(&mut w, VERSION)?;
    w_u32(&mut w, built.db.num_layers() as u32)?;
    w_u32(&mut w, built.db.seq_len as u32)?;
    w_u32(&mut w, built.db.apm_elems() as u32)?;
    w_u32(&mut w, built.db.embed_dim() as u32)?;
    w_u64(&mut w, built.sequences as u64)?;
    w_f64(&mut w, built.indexing_seconds)?;
    w_f64(&mut w, built.build_seconds)?;
    for t in [built.thresholds.conservative, built.thresholds.moderate,
              built.thresholds.aggressive] {
        w.write_all(&t.to_le_bytes())?;
    }
    for li in 0..built.db.num_layers() {
        let layer = built.db.layer(li);
        // Live ids only: a database warmed at serve time has holes where
        // entries were evicted; persisting compacts them away (ids are
        // reassigned densely on load, which is fine — the index is rebuilt
        // from the stored features anyway).
        let ids = layer.live_ids();
        w_u64(&mut w, ids.len() as u64)?;
        for &id in &ids {
            let f = layer.index_vector(id);
            w.write_all(
                unsafe {
                    std::slice::from_raw_parts(
                        f.as_ptr().cast::<u8>(),
                        f.len() * 4,
                    )
                },
            )?;
        }
        for &id in &ids {
            let apm = layer.arena().get(id)?;
            w.write_all(
                unsafe {
                    std::slice::from_raw_parts(
                        apm.as_ptr().cast::<u8>(),
                        apm.len() * 4,
                    )
                },
            )?;
        }
        w_f32s(&mut w, &built.similarity_samples[li])?;
        let p = &built.profiles[li];
        for x in [p.t_attn, p.t_overhead, p.t_apply, p.t_fused, p.alpha] {
            w_f64(&mut w, x)?;
        }
        w_u64(&mut w, p.profiled_tokens)?;
    }
    Ok(())
}

/// Load a database saved by [`save`]. `cfg` must match the family the DB
/// was built with (validated against the stored dimensions).
pub fn load(path: &Path, cfg: &ModelConfig,
            hnsw: HnswParams) -> Result<BuiltDb> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::memo(format!("{}: not an ATDB file",
                                       path.display())));
    }
    let version = r_u32(&mut r)?;
    if version != VERSION {
        return Err(Error::memo(format!("ATDB version {version} != {VERSION}")));
    }
    let layers = r_u32(&mut r)? as usize;
    let seq_len = r_u32(&mut r)? as usize;
    let apm_elems = r_u32(&mut r)? as usize;
    let embed_dim = r_u32(&mut r)? as usize;
    if layers != cfg.layers || apm_elems != cfg.apm_elems(seq_len)
        || embed_dim != cfg.embed_dim
    {
        return Err(Error::memo(format!(
            "ATDB dims (layers {layers}, elems {apm_elems}, dim {embed_dim}) \
             do not match family {:?}",
            cfg.family
        )));
    }
    let sequences = r_u64(&mut r)? as usize;
    let indexing_seconds = r_f64(&mut r)?;
    let build_seconds = r_f64(&mut r)?;
    let mut thr = [0f32; 3];
    for t in thr.iter_mut() {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        *t = f32::from_le_bytes(b);
    }
    let thresholds = Thresholds {
        conservative: thr[0],
        moderate: thr[1],
        aggressive: thr[2],
    };

    let mut db = AttentionDb::new(cfg, seq_len, hnsw);
    let mut similarity_samples = Vec::with_capacity(layers);
    let mut profiles = Vec::with_capacity(layers);
    for li in 0..layers {
        let n = r_u64(&mut r)? as usize;
        let mut feat_bytes = vec![0u8; n * embed_dim * 4];
        r.read_exact(&mut feat_bytes)?;
        let feats: Vec<f32> = feat_bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let mut apm_bytes = vec![0u8; n * apm_elems * 4];
        r.read_exact(&mut apm_bytes)?;
        let apms: Vec<f32> = apm_bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        db.insert_batch(li, &feats, &apms)?;
        similarity_samples.push(r_f32s(&mut r)?);
        let vals: Vec<f64> =
            (0..5).map(|_| r_f64(&mut r)).collect::<Result<_>>()?;
        profiles.push(LayerProfile {
            t_attn: vals[0],
            t_overhead: vals[1],
            t_apply: vals[2],
            t_fused: vals[3],
            alpha: vals[4],
            profiled_tokens: r_u64(&mut r)?,
        });
    }
    Ok(BuiltDb {
        db,
        thresholds,
        similarity_samples,
        profiles,
        indexing_seconds,
        build_seconds,
        sequences,
    })
}

/// Save a [`MemoTier`]'s warm state to `path`: per layer shard, the
/// compacted live entries (feature + APM payload) with their reuse counts
/// and clock reference bits, plus the similarity `threshold` the engine
/// served at (informational, echoed back by [`load_warm`]).
///
/// **Compaction policy (format v2):** entries that saw no admission or
/// reuse since the previous snapshot are aged out of the file instead of
/// persisted — a snapshot carries the working set, not the tier's cold
/// tail. The live tier keeps the aged-out entries (they can still hit
/// and re-warm into the next snapshot); only the file compacts. The
/// since-snapshot bits of exactly the serialized entries are cleared
/// inside the same writer-quiesced section the shard serialized under,
/// so an entry admitted or re-warmed while *other* shards serialize
/// keeps its bit and gets its grace period in the next snapshot. The rare loss
/// case is a failed rename after the bits cleared (disk full): the
/// serialized entries may then age out of the next file unless reused —
/// sound for a cache.
///
/// Each shard is serialized with its *writer* quiesced
/// (`MemoTier::read_layer_quiesced`): admissions and evictions wait for
/// the shard's turn to finish, while readers keep serving the published
/// snapshot throughout — a save never stalls the lookup path. Shards are
/// serialized one at a time, so a snapshot is per-shard (not cross-shard)
/// consistent — fine for a cache, where the worst case is re-missing a
/// handful of entries.
///
/// The snapshot is written to a sibling temp file, flushed, and renamed
/// over `path`, so a crash mid-write (or a full disk) can never destroy
/// the previous good snapshot — crucial for the periodic serve-loop
/// snapshots, which rewrite the same file until the process is killed.
pub fn save_warm(tier: &MemoTier, threshold: f32, path: &Path) -> Result<()> {
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    let aged_out = write_warm(tier, threshold, &tmp)?;
    std::fs::rename(&tmp, path)?;
    if aged_out > 0 {
        log::info!(
            "warm snapshot aged out {aged_out} idle entries \
             (no reuse since the previous snapshot)"
        );
    }
    Ok(())
}

/// Returns how many live entries the compaction policy aged out.
fn write_warm(tier: &MemoTier, threshold: f32, path: &Path) -> Result<u64> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(WARM_MAGIC)?;
    w_u32(&mut w, WARM_VERSION)?;
    w_u32(&mut w, tier.num_layers() as u32)?;
    w_u32(&mut w, tier.seq_len() as u32)?;
    w_u32(&mut w, tier.apm_elems() as u32)?;
    w_u32(&mut w, tier.embed_dim() as u32)?;
    w_u64(&mut w, tier.capacity() as u64)?;
    w.write_all(&threshold.to_le_bytes())?;
    let mut aged_out = 0u64;
    for li in 0..tier.num_layers() {
        // Writer-quiesced: no admission/eviction can churn this shard
        // mid-serialization; concurrent readers keep serving (and their
        // reuse marks land in the shared track, re-warming entries for
        // the *next* snapshot).
        aged_out += tier.read_layer_quiesced(li, |layer| -> Result<u64> {
            // Live ids only (eviction holes compact away in the file and
            // ids are reassigned densely on load), filtered by the
            // since-last-snapshot bits: idle entries age out of the file.
            let warm = layer.warm_bits();
            let live = layer.live_ids();
            let total = live.len();
            let ids: Vec<ApmId> = live
                .into_iter()
                .filter(|id| {
                    warm.get(id.0 as usize).copied().unwrap_or(1) != 0
                })
                .collect();
            // Snapshots of the lock-free reuse track (chunked atomics,
            // `Relaxed`): a reuse marked concurrently with these reads
            // may or may not be counted — the serialized counters are
            // advisory eviction/ordering hints, not an exact ledger.
            let counts = layer.reuse_counts();
            let refs = layer.reuse_refs();
            w_u64(&mut w, ids.len() as u64)?;
            for &id in &ids {
                w_f32_raw(&mut w, layer.index_vector(id))?;
            }
            for &id in &ids {
                w_f32_raw(&mut w, layer.arena().get(id)?)?;
            }
            for &id in &ids {
                w_u32(&mut w,
                      counts.get(id.0 as usize).copied().unwrap_or(0))?;
            }
            for &id in &ids {
                w.write_all(&[refs.get(id.0 as usize).copied().unwrap_or(0)])?;
            }
            // Start the next since-snapshot epoch for exactly the
            // serialized entries, still inside this shard's quiesced
            // section: concurrent reuses marked on *other* entries keep
            // their bits (and their grace period in the next snapshot).
            layer.clear_warm_bits_for(&ids);
            Ok((total - ids.len()) as u64)
        })?;
    }
    // Surface write errors here instead of swallowing them in the
    // BufWriter's Drop — a partial temp file must never be renamed live.
    w.flush()?;
    Ok(aged_out)
}

/// Load a warm snapshot saved by [`save_warm`] into a fresh [`MemoTier`]
/// configured from `memo`; returns the tier and the threshold recorded at
/// save time. Dimensions are validated against `cfg`; an unknown (newer)
/// format version is rejected — see `docs/PERSISTENCE.md`.
///
/// If `memo.max_db_entries` is tighter than the snapshot, the
/// most-reused entries are kept up to the new budget.
pub fn load_warm(path: &Path, cfg: &ModelConfig, memo: &MemoConfig,
                 hnsw: HnswParams) -> Result<(MemoTier, f32)> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != WARM_MAGIC {
        return Err(Error::memo(format!("{}: not an ATWM warm snapshot",
                                       path.display())));
    }
    let version = r_u32(&mut r)?;
    if !WARM_COMPAT_VERSIONS.contains(&version) {
        return Err(Error::memo(format!(
            "ATWM version {version} unsupported (this build reads \
             {WARM_COMPAT_VERSIONS:?}); re-warm from traffic or re-save"
        )));
    }
    let layers = r_u32(&mut r)? as usize;
    let seq_len = r_u32(&mut r)? as usize;
    let apm_elems = r_u32(&mut r)? as usize;
    let embed_dim = r_u32(&mut r)? as usize;
    if layers != cfg.layers || apm_elems != cfg.apm_elems(seq_len)
        || embed_dim != cfg.embed_dim
    {
        return Err(Error::memo(format!(
            "ATWM dims (layers {layers}, elems {apm_elems}, dim {embed_dim}) \
             do not match family {:?}",
            cfg.family
        )));
    }
    let _saved_capacity = r_u64(&mut r)?;
    let mut thr_bytes = [0u8; 4];
    r.read_exact(&mut thr_bytes)?;
    let threshold = f32::from_le_bytes(thr_bytes);

    let tier = MemoTier::new(cfg, seq_len, hnsw, memo);
    for li in 0..layers {
        let n = r_u64(&mut r)? as usize;
        let feats = r_f32_raw(&mut r, n * embed_dim)?;
        let apms = r_f32_raw(&mut r, n * apm_elems)?;
        let mut counts = Vec::with_capacity(n);
        for _ in 0..n {
            counts.push(r_u32(&mut r)?);
        }
        let mut refs = vec![0u8; n];
        r.read_exact(&mut refs)?;

        // Restore in reuse order when the new budget is tighter than the
        // snapshot: the hottest entries are the ones worth keeping.
        let mut order: Vec<usize> = (0..n).collect();
        let cap = memo.max_db_entries;
        if cap > 0 && n > cap {
            order.sort_by(|&a, &b| counts[b].cmp(&counts[a]));
            order.truncate(cap);
        }
        tier.write_layer(li, |layer| -> Result<()> {
            for &i in &order {
                layer.insert_restored(
                    &feats[i * embed_dim..(i + 1) * embed_dim],
                    &apms[i * apm_elems..(i + 1) * apm_elems],
                    counts[i],
                    refs[i],
                )?;
            }
            Ok(())
        })?;
    }
    Ok((tier, threshold))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn cfg() -> ModelConfig {
        ModelConfig {
            family: "bert".into(),
            vocab_size: 64,
            hidden: 16,
            layers: 2,
            heads: 2,
            ffn: 32,
            max_len: 8,
            num_classes: 2,
            rel_pos_buckets: 4,
            embed_dim: 8,
            embed_hidden: 16,
            embed_segments: 4,
            causal: false,
        }
    }

    fn demo_built() -> BuiltDb {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 8, HnswParams::default());
        let mut rng = Pcg32::seeded(5);
        for li in 0..c.layers {
            for _ in 0..6 {
                let f: Vec<f32> =
                    (0..c.embed_dim).map(|_| rng.next_gaussian()).collect();
                let apm: Vec<f32> =
                    (0..c.apm_elems(8)).map(|_| rng.next_f32()).collect();
                db.layer_mut(li).insert(&f, &apm).unwrap();
            }
        }
        BuiltDb {
            db,
            thresholds: Thresholds {
                conservative: 0.9,
                moderate: 0.8,
                aggressive: 0.7,
            },
            similarity_samples: vec![vec![0.5, 0.9], vec![0.3]],
            profiles: vec![
                LayerProfile {
                    t_attn: 1.0,
                    t_overhead: 0.1,
                    t_apply: 0.2,
                    t_fused: 1.1,
                    alpha: 0.5,
                    profiled_tokens: 64,
                };
                2
            ],
            indexing_seconds: 0.5,
            build_seconds: 2.0,
            sequences: 6,
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let built = demo_built();
        let dir = std::env::temp_dir().join("attmemo_persist");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.atdb");
        save(&built, &path).unwrap();
        let loaded = load(&path, &cfg(), HnswParams::default()).unwrap();
        assert_eq!(loaded.db.total_entries(), built.db.total_entries());
        assert_eq!(loaded.sequences, 6);
        assert_eq!(loaded.thresholds.moderate, 0.8);
        assert_eq!(loaded.similarity_samples, built.similarity_samples);
        assert_eq!(loaded.profiles[0].profiled_tokens, 64);
        // Payloads survive byte-exactly.
        for li in 0..2 {
            for id in 0..6u32 {
                assert_eq!(
                    loaded.db.layer(li).arena().get(ApmId(id)).unwrap(),
                    built.db.layer(li).arena().get(ApmId(id)).unwrap()
                );
            }
        }
        // The rebuilt index finds the same nearest entry.
        let f = built.db.layer(0).index_vector(ApmId(3)).to_vec();
        let hit = loaded.db.layer(0).lookup(&f, 32).unwrap();
        assert_eq!(hit.id, ApmId(3));
    }

    #[test]
    fn load_rejects_wrong_family_dims() {
        let built = demo_built();
        let dir = std::env::temp_dir().join("attmemo_persist2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("db.atdb");
        save(&built, &path).unwrap();
        let mut other = cfg();
        other.embed_dim = 16;
        assert!(load(&path, &other, HnswParams::default()).is_err());
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("attmemo_persist3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.atdb");
        std::fs::write(&path, b"not a database").unwrap();
        assert!(load(&path, &cfg(), HnswParams::default()).is_err());
    }

    fn warm_memo(capacity: usize) -> MemoConfig {
        MemoConfig {
            online_admission: true,
            max_db_entries: capacity,
            admission_min_attempts: 0,
            ..MemoConfig::default()
        }
    }

    #[test]
    fn warm_roundtrip_preserves_entries_and_reuse() {
        let c = cfg();
        let memo = warm_memo(16);
        let tier = MemoTier::new(&c, 8, HnswParams::default(), &memo);
        let mut rng = Pcg32::seeded(31);
        let elems = c.apm_elems(8);
        for li in 0..c.layers {
            for i in 0..5 {
                let f: Vec<f32> =
                    (0..c.embed_dim).map(|_| rng.next_gaussian()).collect();
                let apm = vec![(li * 10 + i) as f32; elems];
                tier.admit_batch(li, &[(f.as_slice(), apm.as_slice())],
                                 2.0, 32)
                    .unwrap();
            }
        }
        // Mark some reuse so the counters have something to carry.
        let probe = tier.read_layer(0, |l| {
            l.index_vector(l.live_ids()[2]).to_vec()
        });
        let mut dst = vec![0.0f32; elems];
        for _ in 0..3 {
            tier.lookup_fetch(0, &probe, 32, -10.0, &mut dst).unwrap();
        }

        let dir = std::env::temp_dir().join("attmemo_warm1");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("warm.atwm");
        save_warm(&tier, 0.8, &path).unwrap();
        let (loaded, thr) =
            load_warm(&path, &c, &memo, HnswParams::default()).unwrap();
        assert_eq!(thr, 0.8);
        assert_eq!(loaded.total_entries(), tier.total_entries());
        // Payload + reuse state survive byte-exactly (insertion order is
        // live-id order, so ids line up on a hole-free tier).
        for li in 0..c.layers {
            let want = tier.read_layer(li, |l| {
                (l.reuse_counts(), l.reuse_refs())
            });
            let got = loaded.read_layer(li, |l| {
                (l.reuse_counts(), l.reuse_refs())
            });
            assert_eq!(want, got, "layer {li} reuse state");
        }
        // A probe that hit before the save still hits after the load.
        let hit = loaded.lookup_fetch(0, &probe, 32, 0.99, &mut dst);
        assert!(hit.is_some(), "warm entry lost in the roundtrip");
    }

    #[test]
    fn warm_load_respects_tighter_budget() {
        let c = cfg();
        let tier =
            MemoTier::new(&c, 8, HnswParams::default(), &warm_memo(0));
        let mut rng = Pcg32::seeded(37);
        let elems = c.apm_elems(8);
        for _ in 0..6 {
            let f: Vec<f32> =
                (0..c.embed_dim).map(|_| rng.next_gaussian()).collect();
            tier.admit_batch(0, &[(f.as_slice(), &vec![0.0; elems][..])],
                             2.0, 32)
                .unwrap();
        }
        // Heat up entry 4 so the truncated load must keep it.
        let hot = tier.read_layer(0, |l| {
            l.index_vector(l.live_ids()[4]).to_vec()
        });
        let mut dst = vec![0.0f32; elems];
        for _ in 0..4 {
            tier.lookup_fetch(0, &hot, 32, -10.0, &mut dst).unwrap();
        }
        let dir = std::env::temp_dir().join("attmemo_warm2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("warm.atwm");
        save_warm(&tier, 0.9, &path).unwrap();
        let (loaded, _) =
            load_warm(&path, &c, &warm_memo(2), HnswParams::default())
                .unwrap();
        assert_eq!(loaded.layer_len(0), 2, "budget respected on load");
        let hit = loaded.lookup_fetch(0, &hot, 32, 0.99, &mut dst);
        assert!(hit.is_some(), "hottest entry must survive truncation");
    }

    /// Satellite: the second snapshot ages out entries with zero reuses
    /// since the first one; fresh admissions and reused entries persist.
    #[test]
    fn save_warm_ages_out_idle_entries() {
        let c = cfg();
        let memo = warm_memo(16);
        let tier = MemoTier::new(&c, 8, HnswParams::default(), &memo);
        let mut rng = Pcg32::seeded(43);
        let elems = c.apm_elems(8);
        let feats: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..c.embed_dim).map(|_| rng.next_gaussian()).collect())
            .collect();
        for f in &feats {
            let apm = vec![1.0f32; elems];
            tier.admit_batch(0, &[(f.as_slice(), apm.as_slice())], 2.0, 32)
                .unwrap();
        }

        let dir = std::env::temp_dir().join("attmemo_warm_age");
        std::fs::create_dir_all(&dir).unwrap();
        let first = dir.join("first.atwm");
        save_warm(&tier, 0.8, &first).unwrap();
        let (loaded, _) =
            load_warm(&first, &c, &memo, HnswParams::default()).unwrap();
        assert_eq!(loaded.total_entries(), 4,
                   "every fresh entry survives its first snapshot");

        // Between snapshots: one entry is reused, one fresh entry admits,
        // the other three stay idle.
        let mut dst = vec![0.0f32; elems];
        assert!(tier
            .lookup_fetch(0, &feats[2], 32, -10.0, &mut dst)
            .is_some());
        let fresh: Vec<f32> =
            (0..c.embed_dim).map(|_| rng.next_gaussian()).collect();
        tier.admit_batch(
            0, &[(fresh.as_slice(), &vec![2.0f32; elems][..])], 2.0, 32)
            .unwrap();

        let second = dir.join("second.atwm");
        save_warm(&tier, 0.8, &second).unwrap();
        let (loaded, _) =
            load_warm(&second, &c, &memo, HnswParams::default()).unwrap();
        assert_eq!(loaded.total_entries(), 2,
                   "idle entries must age out of the second snapshot");
        // The live tier keeps everything — only the file compacts.
        assert_eq!(tier.layer_len(0), 5);
        // Exactly the reused and the freshly admitted entries survive.
        assert!(loaded
            .lookup_fetch(0, &feats[2], 32, 0.99, &mut dst)
            .is_some());
        assert!(loaded
            .lookup_fetch(0, &fresh, 32, 0.99, &mut dst)
            .is_some());
        assert!(loaded
            .lookup_fetch(0, &feats[0], 32, 0.99, &mut dst)
            .is_none());
    }

    #[test]
    fn warm_load_accepts_version_one() {
        // v1 and v2 share a layout; a v1 file (older producer) must load.
        let c = cfg();
        let memo = warm_memo(8);
        let tier = MemoTier::new(&c, 8, HnswParams::default(), &memo);
        let elems = c.apm_elems(8);
        let f = vec![0.5f32; c.embed_dim];
        tier.admit_batch(0, &[(f.as_slice(), &vec![1.0f32; elems][..])],
                         2.0, 32)
            .unwrap();
        let dir = std::env::temp_dir().join("attmemo_warm_v1");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.atwm");
        save_warm(&tier, 0.7, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4..8].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let (loaded, thr) =
            load_warm(&path, &c, &memo, HnswParams::default()).unwrap();
        assert_eq!(thr, 0.7);
        assert_eq!(loaded.total_entries(), 1);
    }

    #[test]
    fn warm_load_rejects_future_version_and_garbage() {
        let c = cfg();
        let dir = std::env::temp_dir().join("attmemo_warm3");
        std::fs::create_dir_all(&dir).unwrap();
        let garbage = dir.join("bad.atwm");
        std::fs::write(&garbage, b"not a snapshot").unwrap();
        assert!(load_warm(&garbage, &c, &warm_memo(0),
                          HnswParams::default())
            .is_err());
        // A future version must be rejected, not mis-parsed.
        let future = dir.join("future.atwm");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(WARM_MAGIC);
        bytes.extend_from_slice(&(WARM_VERSION + 1).to_le_bytes());
        std::fs::write(&future, &bytes).unwrap();
        let err = load_warm(&future, &c, &warm_memo(0),
                            HnswParams::default())
            .unwrap_err();
        assert!(format!("{err}").contains("unsupported"), "{err}");
    }
}
