//! Page-aligned APM arena backed by an in-memory file (`memfd_create`).
//!
//! This is the substrate for the paper's memory-mapping trick (§5.3,
//! Fig. 9): every APM is stored page-aligned inside one shared memory file,
//! so a *batch* of scattered APMs can be gathered into a contiguous virtual
//! tensor by mapping their pages back-to-back (`gather.rs`) instead of
//! copying them. The arena is the "attention database memory" — on the
//! paper's testbed it would live in Optane; here it is anonymous shared
//! memory with the tier's latency modelled separately (`memtier`).
//!
//! Entries are addressed by a stable, monotonically assigned [`ApmId`];
//! ids map to *physical page slots* through an indirection table so that
//! serve-time eviction ([`ApmArena::remove`]) frees a slot for reuse by a
//! later admission instead of growing the file forever. A removed id stays
//! dead: `get`/`file_offset` on it error, and its slot's next tenant gets a
//! fresh id.

use std::os::fd::RawFd;
use std::sync::OnceLock;

use crate::{Error, Result};

/// System page size (4096 on this platform; queried once).
pub fn page_size() -> usize {
    static PAGE: OnceLock<usize> = OnceLock::new();
    *PAGE.get_or_init(|| unsafe { libc::sysconf(libc::_SC_PAGESIZE) as usize })
}

/// Round `n` up to a page multiple.
pub fn page_align(n: usize) -> usize {
    let p = page_size();
    (n + p - 1) / p * p
}

/// Identifier of one stored APM entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ApmId(
    /// Raw id value (dense per layer, monotonically assigned).
    pub u32,
);

/// Fixed-stride, page-aligned entry store on a memfd with slot reuse.
///
/// ```
/// use attmemo::memo::ApmArena;
/// let mut arena = ApmArena::new(8).unwrap();
/// let id = arena.push(&[1.0; 8]).unwrap();
/// assert_eq!(arena.get(id).unwrap(), &[1.0; 8]);
/// ```
pub struct ApmArena {
    fd: RawFd,
    /// Bytes of payload per entry (f32 count × 4).
    entry_bytes: usize,
    /// Page-aligned stride between entries.
    stride: usize,
    /// id → physical slot; `None` once evicted.
    slots: Vec<Option<u32>>,
    /// Physical slots freed by eviction, available for reuse.
    free: Vec<u32>,
    /// Live entries (`slots` entries that are `Some`).
    live: usize,
    /// Physical slots ever handed out (high-water mark).
    phys_used: usize,
    /// Physical slots the file currently holds.
    cap: usize,
    /// Persistent read-write mapping of the whole file.
    base: *mut u8,
    map_bytes: usize,
    /// Arena generation: bumped by the owner (`LayerDb::compact`) whenever
    /// the id space is renumbered, so pre-compaction epoch stamps can never
    /// validate against the rebuilt arena.
    generation: u32,
    /// Per-physical-slot reuse epoch, bumped on every `remove`. One slot's
    /// epoch identifies which *tenant* a stamp was taken against.
    slot_epochs: Vec<u32>,
}

// The raw pointer is only dereferenced through &self/&mut self with range
// checks; the underlying memfd pages are valid for the arena's lifetime.
unsafe impl Send for ApmArena {}
unsafe impl Sync for ApmArena {}

const GROW_CHUNK: usize = 256; // entries added per ftruncate

impl ApmArena {
    /// Create an arena for entries of `elems` f32 values each.
    pub fn new(elems: usize) -> Result<Self> {
        if elems == 0 {
            return Err(Error::memo("arena entry size must be positive"));
        }
        let entry_bytes = elems * 4;
        let stride = page_align(entry_bytes);
        let fd = unsafe {
            libc::memfd_create(b"attmemo-apm\0".as_ptr().cast(), 0)
        };
        if fd < 0 {
            return Err(Error::Io(std::io::Error::last_os_error()));
        }
        let mut arena = ApmArena {
            fd,
            entry_bytes,
            stride,
            slots: Vec::new(),
            free: Vec::new(),
            live: 0,
            phys_used: 0,
            cap: 0,
            base: std::ptr::null_mut(),
            map_bytes: 0,
            generation: 0,
            slot_epochs: Vec::new(),
        };
        arena.grow(GROW_CHUNK)?;
        Ok(arena)
    }

    /// Whether gathered batches are usable as one contiguous f32 tensor
    /// (true iff the payload exactly fills its pages; holds for all
    /// serving shapes — e.g. 4·128·128·4 B = 64 pages).
    pub fn dense_mappable(&self) -> bool {
        self.entry_bytes == self.stride
    }

    /// Bytes of payload per entry.
    pub fn entry_bytes(&self) -> usize {
        self.entry_bytes
    }

    /// f32 values per entry.
    pub fn entry_elems(&self) -> usize {
        self.entry_bytes / 4
    }

    /// Page-aligned byte stride between entries in the file.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Arena generation (see [`ApmArena::epoch`]); bumped when the id space
    /// is renumbered by a compaction.
    pub fn generation(&self) -> u32 {
        self.generation
    }

    /// Stamp the arena with a generation. Used by compaction to mark the
    /// rebuilt arena as a different id universe than its predecessor.
    pub(crate) fn set_generation(&mut self, generation: u32) {
        self.generation = generation;
    }

    /// Epoch stamp of a live entry: encodes the arena generation and the
    /// entry's physical-slot reuse counter. A stamp taken at lookup time
    /// and passed back to [`ApmArena::get_checked`] guarantees the bytes
    /// read belong to the *same tenant* the lookup matched — a concurrent
    /// eviction that frees and reuses the slot (or a compaction that
    /// renumbers ids) invalidates the stamp instead of silently serving
    /// stale or foreign bytes. Errors on dead/unknown ids.
    pub fn epoch(&self, id: ApmId) -> Result<u64> {
        match self.slots.get(id.0 as usize) {
            Some(Some(slot)) => Ok(((self.generation as u64) << 32)
                | self.slot_epochs[*slot as usize] as u64),
            Some(None) => {
                Err(Error::memo(format!("ApmId {} was evicted", id.0)))
            }
            None => Err(Error::memo(format!(
                "ApmId {} out of range {}",
                id.0,
                self.slots.len()
            ))),
        }
    }

    /// Read-only view of one entry, validated against an epoch stamp taken
    /// when the entry was looked up (see [`ApmArena::epoch`]). Errors if
    /// the id has died, its slot was reused, or the arena was compacted
    /// since the stamp — never returns another tenant's bytes.
    pub fn get_checked(&self, id: ApmId, epoch: u64) -> Result<&[f32]> {
        if self.epoch(id)? != epoch {
            return Err(Error::memo(format!(
                "ApmId {} is stale: slot reused or arena compacted since \
                 lookup",
                id.0
            )));
        }
        self.get(id)
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.live
    }

    /// Whether no entries are live.
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Upper bound of the id space: ids in `[0, next_id)` have been issued
    /// (some may since have been removed).
    pub fn next_id(&self) -> u32 {
        self.slots.len() as u32
    }

    /// Is `id` currently stored?
    pub fn is_live(&self, id: ApmId) -> bool {
        self.slots
            .get(id.0 as usize)
            .map_or(false, |s| s.is_some())
    }

    /// Ids of all live entries, ascending.
    pub fn live_ids(&self) -> Vec<ApmId> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.map(|_| ApmId(i as u32)))
            .collect()
    }

    pub(crate) fn fd(&self) -> RawFd {
        self.fd
    }

    /// Total bytes resident in the store (capacity × stride).
    pub fn resident_bytes(&self) -> usize {
        self.cap * self.stride
    }

    /// Byte offset of an entry inside the file (for gather mappings).
    pub(crate) fn file_offset(&self, id: ApmId) -> Result<usize> {
        match self.slots.get(id.0 as usize) {
            Some(Some(slot)) => Ok(*slot as usize * self.stride),
            Some(None) => {
                Err(Error::memo(format!("ApmId {} was evicted", id.0)))
            }
            None => Err(Error::memo(format!(
                "ApmId {} out of range {}",
                id.0,
                self.slots.len()
            ))),
        }
    }

    fn grow(&mut self, extra: usize) -> Result<()> {
        let new_cap = self.cap + extra;
        let bytes = new_cap * self.stride;
        if unsafe { libc::ftruncate(self.fd, bytes as libc::off_t) } != 0 {
            return Err(Error::Io(std::io::Error::last_os_error()));
        }
        // Remap the full file read-write.
        if !self.base.is_null() {
            unsafe { libc::munmap(self.base.cast(), self.map_bytes) };
        }
        let base = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                bytes,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                self.fd,
                0,
            )
        };
        if base == libc::MAP_FAILED {
            return Err(Error::Io(std::io::Error::last_os_error()));
        }
        self.base = base.cast();
        self.map_bytes = bytes;
        self.cap = new_cap;
        Ok(())
    }

    /// Store one entry — into a freed slot when available, appending
    /// otherwise; returns its (fresh) id.
    pub fn push(&mut self, data: &[f32]) -> Result<ApmId> {
        if data.len() * 4 != self.entry_bytes {
            return Err(Error::memo(format!(
                "arena push: want {} f32, got {}",
                self.entry_bytes / 4,
                data.len()
            )));
        }
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                if self.phys_used == self.cap {
                    self.grow(GROW_CHUNK.max(self.cap / 2))?;
                }
                let s = self.phys_used as u32;
                self.phys_used += 1;
                self.slot_epochs.push(0);
                s
            }
        };
        let off = slot as usize * self.stride;
        unsafe {
            std::ptr::copy_nonoverlapping(
                data.as_ptr().cast::<u8>(),
                self.base.add(off),
                self.entry_bytes,
            );
        }
        self.slots.push(Some(slot));
        self.live += 1;
        Ok(ApmId((self.slots.len() - 1) as u32))
    }

    /// Evict an entry: its id goes dead and its physical slot becomes
    /// reusable by a later `push`.
    pub fn remove(&mut self, id: ApmId) -> Result<()> {
        let i = id.0 as usize;
        if i >= self.slots.len() {
            return Err(Error::memo(format!(
                "ApmId {} out of range {}",
                id.0,
                self.slots.len()
            )));
        }
        match self.slots[i].take() {
            Some(slot) => {
                // Epoch-check support: the slot's next tenant must be
                // distinguishable from this one, even at the same offset.
                let e = &mut self.slot_epochs[slot as usize];
                *e = e.wrapping_add(1);
                self.free.push(slot);
                self.live -= 1;
                Ok(())
            }
            None => {
                Err(Error::memo(format!("ApmId {} already evicted", id.0)))
            }
        }
    }

    /// Read-only view of one entry.
    pub fn get(&self, id: ApmId) -> Result<&[f32]> {
        let off = self.file_offset(id)?;
        unsafe {
            Ok(std::slice::from_raw_parts(
                self.base.add(off).cast::<f32>(),
                self.entry_bytes / 4,
            ))
        }
    }
}

impl Drop for ApmArena {
    fn drop(&mut self) {
        if !self.base.is_null() {
            unsafe { libc::munmap(self.base.cast(), self.map_bytes) };
        }
        unsafe { libc::close(self.fd) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_alignment() {
        let p = page_size();
        assert!(p >= 4096);
        assert_eq!(page_align(1), p);
        assert_eq!(page_align(p), p);
        assert_eq!(page_align(p + 1), 2 * p);
    }

    #[test]
    fn push_get_roundtrip() {
        let mut a = ApmArena::new(16).unwrap();
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..16).map(|i| -(i as f32)).collect();
        let ix = a.push(&x).unwrap();
        let iy = a.push(&y).unwrap();
        assert_eq!(a.get(ix).unwrap(), &x[..]);
        assert_eq!(a.get(iy).unwrap(), &y[..]);
        assert_eq!(a.len(), 2);
        assert!(a.get(ApmId(2)).is_err());
    }

    #[test]
    fn wrong_size_push_rejected() {
        let mut a = ApmArena::new(16).unwrap();
        assert!(a.push(&[0.0; 8]).is_err());
    }

    #[test]
    fn growth_preserves_data() {
        let elems = 32;
        let mut a = ApmArena::new(elems).unwrap();
        let n = GROW_CHUNK * 2 + 7; // force at least two grows
        for i in 0..n {
            let v = vec![i as f32; elems];
            a.push(&v).unwrap();
        }
        for i in (0..n).step_by(97) {
            assert_eq!(a.get(ApmId(i as u32)).unwrap()[0], i as f32);
        }
        assert_eq!(a.len(), n);
    }

    #[test]
    fn dense_mappable_when_entry_fills_pages() {
        let page_elems = page_size() / 4;
        assert!(ApmArena::new(page_elems).unwrap().dense_mappable());
        assert!(!ApmArena::new(page_elems - 1).unwrap().dense_mappable());
    }

    #[test]
    fn remove_kills_id_and_reuses_slot() {
        let mut a = ApmArena::new(8).unwrap();
        let i0 = a.push(&[0.0; 8]).unwrap();
        let i1 = a.push(&[1.0; 8]).unwrap();
        a.remove(i0).unwrap();
        assert_eq!(a.len(), 1);
        assert!(!a.is_live(i0));
        assert!(a.get(i0).is_err());
        assert!(a.remove(i0).is_err());
        // The freed physical slot is reused: same file offset, fresh id.
        let off0 = 0; // i0 was the first physical slot
        let i2 = a.push(&[2.0; 8]).unwrap();
        assert_eq!(i2, ApmId(2), "ids stay monotonic");
        assert_eq!(a.file_offset(i2).unwrap(), off0, "slot reused");
        assert_eq!(a.get(i2).unwrap(), &[2.0; 8]);
        assert_eq!(a.get(i1).unwrap(), &[1.0; 8], "live entry untouched");
        assert_eq!(a.live_ids(), vec![i1, i2]);
        assert_eq!(a.next_id(), 3);
    }

    #[test]
    fn epoch_invalidates_reused_slot() {
        let mut a = ApmArena::new(8).unwrap();
        let i0 = a.push(&[0.0; 8]).unwrap();
        let e0 = a.epoch(i0).unwrap();
        assert_eq!(a.get_checked(i0, e0).unwrap(), &[0.0; 8]);
        a.remove(i0).unwrap();
        // Same physical slot, new tenant: the old stamp must not validate.
        let i1 = a.push(&[1.0; 8]).unwrap();
        assert_eq!(a.file_offset(i1).unwrap(), 0, "slot reused");
        assert!(a.epoch(i0).is_err(), "dead id has no epoch");
        assert!(a.get_checked(i0, e0).is_err());
        let e1 = a.epoch(i1).unwrap();
        assert_ne!(e1, e0, "reused slot must change epoch");
        assert!(a.get_checked(i1, e0).is_err(), "stale stamp rejected");
        assert_eq!(a.get_checked(i1, e1).unwrap(), &[1.0; 8]);
    }

    #[test]
    fn generation_invalidates_old_stamps() {
        let mut a = ApmArena::new(4).unwrap();
        let id = a.push(&[7.0; 4]).unwrap();
        let stamp = a.epoch(id).unwrap();
        a.set_generation(a.generation() + 1);
        assert!(a.get_checked(id, stamp).is_err(),
                "stamps from another generation must not validate");
        assert_eq!(a.get_checked(id, a.epoch(id).unwrap()).unwrap(),
                   &[7.0; 4]);
    }

    #[test]
    fn bounded_slot_reuse_never_grows_file() {
        let mut a = ApmArena::new(4).unwrap();
        let mut id = a.push(&[0.0; 4]).unwrap();
        let bytes = a.resident_bytes();
        for i in 0..2 * GROW_CHUNK {
            a.remove(id).unwrap();
            id = a.push(&[i as f32; 4]).unwrap();
        }
        assert_eq!(a.resident_bytes(), bytes, "churn must not grow the file");
        assert_eq!(a.len(), 1);
    }
}
