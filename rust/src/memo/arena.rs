//! Page-aligned APM arena backed by a memory-mapped file — an anonymous
//! in-memory one (`memfd_create`, the hot tier) or a regular on-disk one
//! ([`ApmArena::new_file_backed`], the cold spill tier's store).
//!
//! This is the substrate for the paper's memory-mapping trick (§5.3,
//! Fig. 9): every APM is stored page-aligned inside one shared memory file,
//! so a *batch* of scattered APMs can be gathered into a contiguous virtual
//! tensor by mapping their pages back-to-back (`gather.rs`) instead of
//! copying them. The arena is the "attention database memory" — on the
//! paper's testbed it would live in Optane; here it is anonymous shared
//! memory with the tier's latency modelled separately (`memtier`).
//!
//! Entries are addressed by a stable, monotonically assigned [`ApmId`];
//! ids map to *physical page slots* through an indirection table so that
//! serve-time eviction ([`ApmArena::remove`]) frees a slot for reuse by a
//! later admission instead of growing the file forever. A removed id stays
//! dead: `get`/`file_offset` on it error, and its slot's next tenant gets a
//! fresh id.
//!
//! **Snapshots.** Since the seqlock tier (`memo/tier.rs`) went
//! copy-on-write, an arena value is a cheap *snapshot* over a shared
//! backing store: the memfd, its size and its mappings live in an
//! `Arc`-shared [`Store`], while the id→slot table, slot epochs and the
//! free list are per-snapshot. `cow_clone` gives the tier's writer a
//! private copy to mutate; published (frozen) snapshots keep reading the
//! same physical pages. Two rules make that safe with zero reader-side
//! synchronization:
//!
//! * the file **never shrinks or remaps in place** — growth creates a new
//!   mapping and old mappings stay alive (each snapshot pins the mapping
//!   that covers its slots), so a reader's pointer is valid for as long
//!   as it holds the snapshot;
//! * in deferred-free mode (`set_defer_free`, the tier's writer lineage),
//!   a removed entry's physical slot goes onto a *pending* list instead
//!   of the free list — the tier recycles it only once every snapshot
//!   that could still reference the slot has quiesced, so no reader ever
//!   observes a slot's bytes being overwritten under it. When the tier's
//!   retire list hits its generation cap, a slot may be recycled *under*
//!   a stalled reader; the shared tenancy-epoch table below turns that
//!   reader's fetches into clean stamp failures instead of foreign bytes.
//!
//! **Tenancy epochs.** Each physical slot carries a *live* epoch counter
//! in a table of atomics shared across every snapshot of the lineage
//! ([`EpochTable`]); a snapshot's id table records the epoch the tenant
//! was stored under. The two agree while the tenant is live *or* merely
//! evicted-but-unreclaimed (frozen snapshots keep serving such entries,
//! the hit-rate grace PR 5 established); the live epoch is bumped only
//! when the slot is **claimed by its next tenant**, at which point every
//! older snapshot's stamps stop validating. Readers revalidate the stamp
//! *after* copying payload bytes ([`ApmArena::recheck`]), closing the
//! check-then-copy window when a forced reclaim overwrites a slot mid-read.

use std::os::fd::RawFd;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::{Error, Result};

/// System page size (4096 on this platform; queried once).
pub fn page_size() -> usize {
    static PAGE: OnceLock<usize> = OnceLock::new();
    *PAGE.get_or_init(|| unsafe { libc::sysconf(libc::_SC_PAGESIZE) as usize })
}

/// Round `n` up to a page multiple.
pub fn page_align(n: usize) -> usize {
    let p = page_size();
    (n + p - 1) / p * p
}

/// Identifier of one stored APM entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ApmId(
    /// Raw id value (dense per layer, monotonically assigned).
    pub u32,
);

/// One read-write `MAP_SHARED` view of the store's file. Mappings are
/// immutable once created and shared behind `Arc`: growth creates a new,
/// larger mapping while snapshots keep pinning the one that covers their
/// slots — all mappings alias the same physical pages, so a write through
/// the newest mapping is visible through every older one.
struct Mapping {
    base: *mut u8,
    bytes: usize,
}

// SAFETY: the raw pointer is only dereferenced with range checks against
// slots the owning snapshot knows; the pages stay mapped for the Mapping's
// lifetime (munmap happens in Drop, after every referencing snapshot is
// gone).
unsafe impl Send for Mapping {}
unsafe impl Sync for Mapping {}

impl Mapping {
    fn empty() -> Mapping {
        Mapping { base: std::ptr::null_mut(), bytes: 0 }
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        if !self.base.is_null() {
            unsafe { libc::munmap(self.base.cast(), self.bytes) };
        }
    }
}

/// Growth state of a store: serialized by its mutex (in practice by the
/// tier's per-shard writer mutex — only one lineage writer allocates).
struct GrowState {
    /// Physical slots the file currently holds.
    cap: usize,
    /// Physical slots ever handed out (high-water mark).
    phys_used: usize,
    /// Mapping covering all `cap` slots.
    map: Arc<Mapping>,
}

/// The shared backing store of one arena lineage: the memfd plus its
/// growth state. Snapshot clones of an arena share the store; the file is
/// closed when the last snapshot drops.
struct Store {
    fd: RawFd,
    /// Page-aligned byte stride between entries.
    stride: usize,
    grow: Mutex<GrowState>,
    /// `cap × stride`, readable without the grow lock (stats path).
    resident: AtomicUsize,
}

/// Owned identity of one backing [`Store`]: freed page slots are only
/// meaningful on the store they were freed on, so the tier tags its
/// deferred-reclaim lists with a handle and refuses to recycle slots onto
/// any other store (a compaction mid-batch moves the lineage to a fresh
/// store; the old one retires wholesale). Holding the store `Arc` means
/// the identity can never be recycled onto a different memfd.
pub(crate) struct StoreHandle(Arc<Store>);

impl Drop for Store {
    fn drop(&mut self) {
        unsafe { libc::close(self.fd) };
    }
}

/// Extend the file by `extra` slots and install a fresh covering mapping.
/// Old mappings are left untouched (snapshots may still read them).
fn grow_store(store: &Store, g: &mut GrowState, extra: usize) -> Result<()> {
    let new_cap = g.cap + extra;
    let bytes = new_cap * store.stride;
    if unsafe { libc::ftruncate(store.fd, bytes as libc::off_t) } != 0 {
        return Err(Error::Io(std::io::Error::last_os_error()));
    }
    let base = unsafe {
        libc::mmap(
            std::ptr::null_mut(),
            bytes,
            libc::PROT_READ | libc::PROT_WRITE,
            libc::MAP_SHARED,
            store.fd,
            0,
        )
    };
    if base == libc::MAP_FAILED {
        return Err(Error::Io(std::io::Error::last_os_error()));
    }
    g.map = Arc::new(Mapping { base: base.cast(), bytes });
    g.cap = new_cap;
    store.resident.store(bytes, Ordering::Relaxed);
    Ok(())
}

/// Entries per chunk of the chunked id→slot table and the shared
/// tenancy-epoch table. Matches `GROW_CHUNK` so one admission's table
/// traffic stays within the store-growth granularity.
const TABLE_CHUNK: usize = 256;

/// One live entry's physical location plus the tenancy epoch it was
/// stored under (the per-snapshot half of the stamp check; the live half
/// is the shared [`EpochTable`]).
#[derive(Debug, Clone, Copy)]
struct SlotRef {
    slot: u32,
    epoch: u32,
}

/// Chunked persistent id→slot table: chunks are `Arc`-shared between a
/// snapshot and its copy-on-write clone, and a mutation clones only the
/// chunk it touches (`Arc::make_mut`). This keeps `cow_clone` — paid on
/// *every* admission batch — at O(chunks touched), not O(ids ever issued).
#[derive(Clone, Default)]
struct SlotTable {
    chunks: Vec<Arc<Vec<Option<SlotRef>>>>,
    len: usize,
}

impl SlotTable {
    /// `Some(entry)` for issued ids, `None` past the end of the id space.
    fn get(&self, i: usize) -> Option<Option<SlotRef>> {
        if i >= self.len {
            return None;
        }
        Some(self.chunks[i / TABLE_CHUNK][i % TABLE_CHUNK])
    }

    fn push(&mut self, v: Option<SlotRef>) {
        if self.len % TABLE_CHUNK == 0 {
            self.chunks
                .push(Arc::new(Vec::with_capacity(TABLE_CHUNK)));
        }
        let last = self.chunks.last_mut().expect("chunk just ensured");
        Arc::make_mut(last).push(v);
        self.len += 1;
    }

    fn set(&mut self, i: usize, v: Option<SlotRef>) {
        Arc::make_mut(&mut self.chunks[i / TABLE_CHUNK])[i % TABLE_CHUNK] = v;
    }

    fn len(&self) -> usize {
        self.len
    }

    fn iter(&self) -> impl Iterator<Item = Option<SlotRef>> + '_ {
        self.chunks.iter().flat_map(|c| c.iter().copied())
    }
}

/// One chunk of live tenancy epochs (atomics, shared across snapshots).
struct EpochChunk([AtomicU32; TABLE_CHUNK]);

impl EpochChunk {
    fn new() -> Self {
        EpochChunk(std::array::from_fn(|_| AtomicU32::new(0)))
    }
}

/// Per-physical-slot *live* tenancy epochs. The chunk list is cloned per
/// snapshot (cheap `Arc` copies) but the counters inside are shared by the
/// whole lineage: when a slot is claimed by a new tenant the claim bump is
/// visible through every frozen snapshot, which is what lets the tier
/// force-reclaim slots from under a stalled reader without that reader
/// ever validating a stamp against foreign bytes.
#[derive(Clone, Default)]
struct EpochTable {
    chunks: Vec<Arc<EpochChunk>>,
    slots: usize,
}

impl EpochTable {
    /// Make sure `slot` has a counter (writer-side, under the grow/writer
    /// serialization; frozen snapshots never index past their own slots).
    fn ensure(&mut self, slot: usize) {
        while self.slots <= slot {
            if self.slots % TABLE_CHUNK == 0 {
                self.chunks.push(Arc::new(EpochChunk::new()));
            }
            self.slots += 1;
        }
    }

    /// Current tenancy epoch of a slot.
    fn load(&self, slot: u32) -> u32 {
        let i = slot as usize;
        self.chunks[i / TABLE_CHUNK].0[i % TABLE_CHUNK]
            .load(Ordering::Acquire)
    }

    /// Claim a previously-used slot for a new tenant: bump its live epoch
    /// so every stamp taken against the previous tenant stops validating.
    /// `AcqRel` keeps the payload writes that follow ordered after the
    /// bump — a racing reader that observes any new bytes must also
    /// observe the bump on its post-copy revalidation.
    fn claim(&self, slot: u32) -> u32 {
        let i = slot as usize;
        self.chunks[i / TABLE_CHUNK].0[i % TABLE_CHUNK]
            .fetch_add(1, Ordering::AcqRel)
            .wrapping_add(1)
    }
}

/// Fixed-stride, page-aligned entry store on a memfd with slot reuse.
///
/// ```
/// use attmemo::memo::ApmArena;
/// let mut arena = ApmArena::new(8).unwrap();
/// let id = arena.push(&[1.0; 8]).unwrap();
/// assert_eq!(arena.get(id).unwrap(), &[1.0; 8]);
/// ```
pub struct ApmArena {
    store: Arc<Store>,
    /// The store mapping covering every slot this snapshot references.
    map: Arc<Mapping>,
    /// Bytes of payload per entry (f32 count × 4).
    entry_bytes: usize,
    /// id → (physical slot, tenancy epoch at store time); `None` once
    /// evicted. Chunked copy-on-write: an admission clones only the
    /// chunks it touches.
    slots: SlotTable,
    /// Per-physical-slot *live* tenancy epoch, bumped when a slot is
    /// claimed by its next tenant. Shared (atomics) across every snapshot
    /// of the lineage — see the module docs on tenancy epochs.
    epochs: EpochTable,
    /// Physical slots freed by eviction, available for reuse.
    free: Vec<u32>,
    /// Slots freed while `defer_free` is on: dead, but not reusable until
    /// the owner proves no concurrent snapshot can still read them
    /// ([`ApmArena::take_pending_free`] / [`ApmArena::release_slots`]).
    pending_free: Vec<u32>,
    /// Route `remove`d slots through `pending_free` instead of `free`.
    defer_free: bool,
    /// Live entries (`slots` entries that are `Some`).
    live: usize,
    /// Arena generation: bumped by the owner (`LayerDb::compact`) whenever
    /// the id space is renumbered, so pre-compaction epoch stamps can never
    /// validate against the rebuilt arena.
    generation: u32,
}

const GROW_CHUNK: usize = 256; // entries added per ftruncate

impl ApmArena {
    /// Create an arena for entries of `elems` f32 values each, backed by
    /// an anonymous in-memory file (`memfd_create`) — the hot tier's
    /// store.
    pub fn new(elems: usize) -> Result<Self> {
        if elems == 0 {
            return Err(Error::memo("arena entry size must be positive"));
        }
        let fd = unsafe {
            libc::memfd_create(b"attmemo-apm\0".as_ptr().cast(), 0)
        };
        if fd < 0 {
            return Err(Error::Io(std::io::Error::last_os_error()));
        }
        Self::with_fd(elems, fd)
    }

    /// Create an arena backed by a regular file at `path` (created or
    /// truncated) — the cold tier's spill store (`memo/cold.rs`). The
    /// same page-aligned stride, growth (`ftruncate` + fresh
    /// `MAP_SHARED` mapping) and slot/epoch discipline as the memfd
    /// store apply unchanged; entries start on page boundaries, so the
    /// layout stays `O_DIRECT`-friendly for tooling that bypasses the
    /// page cache.
    pub fn new_file_backed(elems: usize,
                           path: &std::path::Path) -> Result<Self> {
        if elems == 0 {
            return Err(Error::memo("arena entry size must be positive"));
        }
        use std::os::unix::ffi::OsStrExt;
        let cpath = std::ffi::CString::new(path.as_os_str().as_bytes())
            .map_err(|_| Error::memo("arena path contains a NUL byte"))?;
        let fd = unsafe {
            libc::open(
                cpath.as_ptr(),
                libc::O_RDWR | libc::O_CREAT | libc::O_TRUNC,
                0o644,
            )
        };
        if fd < 0 {
            return Err(Error::Io(std::io::Error::last_os_error()));
        }
        Self::with_fd(elems, fd)
    }

    /// Shared constructor tail: wrap an owned, freshly created fd (memfd
    /// or regular file, zero-length either way) into a [`Store`] and
    /// pre-grow the first slot chunk. Takes ownership of `fd` — it is
    /// closed when the store drops, including on a growth error here.
    fn with_fd(elems: usize, fd: RawFd) -> Result<Self> {
        let entry_bytes = elems * 4;
        let stride = page_align(entry_bytes);
        let store = Store {
            fd,
            stride,
            grow: Mutex::new(GrowState {
                cap: 0,
                phys_used: 0,
                map: Arc::new(Mapping::empty()),
            }),
            resident: AtomicUsize::new(0),
        };
        let map = {
            let mut g = store.grow.lock().unwrap();
            grow_store(&store, &mut g, GROW_CHUNK)?;
            g.map.clone()
        };
        Ok(ApmArena {
            store: Arc::new(store),
            map,
            entry_bytes,
            slots: SlotTable::default(),
            epochs: EpochTable::default(),
            free: Vec::new(),
            pending_free: Vec::new(),
            defer_free: false,
            live: 0,
            generation: 0,
        })
    }

    /// Cheap snapshot copy for the copy-on-write tier: the chunked
    /// id→slot table shares its chunks until a mutation touches them
    /// (O(chunks) `Arc` copies here, O(touched chunks) per admission),
    /// the live tenancy-epoch counters and the backing store (memfd,
    /// mappings, payload bytes) are shared outright.
    pub(crate) fn cow_clone(&self) -> ApmArena {
        ApmArena {
            store: Arc::clone(&self.store),
            map: Arc::clone(&self.map),
            entry_bytes: self.entry_bytes,
            slots: self.slots.clone(),
            epochs: self.epochs.clone(),
            free: self.free.clone(),
            pending_free: self.pending_free.clone(),
            defer_free: self.defer_free,
            live: self.live,
            generation: self.generation,
        }
    }

    /// Opaque identity of this arena's backing store (see
    /// [`StoreHandle`]). The handle keeps the store alive, so the
    /// identity can never be recycled onto a different memfd (no ABA).
    pub(crate) fn store_handle(&self) -> StoreHandle {
        StoreHandle(Arc::clone(&self.store))
    }

    /// Whether this arena still lives on the store `h` identifies (false
    /// across a compaction, which rebuilds onto a new store).
    pub(crate) fn is_on_store(&self, h: &StoreHandle) -> bool {
        Arc::ptr_eq(&self.store, &h.0)
    }

    /// Switch `remove` between immediate slot reuse (single-threaded
    /// owners: offline builds, benches) and deferred reclamation (the
    /// concurrent tier, which recycles slots only after snapshot
    /// quiescence).
    pub(crate) fn set_defer_free(&mut self, on: bool) {
        self.defer_free = on;
    }

    /// Whether removals defer slot reuse (see
    /// [`ApmArena::set_defer_free`]).
    pub(crate) fn defer_free(&self) -> bool {
        self.defer_free
    }

    /// Drain the slots freed since the last call (deferred mode). The
    /// caller owns proving quiescence before feeding them back through
    /// [`ApmArena::release_slots`].
    pub(crate) fn take_pending_free(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.pending_free)
    }

    /// Return quiesced slots to the free list for reuse by later pushes.
    pub(crate) fn release_slots(&mut self, slots: Vec<u32>) {
        self.free.extend(slots);
    }

    /// Whether gathered batches are usable as one contiguous f32 tensor
    /// (true iff the payload exactly fills its pages; holds for all
    /// serving shapes — e.g. 4·128·128·4 B = 64 pages).
    pub fn dense_mappable(&self) -> bool {
        self.entry_bytes == self.store.stride
    }

    /// Bytes of payload per entry.
    pub fn entry_bytes(&self) -> usize {
        self.entry_bytes
    }

    /// f32 values per entry.
    pub fn entry_elems(&self) -> usize {
        self.entry_bytes / 4
    }

    /// Page-aligned byte stride between entries in the file.
    pub fn stride(&self) -> usize {
        self.store.stride
    }

    /// Arena generation (see [`ApmArena::epoch`]); bumped when the id space
    /// is renumbered by a compaction.
    pub fn generation(&self) -> u32 {
        self.generation
    }

    /// Stamp the arena with a generation. Used by compaction to mark the
    /// rebuilt arena as a different id universe than its predecessor.
    pub(crate) fn set_generation(&mut self, generation: u32) {
        self.generation = generation;
    }

    /// Epoch stamp of a live entry: encodes the arena generation and the
    /// tenancy epoch the entry was stored under. A stamp taken at lookup
    /// time and passed back to [`ApmArena::get_checked`] guarantees the
    /// bytes read belong to the *same tenant* the lookup matched — a
    /// concurrent eviction whose slot was recycled (or a compaction that
    /// renumbers ids) invalidates the stamp instead of silently serving
    /// stale or foreign bytes. Errors on dead/unknown ids.
    pub fn epoch(&self, id: ApmId) -> Result<u64> {
        match self.slots.get(id.0 as usize) {
            Some(Some(r)) => {
                Ok(((self.generation as u64) << 32) | r.epoch as u64)
            }
            Some(None) => {
                Err(Error::memo(format!("ApmId {} was evicted", id.0)))
            }
            None => Err(Error::memo(format!(
                "ApmId {} out of range {}",
                id.0,
                self.slots.len()
            ))),
        }
    }

    /// Does `stamp` still identify the entry's current tenancy? True only
    /// when the id is live in this snapshot, the stamp matches the epoch
    /// the entry was stored under, *and* the slot's shared live epoch
    /// agrees — i.e. no later lineage writer has recycled the slot for a
    /// new tenant (a merely-evicted, not-yet-reclaimed entry still
    /// validates: frozen snapshots keep serving it).
    fn stamp_valid(&self, id: ApmId, stamp: u64) -> bool {
        match self.slots.get(id.0 as usize) {
            Some(Some(r)) => {
                (((self.generation as u64) << 32) | r.epoch as u64) == stamp
                    && self.epochs.load(r.slot) == r.epoch
            }
            _ => false,
        }
    }

    /// Read-only view of one entry, validated against an epoch stamp taken
    /// when the entry was looked up (see [`ApmArena::epoch`]). Errors if
    /// the id has died, its slot was recycled for a new tenant, or the
    /// arena was compacted since the stamp — never returns another
    /// tenant's bytes. The returned slice is a *plain* view: it is only
    /// safe to read while no lineage writer can overwrite the slot (the
    /// writer mutex is held, or the arena is exclusively owned). Readers
    /// racing live writers must copy through [`ApmArena::copy_checked`]
    /// instead, which goes through word-sized atomics.
    pub fn get_checked(&self, id: ApmId, epoch: u64) -> Result<&[f32]> {
        if !self.stamp_valid(id, epoch) {
            return Err(Error::memo(format!(
                "ApmId {} is stale: slot reused or arena compacted since \
                 lookup",
                id.0
            )));
        }
        self.get(id)
    }

    /// Post-copy stamp revalidation (the seqlock read discipline): after
    /// copying bytes obtained through [`ApmArena::get_checked`], confirm
    /// the slot's tenancy did not change mid-copy. The `Acquire` fence
    /// orders the copy's reads before the epoch reload, pairing with the
    /// `AcqRel` claim bump a reclaiming writer performs *before* it
    /// overwrites the slot.
    pub fn recheck(&self, id: ApmId, epoch: u64) -> bool {
        std::sync::atomic::fence(Ordering::Acquire);
        self.stamp_valid(id, epoch)
    }

    /// Optimistic cross-thread copy of one entry into `dst`, validated
    /// against an epoch stamp taken at lookup time. This is the reader
    /// half of the seqlock-over-mmap discipline: the payload words are
    /// read through word-sized `Relaxed` atomic loads (pairing with the
    /// atomic stores in [`ApmArena::push`]), so racing a forced slot
    /// reclaim is well-defined rather than UB and ThreadSanitizer accepts
    /// it. A pre-copy stamp check rejects already-stale ids; callers must
    /// still confirm the copy with [`ApmArena::recheck`] afterwards to
    /// discard a torn copy from a reclaim that landed mid-read. Errors on
    /// stale stamps and on `dst` length mismatches; on error `dst`'s
    /// contents are unspecified.
    pub fn copy_checked(
        &self,
        id: ApmId,
        epoch: u64,
        dst: &mut [f32],
    ) -> Result<()> {
        if dst.len() * 4 != self.entry_bytes {
            return Err(Error::memo(format!(
                "arena copy: want {} f32, got {}",
                self.entry_bytes / 4,
                dst.len()
            )));
        }
        if !self.stamp_valid(id, epoch) {
            return Err(Error::memo(format!(
                "ApmId {} is stale: slot reused or arena compacted since \
                 lookup",
                id.0
            )));
        }
        let off = self.file_offset(id)?;
        unsafe {
            load_entry_words(self.map.base.add(off), dst);
        }
        Ok(())
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.live
    }

    /// Whether no entries are live.
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Upper bound of the id space: ids in `[0, next_id)` have been issued
    /// (some may since have been removed).
    pub fn next_id(&self) -> u32 {
        self.slots.len() as u32
    }

    /// Is `id` currently stored?
    pub fn is_live(&self, id: ApmId) -> bool {
        self.slots
            .get(id.0 as usize)
            .map_or(false, |s| s.is_some())
    }

    /// Ids of all live entries, ascending.
    pub fn live_ids(&self) -> Vec<ApmId> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.map(|_| ApmId(i as u32)))
            .collect()
    }

    /// Number of entries per chunk of the id table (the copy-on-write
    /// clone granularity; exposed for tests and sizing docs).
    pub fn table_chunk() -> usize {
        TABLE_CHUNK
    }

    pub(crate) fn fd(&self) -> RawFd {
        self.store.fd
    }

    /// Total bytes resident in the store (capacity × stride). Lock-free:
    /// reads the store's atomic gauge.
    pub fn resident_bytes(&self) -> usize {
        self.store.resident.load(Ordering::Relaxed)
    }

    /// Byte offset of an entry inside the file (for gather mappings).
    pub(crate) fn file_offset(&self, id: ApmId) -> Result<usize> {
        match self.slots.get(id.0 as usize) {
            Some(Some(r)) => Ok(r.slot as usize * self.store.stride),
            Some(None) => {
                Err(Error::memo(format!("ApmId {} was evicted", id.0)))
            }
            None => Err(Error::memo(format!(
                "ApmId {} out of range {}",
                id.0,
                self.slots.len()
            ))),
        }
    }

    /// Hand out a never-used physical slot, extending the file (and
    /// refreshing this snapshot's mapping) when the high-water mark hits
    /// the current capacity.
    fn alloc_fresh_slot(&mut self) -> Result<u32> {
        let mut g = self.store.grow.lock().unwrap();
        if g.phys_used == g.cap {
            let extra = GROW_CHUNK.max(g.cap / 2);
            grow_store(&self.store, &mut g, extra)?;
        }
        let s = g.phys_used as u32;
        g.phys_used += 1;
        // The writer's view must cover the slot it is about to fill; old
        // snapshots keep their own (older, smaller) mapping.
        self.map = g.map.clone();
        Ok(s)
    }

    /// Store one entry — into a freed slot when available, appending
    /// otherwise; returns its (fresh) id.
    pub fn push(&mut self, data: &[f32]) -> Result<ApmId> {
        if data.len() * 4 != self.entry_bytes {
            return Err(Error::memo(format!(
                "arena push: want {} f32, got {}",
                self.entry_bytes / 4,
                data.len()
            )));
        }
        let (slot, reused) = match self.free.pop() {
            Some(s) => (s, true),
            None => (self.alloc_fresh_slot()?, false),
        };
        self.epochs.ensure(slot as usize);
        // A recycled slot gets a fresh tenancy epoch *before* its bytes
        // are overwritten: stamps against the previous tenant stop
        // validating first, so a stalled reader racing a forced reclaim
        // fails its (pre- or post-copy) stamp check instead of returning
        // this tenant's bytes. Fresh slots never had a tenant — no stamp
        // can exist, epoch 0 stands.
        let epoch = if reused {
            self.epochs.claim(slot)
        } else {
            self.epochs.load(slot)
        };
        let off = slot as usize * self.store.stride;
        // Payload bytes land through word-sized `Relaxed` atomic stores:
        // an optimistic reader racing a forced reclaim may be copying the
        // old tenant out of this slot concurrently (`copy_checked`), and
        // word atomics make that deliberate race well-defined instead of
        // UB — the reader's post-copy `recheck` discards the torn copy.
        // Ordering is carried by the epoch claim above (`AcqRel`) and the
        // reader's `Acquire` fence, not by these stores; on x86-64 a
        // relaxed atomic store compiles to the same plain `mov`.
        unsafe {
            store_entry_words(self.map.base.add(off), data);
        }
        self.slots.push(Some(SlotRef { slot, epoch }));
        self.live += 1;
        Ok(ApmId((self.slots.len() - 1) as u32))
    }

    /// Evict an entry: its id goes dead and its physical slot becomes
    /// reusable by a later `push` — immediately, or (in deferred mode)
    /// once the owner releases it after snapshot quiescence.
    pub fn remove(&mut self, id: ApmId) -> Result<()> {
        let i = id.0 as usize;
        if i >= self.slots.len() {
            return Err(Error::memo(format!(
                "ApmId {} out of range {}",
                id.0,
                self.slots.len()
            )));
        }
        match self.slots.get(i).flatten() {
            Some(r) => {
                // The id dies now; the slot's *live* epoch is bumped only
                // when the next tenant claims it (`push`), so frozen
                // snapshots that still map this id keep validating stamps
                // — and keep serving the intact bytes — until the slot is
                // actually recycled.
                self.slots.set(i, None);
                if self.defer_free {
                    self.pending_free.push(r.slot);
                } else {
                    self.free.push(r.slot);
                }
                self.live -= 1;
                Ok(())
            }
            None => {
                Err(Error::memo(format!("ApmId {} already evicted", id.0)))
            }
        }
    }

    /// Read-only view of one entry.
    pub fn get(&self, id: ApmId) -> Result<&[f32]> {
        let off = self.file_offset(id)?;
        unsafe {
            Ok(std::slice::from_raw_parts(
                self.map.base.add(off).cast::<f32>(),
                self.entry_bytes / 4,
            ))
        }
    }
}

/// Write `src` into the slot at `dst` through word-sized `Relaxed` atomic
/// stores: 8-byte words for the bulk, one 4-byte word for an odd tail
/// element. Byte layout is identical to a plain `memcpy` of the `f32`s
/// (words are read out of `src` in memory order), so single-threaded
/// plain readers ([`ApmArena::get`]) see the same bytes.
///
/// # Safety
/// `dst` must be valid for `src.len() * 4` bytes of writes and 8-byte
/// aligned. Slot offsets satisfy this: the mmap base is page-aligned and
/// the per-slot stride is a page multiple.
unsafe fn store_entry_words(dst: *mut u8, src: &[f32]) {
    let pairs = src.len() / 2;
    let d64 = dst.cast::<AtomicU64>();
    for p in 0..pairs {
        // Unaligned read: `src` is only guaranteed 4-byte aligned.
        let w = std::ptr::read_unaligned(
            src.as_ptr().add(2 * p).cast::<u64>(),
        );
        (*d64.add(p)).store(w, Ordering::Relaxed);
    }
    if src.len() % 2 == 1 {
        let d32 = dst.add(pairs * 8).cast::<AtomicU32>();
        (*d32).store(src[src.len() - 1].to_bits(), Ordering::Relaxed);
    }
}

/// Read one slot's payload into `dst` through word-sized `Relaxed` atomic
/// loads — the counterpart of [`store_entry_words`]. The copy may be torn
/// when it races a reclaiming writer; callers detect that through the
/// post-copy epoch recheck, never by inspecting the bytes.
///
/// # Safety
/// `src` must be valid for `dst.len() * 4` bytes of reads and 8-byte
/// aligned (see [`store_entry_words`]).
unsafe fn load_entry_words(src: *const u8, dst: &mut [f32]) {
    let pairs = dst.len() / 2;
    let s64 = src.cast::<AtomicU64>();
    for p in 0..pairs {
        let w = (*s64.add(p)).load(Ordering::Relaxed);
        std::ptr::write_unaligned(
            dst.as_mut_ptr().add(2 * p).cast::<u64>(),
            w,
        );
    }
    if dst.len() % 2 == 1 {
        let s32 = src.add(pairs * 8).cast::<AtomicU32>();
        let n = dst.len();
        dst[n - 1] = f32::from_bits((*s32).load(Ordering::Relaxed));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_alignment() {
        let p = page_size();
        assert!(p >= 4096);
        assert_eq!(page_align(1), p);
        assert_eq!(page_align(p), p);
        assert_eq!(page_align(p + 1), 2 * p);
    }

    #[test]
    fn push_get_roundtrip() {
        let mut a = ApmArena::new(16).unwrap();
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..16).map(|i| -(i as f32)).collect();
        let ix = a.push(&x).unwrap();
        let iy = a.push(&y).unwrap();
        assert_eq!(a.get(ix).unwrap(), &x[..]);
        assert_eq!(a.get(iy).unwrap(), &y[..]);
        assert_eq!(a.len(), 2);
        assert!(a.get(ApmId(2)).is_err());
    }

    #[test]
    fn wrong_size_push_rejected() {
        let mut a = ApmArena::new(16).unwrap();
        assert!(a.push(&[0.0; 8]).is_err());
    }

    #[test]
    fn copy_checked_roundtrip_and_odd_tail() {
        // Odd element count exercises the 4-byte tail word.
        let mut a = ApmArena::new(17).unwrap();
        let x: Vec<f32> = (0..17).map(|i| i as f32 * 0.5 - 3.0).collect();
        let id = a.push(&x).unwrap();
        let stamp = a.epoch(id).unwrap();
        let mut dst = vec![0.0f32; 17];
        a.copy_checked(id, stamp, &mut dst).unwrap();
        assert_eq!(dst, x);
        assert!(a.recheck(id, stamp));
        // Wrong-size destination and stale stamps are rejected.
        assert!(a.copy_checked(id, stamp, &mut [0.0; 8]).is_err());
        a.remove(id).unwrap();
        let _ = a.push(&x).unwrap(); // recycles the slot, bumps its epoch
        assert!(a.copy_checked(id, stamp, &mut dst).is_err());
    }

    #[test]
    fn growth_preserves_data() {
        let elems = 32;
        let mut a = ApmArena::new(elems).unwrap();
        let n = GROW_CHUNK * 2 + 7; // force at least two grows
        for i in 0..n {
            let v = vec![i as f32; elems];
            a.push(&v).unwrap();
        }
        for i in (0..n).step_by(97) {
            assert_eq!(a.get(ApmId(i as u32)).unwrap()[0], i as f32);
        }
        assert_eq!(a.len(), n);
    }

    #[test]
    fn dense_mappable_when_entry_fills_pages() {
        let page_elems = page_size() / 4;
        assert!(ApmArena::new(page_elems).unwrap().dense_mappable());
        assert!(!ApmArena::new(page_elems - 1).unwrap().dense_mappable());
    }

    #[test]
    fn remove_kills_id_and_reuses_slot() {
        let mut a = ApmArena::new(8).unwrap();
        let i0 = a.push(&[0.0; 8]).unwrap();
        let i1 = a.push(&[1.0; 8]).unwrap();
        a.remove(i0).unwrap();
        assert_eq!(a.len(), 1);
        assert!(!a.is_live(i0));
        assert!(a.get(i0).is_err());
        assert!(a.remove(i0).is_err());
        // The freed physical slot is reused: same file offset, fresh id.
        let off0 = 0; // i0 was the first physical slot
        let i2 = a.push(&[2.0; 8]).unwrap();
        assert_eq!(i2, ApmId(2), "ids stay monotonic");
        assert_eq!(a.file_offset(i2).unwrap(), off0, "slot reused");
        assert_eq!(a.get(i2).unwrap(), &[2.0; 8]);
        assert_eq!(a.get(i1).unwrap(), &[1.0; 8], "live entry untouched");
        assert_eq!(a.live_ids(), vec![i1, i2]);
        assert_eq!(a.next_id(), 3);
    }

    #[test]
    fn epoch_invalidates_reused_slot() {
        let mut a = ApmArena::new(8).unwrap();
        let i0 = a.push(&[0.0; 8]).unwrap();
        let e0 = a.epoch(i0).unwrap();
        assert_eq!(a.get_checked(i0, e0).unwrap(), &[0.0; 8]);
        a.remove(i0).unwrap();
        // Same physical slot, new tenant: the old stamp must not validate.
        let i1 = a.push(&[1.0; 8]).unwrap();
        assert_eq!(a.file_offset(i1).unwrap(), 0, "slot reused");
        assert!(a.epoch(i0).is_err(), "dead id has no epoch");
        assert!(a.get_checked(i0, e0).is_err());
        let e1 = a.epoch(i1).unwrap();
        assert_ne!(e1, e0, "reused slot must change epoch");
        assert!(a.get_checked(i1, e0).is_err(), "stale stamp rejected");
        assert_eq!(a.get_checked(i1, e1).unwrap(), &[1.0; 8]);
    }

    #[test]
    fn generation_invalidates_old_stamps() {
        let mut a = ApmArena::new(4).unwrap();
        let id = a.push(&[7.0; 4]).unwrap();
        let stamp = a.epoch(id).unwrap();
        a.set_generation(a.generation() + 1);
        assert!(a.get_checked(id, stamp).is_err(),
                "stamps from another generation must not validate");
        assert_eq!(a.get_checked(id, a.epoch(id).unwrap()).unwrap(),
                   &[7.0; 4]);
    }

    #[test]
    fn bounded_slot_reuse_never_grows_file() {
        let mut a = ApmArena::new(4).unwrap();
        let mut id = a.push(&[0.0; 4]).unwrap();
        let bytes = a.resident_bytes();
        for i in 0..2 * GROW_CHUNK {
            a.remove(id).unwrap();
            id = a.push(&[i as f32; 4]).unwrap();
        }
        assert_eq!(a.resident_bytes(), bytes, "churn must not grow the file");
        assert_eq!(a.len(), 1);
    }

    /// The seqlock tier's slot discipline: a deferred-mode removal must
    /// not hand the slot to the next push (a frozen snapshot could still
    /// be reading it); release after quiescence recycles it.
    #[test]
    fn deferred_free_recycles_only_after_release() {
        let mut a = ApmArena::new(8).unwrap();
        a.set_defer_free(true);
        assert!(a.defer_free());
        let i0 = a.push(&[0.0; 8]).unwrap();
        let off0 = a.file_offset(i0).unwrap();
        a.remove(i0).unwrap();
        let i1 = a.push(&[1.0; 8]).unwrap();
        assert_ne!(a.file_offset(i1).unwrap(), off0,
                   "a pending slot must not be reused before release");
        let pending = a.take_pending_free();
        assert_eq!(pending, vec![0]);
        assert!(a.take_pending_free().is_empty(), "drain is one-shot");
        a.release_slots(pending);
        let i2 = a.push(&[2.0; 8]).unwrap();
        assert_eq!(a.file_offset(i2).unwrap(), off0,
                   "a released slot recycles");
        assert_eq!(a.get(i2).unwrap(), &[2.0; 8]);
    }

    /// Copy-on-write: mutating the writer's copy leaves a snapshot's view
    /// (table *and* payload bytes) intact — the no-torn-reads property the
    /// seqlock tier is built on.
    #[test]
    fn cow_clone_shares_store_and_isolates_tables() {
        let mut a = ApmArena::new(8).unwrap();
        a.set_defer_free(true);
        let i0 = a.push(&[3.0; 8]).unwrap();
        let snap = a.cow_clone();
        assert!(snap.is_on_store(&a.store_handle()));
        a.remove(i0).unwrap();
        let i1 = a.push(&[4.0; 8]).unwrap(); // deferred free ⇒ fresh slot
        assert!(!a.is_live(i0));
        assert!(snap.is_live(i0), "snapshot view must be frozen");
        assert_eq!(snap.get(i0).unwrap(), &[3.0; 8],
                   "snapshot bytes overwritten under a frozen view");
        assert_eq!(a.get(i1).unwrap(), &[4.0; 8]);
    }

    /// The cold tier's store variant: a file-backed arena behaves like
    /// the memfd one and its payload bytes land in the real file at
    /// slot × stride (the cold recovery path reads them back there).
    #[test]
    fn file_backed_store_roundtrips_and_lands_in_file() {
        let dir = std::env::temp_dir().join("attmemo_arena_file");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cold.apm");
        let mut a = ApmArena::new_file_backed(8, &path).unwrap();
        let i0 = a.push(&[3.0; 8]).unwrap();
        let i1 = a.push(&[4.0; 8]).unwrap();
        assert_eq!(a.get(i0).unwrap(), &[3.0; 8]);
        assert_eq!(a.get(i1).unwrap(), &[4.0; 8]);
        assert_eq!(a.stride() % page_size(), 0, "O_DIRECT-friendly stride");
        let stride = a.stride();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.len() >= 2 * stride);
        for (slot, want) in [(0usize, 3.0f32), (1, 4.0)] {
            let b: [u8; 4] = bytes[slot * stride..slot * stride + 4]
                .try_into()
                .unwrap();
            assert_eq!(f32::from_le_bytes(b), want,
                       "slot {slot} bytes must be visible in the file");
        }
        drop(a);
        // Reopening truncates: the constructor hands back a fresh store
        // (recovery replays the index log before recreating the file).
        let a2 = ApmArena::new_file_backed(8, &path).unwrap();
        assert_eq!(a2.len(), 0);
        assert!(ApmArena::new_file_backed(0, &path).is_err());
    }

    /// Growth installs a new mapping; snapshots pin the old one, so their
    /// pointers stay valid across any number of regrows.
    #[test]
    fn snapshot_survives_store_growth_remap() {
        let mut a = ApmArena::new(8).unwrap();
        let i0 = a.push(&[7.0; 8]).unwrap();
        let snap = a.cow_clone();
        for i in 0..2 * GROW_CHUNK {
            a.push(&[i as f32; 8]).unwrap();
        }
        assert_eq!(snap.get(i0).unwrap(), &[7.0; 8],
                   "old mapping must stay valid after regrowth");
        assert_eq!(a.get(i0).unwrap(), &[7.0; 8]);
    }
}
