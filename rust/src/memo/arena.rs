//! Page-aligned APM arena backed by an in-memory file (`memfd_create`).
//!
//! This is the substrate for the paper's memory-mapping trick (§5.3,
//! Fig. 9): every APM is stored page-aligned inside one shared memory file,
//! so a *batch* of scattered APMs can be gathered into a contiguous virtual
//! tensor by mapping their pages back-to-back (`gather.rs`) instead of
//! copying them. The arena is the "attention database memory" — on the
//! paper's testbed it would live in Optane; here it is anonymous shared
//! memory with the tier's latency modelled separately (`memtier`).
//!
//! Entries are addressed by a stable, monotonically assigned [`ApmId`];
//! ids map to *physical page slots* through an indirection table so that
//! serve-time eviction ([`ApmArena::remove`]) frees a slot for reuse by a
//! later admission instead of growing the file forever. A removed id stays
//! dead: `get`/`file_offset` on it error, and its slot's next tenant gets a
//! fresh id.

use std::os::fd::RawFd;
use std::sync::OnceLock;

use crate::{Error, Result};

/// System page size (4096 on this platform; queried once).
pub fn page_size() -> usize {
    static PAGE: OnceLock<usize> = OnceLock::new();
    *PAGE.get_or_init(|| unsafe { libc::sysconf(libc::_SC_PAGESIZE) as usize })
}

/// Round `n` up to a page multiple.
pub fn page_align(n: usize) -> usize {
    let p = page_size();
    (n + p - 1) / p * p
}

/// Identifier of one stored APM entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ApmId(pub u32);

/// Fixed-stride, page-aligned entry store on a memfd with slot reuse.
pub struct ApmArena {
    fd: RawFd,
    /// Bytes of payload per entry (f32 count × 4).
    entry_bytes: usize,
    /// Page-aligned stride between entries.
    stride: usize,
    /// id → physical slot; `None` once evicted.
    slots: Vec<Option<u32>>,
    /// Physical slots freed by eviction, available for reuse.
    free: Vec<u32>,
    /// Live entries (`slots` entries that are `Some`).
    live: usize,
    /// Physical slots ever handed out (high-water mark).
    phys_used: usize,
    /// Physical slots the file currently holds.
    cap: usize,
    /// Persistent read-write mapping of the whole file.
    base: *mut u8,
    map_bytes: usize,
}

// The raw pointer is only dereferenced through &self/&mut self with range
// checks; the underlying memfd pages are valid for the arena's lifetime.
unsafe impl Send for ApmArena {}
unsafe impl Sync for ApmArena {}

const GROW_CHUNK: usize = 256; // entries added per ftruncate

impl ApmArena {
    /// Create an arena for entries of `elems` f32 values each.
    pub fn new(elems: usize) -> Result<Self> {
        if elems == 0 {
            return Err(Error::memo("arena entry size must be positive"));
        }
        let entry_bytes = elems * 4;
        let stride = page_align(entry_bytes);
        let fd = unsafe {
            libc::memfd_create(b"attmemo-apm\0".as_ptr().cast(), 0)
        };
        if fd < 0 {
            return Err(Error::Io(std::io::Error::last_os_error()));
        }
        let mut arena = ApmArena {
            fd,
            entry_bytes,
            stride,
            slots: Vec::new(),
            free: Vec::new(),
            live: 0,
            phys_used: 0,
            cap: 0,
            base: std::ptr::null_mut(),
            map_bytes: 0,
        };
        arena.grow(GROW_CHUNK)?;
        Ok(arena)
    }

    /// Whether gathered batches are usable as one contiguous f32 tensor
    /// (true iff the payload exactly fills its pages; holds for all
    /// serving shapes — e.g. 4·128·128·4 B = 64 pages).
    pub fn dense_mappable(&self) -> bool {
        self.entry_bytes == self.stride
    }

    pub fn entry_bytes(&self) -> usize {
        self.entry_bytes
    }

    pub fn entry_elems(&self) -> usize {
        self.entry_bytes / 4
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Upper bound of the id space: ids in `[0, next_id)` have been issued
    /// (some may since have been removed).
    pub fn next_id(&self) -> u32 {
        self.slots.len() as u32
    }

    /// Is `id` currently stored?
    pub fn is_live(&self, id: ApmId) -> bool {
        self.slots
            .get(id.0 as usize)
            .map_or(false, |s| s.is_some())
    }

    /// Ids of all live entries, ascending.
    pub fn live_ids(&self) -> Vec<ApmId> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.map(|_| ApmId(i as u32)))
            .collect()
    }

    pub(crate) fn fd(&self) -> RawFd {
        self.fd
    }

    /// Total bytes resident in the store (capacity × stride).
    pub fn resident_bytes(&self) -> usize {
        self.cap * self.stride
    }

    /// Byte offset of an entry inside the file (for gather mappings).
    pub(crate) fn file_offset(&self, id: ApmId) -> Result<usize> {
        match self.slots.get(id.0 as usize) {
            Some(Some(slot)) => Ok(*slot as usize * self.stride),
            Some(None) => {
                Err(Error::memo(format!("ApmId {} was evicted", id.0)))
            }
            None => Err(Error::memo(format!(
                "ApmId {} out of range {}",
                id.0,
                self.slots.len()
            ))),
        }
    }

    fn grow(&mut self, extra: usize) -> Result<()> {
        let new_cap = self.cap + extra;
        let bytes = new_cap * self.stride;
        if unsafe { libc::ftruncate(self.fd, bytes as libc::off_t) } != 0 {
            return Err(Error::Io(std::io::Error::last_os_error()));
        }
        // Remap the full file read-write.
        if !self.base.is_null() {
            unsafe { libc::munmap(self.base.cast(), self.map_bytes) };
        }
        let base = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                bytes,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                self.fd,
                0,
            )
        };
        if base == libc::MAP_FAILED {
            return Err(Error::Io(std::io::Error::last_os_error()));
        }
        self.base = base.cast();
        self.map_bytes = bytes;
        self.cap = new_cap;
        Ok(())
    }

    /// Store one entry — into a freed slot when available, appending
    /// otherwise; returns its (fresh) id.
    pub fn push(&mut self, data: &[f32]) -> Result<ApmId> {
        if data.len() * 4 != self.entry_bytes {
            return Err(Error::memo(format!(
                "arena push: want {} f32, got {}",
                self.entry_bytes / 4,
                data.len()
            )));
        }
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                if self.phys_used == self.cap {
                    self.grow(GROW_CHUNK.max(self.cap / 2))?;
                }
                let s = self.phys_used as u32;
                self.phys_used += 1;
                s
            }
        };
        let off = slot as usize * self.stride;
        unsafe {
            std::ptr::copy_nonoverlapping(
                data.as_ptr().cast::<u8>(),
                self.base.add(off),
                self.entry_bytes,
            );
        }
        self.slots.push(Some(slot));
        self.live += 1;
        Ok(ApmId((self.slots.len() - 1) as u32))
    }

    /// Evict an entry: its id goes dead and its physical slot becomes
    /// reusable by a later `push`.
    pub fn remove(&mut self, id: ApmId) -> Result<()> {
        let i = id.0 as usize;
        if i >= self.slots.len() {
            return Err(Error::memo(format!(
                "ApmId {} out of range {}",
                id.0,
                self.slots.len()
            )));
        }
        match self.slots[i].take() {
            Some(slot) => {
                self.free.push(slot);
                self.live -= 1;
                Ok(())
            }
            None => {
                Err(Error::memo(format!("ApmId {} already evicted", id.0)))
            }
        }
    }

    /// Read-only view of one entry.
    pub fn get(&self, id: ApmId) -> Result<&[f32]> {
        let off = self.file_offset(id)?;
        unsafe {
            Ok(std::slice::from_raw_parts(
                self.base.add(off).cast::<f32>(),
                self.entry_bytes / 4,
            ))
        }
    }
}

impl Drop for ApmArena {
    fn drop(&mut self) {
        if !self.base.is_null() {
            unsafe { libc::munmap(self.base.cast(), self.map_bytes) };
        }
        unsafe { libc::close(self.fd) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_alignment() {
        let p = page_size();
        assert!(p >= 4096);
        assert_eq!(page_align(1), p);
        assert_eq!(page_align(p), p);
        assert_eq!(page_align(p + 1), 2 * p);
    }

    #[test]
    fn push_get_roundtrip() {
        let mut a = ApmArena::new(16).unwrap();
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..16).map(|i| -(i as f32)).collect();
        let ix = a.push(&x).unwrap();
        let iy = a.push(&y).unwrap();
        assert_eq!(a.get(ix).unwrap(), &x[..]);
        assert_eq!(a.get(iy).unwrap(), &y[..]);
        assert_eq!(a.len(), 2);
        assert!(a.get(ApmId(2)).is_err());
    }

    #[test]
    fn wrong_size_push_rejected() {
        let mut a = ApmArena::new(16).unwrap();
        assert!(a.push(&[0.0; 8]).is_err());
    }

    #[test]
    fn growth_preserves_data() {
        let elems = 32;
        let mut a = ApmArena::new(elems).unwrap();
        let n = GROW_CHUNK * 2 + 7; // force at least two grows
        for i in 0..n {
            let v = vec![i as f32; elems];
            a.push(&v).unwrap();
        }
        for i in (0..n).step_by(97) {
            assert_eq!(a.get(ApmId(i as u32)).unwrap()[0], i as f32);
        }
        assert_eq!(a.len(), n);
    }

    #[test]
    fn dense_mappable_when_entry_fills_pages() {
        let page_elems = page_size() / 4;
        assert!(ApmArena::new(page_elems).unwrap().dense_mappable());
        assert!(!ApmArena::new(page_elems - 1).unwrap().dense_mappable());
    }

    #[test]
    fn remove_kills_id_and_reuses_slot() {
        let mut a = ApmArena::new(8).unwrap();
        let i0 = a.push(&[0.0; 8]).unwrap();
        let i1 = a.push(&[1.0; 8]).unwrap();
        a.remove(i0).unwrap();
        assert_eq!(a.len(), 1);
        assert!(!a.is_live(i0));
        assert!(a.get(i0).is_err());
        assert!(a.remove(i0).is_err());
        // The freed physical slot is reused: same file offset, fresh id.
        let off0 = 0; // i0 was the first physical slot
        let i2 = a.push(&[2.0; 8]).unwrap();
        assert_eq!(i2, ApmId(2), "ids stay monotonic");
        assert_eq!(a.file_offset(i2).unwrap(), off0, "slot reused");
        assert_eq!(a.get(i2).unwrap(), &[2.0; 8]);
        assert_eq!(a.get(i1).unwrap(), &[1.0; 8], "live entry untouched");
        assert_eq!(a.live_ids(), vec![i1, i2]);
        assert_eq!(a.next_id(), 3);
    }

    #[test]
    fn bounded_slot_reuse_never_grows_file() {
        let mut a = ApmArena::new(4).unwrap();
        let mut id = a.push(&[0.0; 4]).unwrap();
        let bytes = a.resident_bytes();
        for i in 0..2 * GROW_CHUNK {
            a.remove(id).unwrap();
            id = a.push(&[i as f32; 4]).unwrap();
        }
        assert_eq!(a.resident_bytes(), bytes, "churn must not grow the file");
        assert_eq!(a.len(), 1);
    }
}
