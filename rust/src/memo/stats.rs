//! Memoization accounting: hit/miss/attempt counters per layer, the
//! paper's memoization rate (Eq. 2), and per-stage timing for Table 4.

use crate::util::stats::Summary;

/// Per-stage latency breakdown of a memoized self-attention (paper
/// Table 4 rows).
#[derive(Debug, Default)]
pub struct StageTimes {
    /// Hidden-state embedding (§5.2) per batch-layer.
    pub embedding_ms: Summary,
    /// Index search + online-tier fetch per batch-layer.
    pub search_ms: Summary,
    /// APM batch assembly (mapped or copied) per batch-layer.
    pub mapping_ms: Summary,
    /// Attention-score computation for miss rows per batch-layer.
    pub scores_ms: Summary,
    /// Post-APM remainder of the layer (`attn_apply`) per batch-layer.
    pub apply_ms: Summary,
}

/// Counters for one layer.
#[derive(Debug, Default, Clone)]
pub struct LayerCounters {
    /// Sequences for which memoization was attempted (embedding + search).
    pub attempts: u64,
    /// Attempts whose similarity cleared the threshold (APM reused).
    pub hits: u64,
    /// Sequences that skipped the attempt entirely (selective policy).
    pub skipped: u64,
    /// Sequences whose attempt was rolled back by the padded-batch quorum
    /// (the fused path won; their `attempts`/`hits` were reverted).
    pub reverted: u64,
    /// Sequences processed through this layer in total.
    pub total: u64,
    /// APMs admitted into the online database at serve time.
    pub admitted: u64,
    /// Online-database entries evicted to make room for admissions.
    pub evicted: u64,
    /// Miss rows skipped by intra-batch dedup (a near-identical entry —
    /// often from the same batch — was already stored).
    pub deduped: u64,
    /// Eviction victims demoted into the cold spill tier instead of
    /// dropped (0 without a cold tier; never exceeds `evicted`).
    pub demoted: u64,
}

/// Whole-engine memoization statistics.
#[derive(Debug, Default)]
pub struct MemoStats {
    /// Per-layer counters, indexed by layer.
    pub layers: Vec<LayerCounters>,
    /// Per-stage latency summaries.
    pub stages: StageTimes,
}

impl MemoStats {
    /// Zeroed statistics for `num_layers` layers.
    pub fn new(num_layers: usize) -> Self {
        MemoStats {
            layers: vec![LayerCounters::default(); num_layers],
            stages: StageTimes::default(),
        }
    }

    /// Paper Eq. 2: `ms = M / (N × L)` — successful memoizations over
    /// (sequences × layers).
    pub fn memoization_rate(&self) -> f64 {
        let hits: u64 = self.layers.iter().map(|l| l.hits).sum();
        let n: u64 = self.layers.first().map_or(0, |l| l.total);
        let denom = n * self.layers.len() as u64;
        if denom == 0 {
            0.0
        } else {
            hits as f64 / denom as f64
        }
    }

    /// Per-layer memoization rate (Eq. 2 with L = 1).
    pub fn layer_rate(&self, layer: usize) -> f64 {
        let l = &self.layers[layer];
        if l.total == 0 {
            0.0
        } else {
            l.hits as f64 / l.total as f64
        }
    }

    /// Hit rate among attempted lookups.
    pub fn attempt_hit_rate(&self, layer: usize) -> f64 {
        let l = &self.layers[layer];
        if l.attempts == 0 {
            0.0
        } else {
            l.hits as f64 / l.attempts as f64
        }
    }

    /// Total serve-time admissions across layers.
    pub fn total_admitted(&self) -> u64 {
        self.layers.iter().map(|l| l.admitted).sum()
    }

    /// Total serve-time evictions across layers.
    pub fn total_evicted(&self) -> u64 {
        self.layers.iter().map(|l| l.evicted).sum()
    }

    /// Total intra-batch-dedup skips across layers.
    pub fn total_deduped(&self) -> u64 {
        self.layers.iter().map(|l| l.deduped).sum()
    }

    /// Total cold-tier demotions across layers (0 without a cold tier).
    pub fn total_demoted(&self) -> u64 {
        self.layers.iter().map(|l| l.demoted).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates() {
        let mut s = MemoStats::new(2);
        for l in &mut s.layers {
            l.total = 10;
        }
        s.layers[0].attempts = 10;
        s.layers[0].hits = 5;
        s.layers[1].attempts = 4;
        s.layers[1].hits = 1;
        s.layers[1].skipped = 6;
        assert!((s.memoization_rate() - 6.0 / 20.0).abs() < 1e-12);
        assert!((s.layer_rate(0) - 0.5).abs() < 1e-12);
        assert!((s.attempt_hit_rate(1) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn empty_is_zero() {
        let s = MemoStats::new(0);
        assert_eq!(s.memoization_rate(), 0.0);
        let s2 = MemoStats::new(3);
        assert_eq!(s2.memoization_rate(), 0.0);
    }
}
