//! Hierarchical Navigable Small World graphs (Malkov & Yashunin, 2016),
//! implemented from scratch — the stand-in for the paper's Faiss index.
//!
//! Standard construction: geometric level assignment, greedy descent
//! through upper layers, beam (`ef`) search at each level, bidirectional
//! links pruned to `m` (2·m at level 0) by distance. Search quality /
//! recall is validated against `BruteForceIndex` in property tests and the
//! Fig. 7 bench.

use crate::memo::index::{Hit, VectorIndex};
use crate::tensor::ops::l2_sq;
use crate::util::Pcg32;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Construction/search parameters.
#[derive(Debug, Clone, Copy)]
pub struct HnswParams {
    /// Max links per node per level (level 0 allows 2·m).
    pub m: usize,
    /// Beam width during construction.
    pub ef_construction: usize,
    /// Default beam width during search (override per call available).
    pub ef_search: usize,
    /// RNG seed for level draws (deterministic builds).
    pub seed: u64,
}

impl Default for HnswParams {
    fn default() -> Self {
        HnswParams { m: 16, ef_construction: 100, ef_search: 48, seed: 7 }
    }
}

#[derive(Clone)]
struct Node {
    /// Neighbour lists, one per level (index 0 = ground level).
    links: Vec<Vec<u32>>,
}

/// The index. Vectors are stored in one flat array.
///
/// Deletion is by tombstone (`remove`): the node keeps its vector and its
/// links — so it still *routes* searches through the small world — but it
/// is never returned as a hit and new nodes stop linking to it. This is
/// the standard HNSW delete strategy and what lets the serve-time
/// eviction path retire entries without rebuilding the graph.
///
/// `Clone` duplicates the whole graph (vectors, links, tombstones, RNG
/// state) — the seqlock tier's copy-on-write admission path clones once
/// per admitted *batch*, mutates the copy, and publishes it while frozen
/// snapshots keep serving searches.
#[derive(Clone)]
pub struct Hnsw {
    dim: usize,
    params: HnswParams,
    data: Vec<f32>,
    nodes: Vec<Node>,
    deleted: Vec<bool>,
    live: usize,
    entry: Option<u32>,
    max_level: usize,
    rng: Pcg32,
    level_mult: f64,
}

/// Max-heap entry by distance (for result sets).
#[derive(PartialEq)]
struct Far(f32, u32);
impl Eq for Far {}
impl PartialOrd for Far {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Far {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.partial_cmp(&other.0).unwrap_or(Ordering::Equal)
    }
}

/// Min-heap entry by distance (candidate frontier) via reversed ordering.
#[derive(PartialEq)]
struct Near(f32, u32);
impl Eq for Near {}
impl PartialOrd for Near {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Near {
    fn cmp(&self, other: &Self) -> Ordering {
        other.0.partial_cmp(&self.0).unwrap_or(Ordering::Equal)
    }
}

impl Hnsw {
    /// Empty index over `dim`-dimensional vectors.
    pub fn new(dim: usize, params: HnswParams) -> Self {
        let level_mult = 1.0 / (params.m as f64).ln();
        Hnsw {
            dim,
            params,
            data: Vec::new(),
            nodes: Vec::new(),
            deleted: Vec::new(),
            live: 0,
            entry: None,
            max_level: 0,
            rng: Pcg32::seeded(params.seed),
            level_mult,
        }
    }

    /// Vectors that are still searchable (not tombstoned).
    pub fn live_len(&self) -> usize {
        self.live
    }

    /// Has this id been tombstoned?
    pub fn is_deleted(&self, id: u32) -> bool {
        self.deleted.get(id as usize).copied().unwrap_or(false)
    }

    /// Construction/search parameters the index was built with.
    pub fn params(&self) -> &HnswParams {
        &self.params
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    fn vec(&self, id: u32) -> &[f32] {
        let i = id as usize * self.dim;
        &self.data[i..i + self.dim]
    }

    /// Stored vector by id (persistence / diagnostics).
    pub fn vector(&self, id: u32) -> &[f32] {
        self.vec(id)
    }

    #[inline]
    fn dist(&self, q: &[f32], id: u32) -> f32 {
        l2_sq(q, self.vec(id))
    }

    /// Greedy closest-point descent on one level.
    fn greedy(&self, q: &[f32], start: u32, level: usize) -> u32 {
        let mut cur = start;
        let mut cur_d = self.dist(q, cur);
        loop {
            let mut improved = false;
            for &n in &self.nodes[cur as usize].links[level] {
                let d = self.dist(q, n);
                if d < cur_d {
                    cur = n;
                    cur_d = d;
                    improved = true;
                }
            }
            if !improved {
                return cur;
            }
        }
    }

    /// Beam search on one level; returns up to `ef` closest as a max-heap.
    ///
    /// Tombstoned nodes participate in the frontier (they route) but are
    /// never added to the result set.
    fn search_level(&self, q: &[f32], start: u32, level: usize,
                    ef: usize) -> Vec<Hit> {
        let mut visited = vec![false; self.nodes.len()];
        visited[start as usize] = true;
        let d0 = self.dist(q, start);
        let mut frontier = BinaryHeap::new(); // min-heap
        let mut results: BinaryHeap<Far> = BinaryHeap::new(); // max-heap
        frontier.push(Near(d0, start));
        if !self.deleted[start as usize] {
            results.push(Far(d0, start));
        }
        while let Some(Near(d, c)) = frontier.pop() {
            let worst = results.peek().map_or(f32::INFINITY, |f| f.0);
            if d > worst && results.len() >= ef {
                break;
            }
            for &n in &self.nodes[c as usize].links[level] {
                if visited[n as usize] {
                    continue;
                }
                visited[n as usize] = true;
                let dn = self.dist(q, n);
                let worst = results.peek().map_or(f32::INFINITY, |f| f.0);
                if results.len() < ef || dn < worst {
                    frontier.push(Near(dn, n));
                    if !self.deleted[n as usize] {
                        results.push(Far(dn, n));
                        if results.len() > ef {
                            results.pop();
                        }
                    }
                }
            }
        }
        let mut hits: Vec<Hit> = results
            .into_iter()
            .map(|Far(d, id)| Hit { id, dist_sq: d })
            .collect();
        hits.sort_by(|a, b| a.dist_sq.partial_cmp(&b.dist_sq).unwrap());
        hits
    }

    /// Select up to `m` neighbours (simple nearest selection).
    fn select(&self, hits: &[Hit], m: usize) -> Vec<u32> {
        hits.iter().take(m).map(|h| h.id).collect()
    }

    /// Prune a node's link list back to the cap, keeping the closest.
    fn shrink(&mut self, id: u32, level: usize) {
        let cap = if level == 0 { self.params.m * 2 } else { self.params.m };
        let links = &self.nodes[id as usize].links[level];
        if links.len() <= cap {
            return;
        }
        let base = self.vec(id).to_vec();
        let mut scored: Vec<(f32, u32)> = links
            .iter()
            .map(|&n| (l2_sq(&base, self.vec(n)), n))
            .collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        scored.truncate(cap);
        self.nodes[id as usize].links[level] =
            scored.into_iter().map(|(_, n)| n).collect();
    }

    /// Search with an explicit beam width.
    pub fn search_ef(&self, q: &[f32], k: usize, ef: usize) -> Vec<Hit> {
        let Some(entry) = self.entry else {
            return Vec::new();
        };
        let mut cur = entry;
        for level in (1..=self.max_level).rev() {
            cur = self.greedy(q, cur, level);
        }
        let mut hits = self.search_level(q, cur, 0, ef.max(k));
        hits.truncate(k);
        hits
    }
}

impl VectorIndex for Hnsw {
    fn add(&mut self, v: &[f32]) -> u32 {
        assert_eq!(v.len(), self.dim, "dimension mismatch");
        let id = self.nodes.len() as u32;
        self.data.extend_from_slice(v);
        let level = self.rng.hnsw_level(self.level_mult);
        self.nodes.push(Node { links: vec![Vec::new(); level + 1] });
        self.deleted.push(false);
        self.live += 1;

        let Some(entry) = self.entry else {
            self.entry = Some(id);
            self.max_level = level;
            return id;
        };

        let mut cur = entry;
        for l in ((level + 1)..=self.max_level).rev() {
            cur = self.greedy(v, cur, l);
        }
        for l in (0..=level.min(self.max_level)).rev() {
            let hits = self.search_level(v, cur, l, self.params.ef_construction);
            cur = hits.first().map_or(cur, |h| h.id);
            let mut neighbours = self.select(&hits, if l == 0 {
                self.params.m * 2
            } else {
                self.params.m
            });
            if neighbours.is_empty() {
                // Every beam candidate is tombstoned: bridge through the
                // routing node anyway so the new vector stays reachable.
                neighbours.push(cur);
            }
            for &n in &neighbours {
                self.nodes[id as usize].links[l].push(n);
                self.nodes[n as usize].links[l].push(id);
                self.shrink(n, l);
            }
        }
        if level > self.max_level {
            self.max_level = level;
            self.entry = Some(id);
        }
        id
    }

    fn search(&self, q: &[f32], k: usize) -> Vec<Hit> {
        self.search_ef(q, k, self.params.ef_search)
    }

    fn len(&self) -> usize {
        self.nodes.len()
    }

    fn remove(&mut self, id: u32) -> bool {
        match self.deleted.get_mut(id as usize) {
            Some(d) if !*d => {
                *d = true;
                self.live -= 1;
                true
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memo::index::BruteForceIndex;

    fn random_vecs(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::seeded(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.next_gaussian()).collect())
            .collect()
    }

    #[test]
    fn empty_index_returns_nothing() {
        let idx = Hnsw::new(4, HnswParams::default());
        assert!(idx.search(&[0.0; 4], 3).is_empty());
    }

    #[test]
    fn single_element() {
        let mut idx = Hnsw::new(2, HnswParams::default());
        idx.add(&[1.0, 2.0]);
        let hits = idx.search(&[1.0, 2.0], 1);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].id, 0);
        assert!(hits[0].dist_sq < 1e-9);
    }

    #[test]
    fn exact_match_found() {
        let vecs = random_vecs(200, 16, 1);
        let mut idx = Hnsw::new(16, HnswParams::default());
        for v in &vecs {
            idx.add(v);
        }
        for probe in [0usize, 57, 123, 199] {
            let hits = idx.search(&vecs[probe], 1);
            assert_eq!(hits[0].id, probe as u32, "probe {probe}");
        }
    }

    #[test]
    fn recall_at_10_vs_bruteforce() {
        let dim = 16;
        let vecs = random_vecs(500, dim, 2);
        let mut hnsw = Hnsw::new(dim, HnswParams::default());
        let mut bf = BruteForceIndex::new(dim);
        for v in &vecs {
            hnsw.add(v);
            bf.add(v);
        }
        let queries = random_vecs(50, dim, 3);
        let mut found = 0usize;
        let mut total = 0usize;
        for q in &queries {
            let exact: Vec<u32> =
                bf.search(q, 10).into_iter().map(|h| h.id).collect();
            let approx: Vec<u32> =
                hnsw.search_ef(q, 10, 64).into_iter().map(|h| h.id).collect();
            total += exact.len();
            found += exact.iter().filter(|e| approx.contains(e)).count();
        }
        let recall = found as f64 / total as f64;
        assert!(recall > 0.9, "recall {recall}");
    }

    #[test]
    fn results_sorted_and_unique() {
        let vecs = random_vecs(300, 8, 4);
        let mut idx = Hnsw::new(8, HnswParams::default());
        for v in &vecs {
            idx.add(v);
        }
        let hits = idx.search(&vecs[5], 20);
        for w in hits.windows(2) {
            assert!(w[0].dist_sq <= w[1].dist_sq);
        }
        let mut ids: Vec<u32> = hits.iter().map(|h| h.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), hits.len());
    }

    #[test]
    fn removed_ids_stop_matching_but_keep_routing() {
        let vecs = random_vecs(300, 8, 6);
        let mut idx = Hnsw::new(8, HnswParams::default());
        for v in &vecs {
            idx.add(v);
        }
        // Tombstone every third vector (including, with high likelihood,
        // routing hubs) and verify none of them is ever returned while
        // recall on the survivors stays intact.
        let mut removed = Vec::new();
        for id in (0..300u32).step_by(3) {
            assert!(idx.remove(id));
            removed.push(id);
        }
        assert!(!idx.remove(removed[0]), "double remove must report false");
        assert!(idx.is_deleted(removed[0]));
        assert!(!idx.is_deleted(1));
        assert_eq!(idx.live_len(), 200);
        assert_eq!(idx.len(), 300);
        for probe in [1usize, 50, 100, 250] {
            let hits = idx.search_ef(&vecs[probe], 10, 128);
            assert!(!hits.is_empty());
            for h in &hits {
                assert!(!removed.contains(&h.id), "tombstoned id {}", h.id);
            }
            if probe % 3 != 0 {
                assert_eq!(hits[0].id, probe as u32, "live self-match");
            }
        }
    }

    #[test]
    fn all_removed_returns_nothing() {
        let mut idx = Hnsw::new(4, HnswParams::default());
        for i in 0..10 {
            idx.add(&[i as f32, 0.0, 0.0, 0.0]);
        }
        for id in 0..10 {
            idx.remove(id);
        }
        assert!(idx.search(&[0.0; 4], 3).is_empty());
        // Adding after a full purge works and is findable again.
        let id = idx.add(&[1.0, 2.0, 3.0, 4.0]);
        let hits = idx.search(&[1.0, 2.0, 3.0, 4.0], 1);
        assert_eq!(hits[0].id, id);
    }

    #[test]
    fn deterministic_given_seed() {
        let vecs = random_vecs(100, 8, 5);
        let build = || {
            let mut idx = Hnsw::new(8, HnswParams::default());
            for v in &vecs {
                idx.add(v);
            }
            idx.search(&vecs[0], 5)
        };
        assert_eq!(build(), build());
    }
}
