//! Hierarchical Navigable Small World graphs (Malkov & Yashunin, 2016),
//! implemented from scratch — the stand-in for the paper's Faiss index.
//!
//! Standard construction: geometric level assignment, greedy descent
//! through upper layers, beam (`ef`) search at each level, bidirectional
//! links pruned to `m` (2·m at level 0) by distance. Search quality /
//! recall is validated against `BruteForceIndex` in property tests and the
//! Fig. 7 bench.
//!
//! # Generational storage
//!
//! The graph is stored the same way the arena's id/slot tables are:
//! node records (neighbour lists + tombstone flag) and vector rows live
//! in [`Hnsw::node_chunk`]-sized chunks whose `Arc`s are shared between
//! a snapshot and its [`Clone`]. Within a chunk each node record is
//! itself `Arc`-shared, so a mutation unshares the touched chunk's
//! *pointer array* (cheap) and deep-copies only the node records it
//! actually rewrites. `Clone` is therefore O(chunk pointers), an insert
//! or tombstone is O(nodes touched), and the seqlock tier's
//! copy-on-write publish no longer pays an O(index) graph copy per
//! mixed batch. [`Hnsw::touched_nodes`] counts the deep copies since the
//! clone — the `publish_touched_nodes` bench metric.

use crate::kernels::simd::l2_sq;
use crate::memo::index::{Hit, VectorIndex};
use crate::util::Pcg32;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::Arc;

/// Construction/search parameters.
#[derive(Debug, Clone, Copy)]
pub struct HnswParams {
    /// Max links per node per level (level 0 allows 2·m).
    pub m: usize,
    /// Beam width during construction.
    pub ef_construction: usize,
    /// Default beam width during search (override per call available).
    pub ef_search: usize,
    /// RNG seed for level draws (deterministic builds).
    pub seed: u64,
}

impl Default for HnswParams {
    fn default() -> Self {
        HnswParams { m: 16, ef_construction: 100, ef_search: 48, seed: 7 }
    }
}

/// Nodes per copy-on-write chunk. Matches the arena's table chunking so
/// one admission's index traffic has the same sharing granularity as its
/// slot-table traffic.
const NODE_CHUNK: usize = 256;

/// One node: neighbour lists per level plus the tombstone flag.
/// `Arc`-shared between generations; deep-copied only when rewritten.
#[derive(Clone)]
struct Node {
    /// Neighbour lists, one per level (index 0 = ground level).
    links: Vec<Vec<u32>>,
    deleted: bool,
}

/// A chunk of `Arc`-shared node records. Unsharing a chunk copies the
/// pointer array only — the records stay shared until touched.
#[derive(Clone, Default)]
struct NodeChunk {
    nodes: Vec<Arc<Node>>,
}

/// A chunk of vector rows (append-only; only the tail chunk is ever
/// unshared, when a new vector lands in a chunk a snapshot still holds).
#[derive(Clone, Default)]
struct VecChunk {
    data: Vec<f32>,
}

/// The index. Vectors and node records live in generational chunks (see
/// the module docs).
///
/// Deletion is by tombstone (`remove`): the node keeps its id and its
/// vector, but searches skip it during traversal — it is never returned
/// as a hit, never expanded, and new nodes stop linking to it. Dead
/// neighbour slots are reclaimed incrementally (`shrink` drops them
/// whenever a list is touched) and wholesale by [`Hnsw::compact`].
pub struct Hnsw {
    dim: usize,
    params: HnswParams,
    vec_chunks: Vec<Arc<VecChunk>>,
    node_chunks: Vec<Arc<NodeChunk>>,
    len: usize,
    live: usize,
    entry: Option<u32>,
    max_level: usize,
    rng: Pcg32,
    level_mult: f64,
    /// Node records and vector rows deep-copied since this generation
    /// was cloned (see [`Hnsw::touched_nodes`]).
    touched: u64,
    /// Tombstones added since the last [`Hnsw::compact`] — the
    /// churn-trigger counter. Carried across generational clones (the
    /// clone is the same logical index), reset only by a compact.
    dead_since_compact: u64,
}

impl Clone for Hnsw {
    /// Generational clone: shares every chunk with `self` (O(chunk
    /// pointers), not O(nodes)) and starts its own
    /// [`Hnsw::touched_nodes`] counter at zero. The seqlock tier's
    /// copy-on-write admission path clones once per admitted batch,
    /// mutates the clone, and publishes it while frozen snapshots keep
    /// answering searches from their own generation.
    fn clone(&self) -> Self {
        Hnsw {
            dim: self.dim,
            params: self.params,
            vec_chunks: self.vec_chunks.clone(),
            node_chunks: self.node_chunks.clone(),
            len: self.len,
            live: self.live,
            entry: self.entry,
            max_level: self.max_level,
            rng: self.rng.clone(),
            level_mult: self.level_mult,
            touched: 0,
            dead_since_compact: self.dead_since_compact,
        }
    }
}

/// Max-heap entry by distance (for result sets).
#[derive(PartialEq)]
struct Far(f32, u32);
impl Eq for Far {}
impl PartialOrd for Far {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Far {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.partial_cmp(&other.0).unwrap_or(Ordering::Equal)
    }
}

/// Min-heap entry by distance (candidate frontier) via reversed ordering.
#[derive(PartialEq)]
struct Near(f32, u32);
impl Eq for Near {}
impl PartialOrd for Near {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Near {
    fn cmp(&self, other: &Self) -> Ordering {
        other.0.partial_cmp(&self.0).unwrap_or(Ordering::Equal)
    }
}

impl Hnsw {
    /// Empty index over `dim`-dimensional vectors.
    pub fn new(dim: usize, params: HnswParams) -> Self {
        let level_mult = 1.0 / (params.m as f64).ln();
        Hnsw {
            dim,
            params,
            vec_chunks: Vec::new(),
            node_chunks: Vec::new(),
            len: 0,
            live: 0,
            entry: None,
            max_level: 0,
            rng: Pcg32::seeded(params.seed),
            level_mult,
            touched: 0,
            dead_since_compact: 0,
        }
    }

    /// Nodes per copy-on-write chunk (the sharing granularity between
    /// generations; exposed for tests and sizing docs).
    pub fn node_chunk() -> usize {
        NODE_CHUNK
    }

    /// Vectors that are still searchable (not tombstoned).
    pub fn live_len(&self) -> usize {
        self.live
    }

    /// Has this id been tombstoned?
    pub fn is_deleted(&self, id: u32) -> bool {
        (id as usize) < self.len && self.node(id).deleted
    }

    /// Construction/search parameters the index was built with.
    pub fn params(&self) -> &HnswParams {
        &self.params
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Node records and vector rows this generation deep-copied since it
    /// was cloned off its parent: the actual byte cost of the
    /// copy-on-write mutations behind one publish. Chunks merely
    /// unshared at the pointer-array level do not count — only nodes
    /// whose neighbour lists were rewritten and vector rows recopied
    /// into a fresh tail chunk. Stays O(batch), not O(index): the
    /// write-path bench gates on it (`publish_touched_nodes`).
    pub fn touched_nodes(&self) -> u64 {
        self.touched
    }

    /// Deep-copy every shared chunk and node record, as the pre-PR-9
    /// whole-graph clone did. This is the A/B baseline arm of the
    /// write-path bench (`MemoConfig::full_index_clone`); the copies are
    /// counted by [`Hnsw::touched_nodes`] so both arms report through
    /// the same metric.
    pub fn unshare_all(&mut self) {
        for id in 0..self.len as u32 {
            let _ = self.node_mut(id);
        }
        let dim = self.dim.max(1);
        let Hnsw { vec_chunks, touched, .. } = self;
        for c in vec_chunks {
            if Arc::get_mut(c).is_none() {
                *touched += (c.data.len() / dim) as u64;
                *c = Arc::new((**c).clone());
            }
        }
    }

    #[inline]
    fn node(&self, id: u32) -> &Node {
        let i = id as usize;
        &self.node_chunks[i / NODE_CHUNK].nodes[i % NODE_CHUNK]
    }

    /// Mutable access to one node record, unsharing along the way: the
    /// chunk's pointer array is cloned if a snapshot still holds it, and
    /// the record itself is deep-copied (and counted as touched) only if
    /// shared.
    fn node_mut(&mut self, id: u32) -> &mut Node {
        let i = id as usize;
        let Hnsw { node_chunks, touched, .. } = self;
        let chunk = &mut node_chunks[i / NODE_CHUNK];
        if Arc::get_mut(chunk).is_none() {
            *chunk = Arc::new((**chunk).clone());
        }
        let rec = &mut Arc::get_mut(chunk)
            .expect("chunk just unshared")
            .nodes[i % NODE_CHUNK];
        if Arc::get_mut(rec).is_none() {
            *touched += 1;
            *rec = Arc::new((**rec).clone());
        }
        Arc::get_mut(rec).expect("node just unshared")
    }

    /// Append one node (vector row + empty links): extends the tail
    /// chunks, unsharing them first when a snapshot still holds them
    /// (the recopied tail vector rows count as touched).
    fn push_node(&mut self, v: &[f32], levels: usize) {
        if self.len % NODE_CHUNK == 0 {
            self.vec_chunks.push(Arc::new(VecChunk::default()));
            self.node_chunks.push(Arc::new(NodeChunk::default()));
        }
        let dim = self.dim.max(1);
        let Hnsw { vec_chunks, node_chunks, touched, .. } = self;
        let vtail = vec_chunks.last_mut().expect("tail chunk ensured");
        if Arc::get_mut(vtail).is_none() {
            *touched += (vtail.data.len() / dim) as u64;
            *vtail = Arc::new((**vtail).clone());
        }
        Arc::get_mut(vtail)
            .expect("tail just unshared")
            .data
            .extend_from_slice(v);
        let ntail = node_chunks.last_mut().expect("tail chunk ensured");
        if Arc::get_mut(ntail).is_none() {
            *ntail = Arc::new((**ntail).clone());
        }
        Arc::get_mut(ntail).expect("tail just unshared").nodes.push(
            Arc::new(Node { links: vec![Vec::new(); levels], deleted: false }),
        );
        self.len += 1;
        self.live += 1;
    }

    #[inline]
    fn vec(&self, id: u32) -> &[f32] {
        let i = id as usize;
        let off = (i % NODE_CHUNK) * self.dim;
        &self.vec_chunks[i / NODE_CHUNK].data[off..off + self.dim]
    }

    /// Stored vector by id (persistence / diagnostics).
    pub fn vector(&self, id: u32) -> &[f32] {
        self.vec(id)
    }

    #[inline]
    fn dist(&self, q: &[f32], id: u32) -> f32 {
        l2_sq(q, self.vec(id))
    }

    /// Greedy closest-point descent on one level. Tombstoned neighbours
    /// are skipped, so `cur` stays live throughout (the entry point is
    /// kept live by `remove`).
    fn greedy(&self, q: &[f32], start: u32, level: usize) -> u32 {
        let mut cur = start;
        let mut cur_d = self.dist(q, cur);
        loop {
            let mut improved = false;
            for &n in &self.node(cur).links[level] {
                if self.node(n).deleted {
                    continue;
                }
                let d = self.dist(q, n);
                if d < cur_d {
                    cur = n;
                    cur_d = d;
                    improved = true;
                }
            }
            if !improved {
                return cur;
            }
        }
    }

    /// Beam search on one level; returns up to `ef` closest as a max-heap.
    ///
    /// Tombstoned nodes are skipped during candidate expansion: they
    /// neither join the frontier nor the result set, so a churned index
    /// stops paying distance evaluations for dead entries. Connectivity
    /// across removed hubs is restored by `shrink` (drops dead links on
    /// touch) and [`Hnsw::compact`] (bridges through them wholesale).
    fn search_level(&self, q: &[f32], start: u32, level: usize,
                    ef: usize) -> Vec<Hit> {
        let mut visited = vec![false; self.len];
        visited[start as usize] = true;
        let d0 = self.dist(q, start);
        let mut frontier = BinaryHeap::new(); // min-heap
        let mut results: BinaryHeap<Far> = BinaryHeap::new(); // max-heap
        frontier.push(Near(d0, start));
        if !self.node(start).deleted {
            results.push(Far(d0, start));
        }
        while let Some(Near(d, c)) = frontier.pop() {
            let worst = results.peek().map_or(f32::INFINITY, |f| f.0);
            if d > worst && results.len() >= ef {
                break;
            }
            for &n in &self.node(c).links[level] {
                if visited[n as usize] {
                    continue;
                }
                visited[n as usize] = true;
                if self.node(n).deleted {
                    continue;
                }
                let dn = self.dist(q, n);
                let worst = results.peek().map_or(f32::INFINITY, |f| f.0);
                if results.len() < ef || dn < worst {
                    frontier.push(Near(dn, n));
                    results.push(Far(dn, n));
                    if results.len() > ef {
                        results.pop();
                    }
                }
            }
        }
        let mut hits: Vec<Hit> = results
            .into_iter()
            .map(|Far(d, id)| Hit { id, dist_sq: d })
            .collect();
        hits.sort_by(|a, b| a.dist_sq.partial_cmp(&b.dist_sq).unwrap());
        hits
    }

    /// Select up to `m` neighbours (simple nearest selection).
    fn select(&self, hits: &[Hit], m: usize) -> Vec<u32> {
        hits.iter().take(m).map(|h| h.id).collect()
    }

    /// Prune a node's link list: tombstoned neighbours are dropped first
    /// (incremental slot reclamation — every touch of a list frees its
    /// dead entries), then the survivors are capped to the closest.
    fn shrink(&mut self, id: u32, level: usize) {
        let cap = if level == 0 { self.params.m * 2 } else { self.params.m };
        let links = &self.node(id).links[level];
        let has_dead = links.iter().any(|&n| self.node(n).deleted);
        if !has_dead && links.len() <= cap {
            return;
        }
        let mut kept: Vec<u32> = links
            .iter()
            .copied()
            .filter(|&n| !self.node(n).deleted)
            .collect();
        if kept.len() > cap {
            let base = self.vec(id).to_vec();
            let mut scored: Vec<(f32, u32)> = kept
                .iter()
                .map(|&n| (l2_sq(&base, self.vec(n)), n))
                .collect();
            scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            scored.truncate(cap);
            kept = scored.into_iter().map(|(_, n)| n).collect();
        }
        self.node_mut(id).links[level] = kept;
    }

    /// Search with an explicit beam width.
    pub fn search_ef(&self, q: &[f32], k: usize, ef: usize) -> Vec<Hit> {
        let Some(entry) = self.entry else {
            return Vec::new();
        };
        debug_assert!(!self.node(entry).deleted, "entry must stay live");
        let mut cur = entry;
        for level in (1..=self.max_level).rev() {
            cur = self.greedy(q, cur, level);
        }
        let mut hits = self.search_level(q, cur, 0, ef.max(k));
        hits.truncate(k);
        hits
    }

    /// Reclaim tombstoned neighbour slots wholesale: drop every dead id
    /// from every live node's lists — bridging through each dead
    /// neighbour's own live links, so regions stitched together by a
    /// since-removed hub stay reachable — then release the dead nodes'
    /// link storage. Returns the number of dead link slots reclaimed.
    ///
    /// O(index); run it on maintenance boundaries. (The tier's
    /// `LayerDb::compact` rebuilds and renumbers instead, which reclaims
    /// as a side effect; this in-place form keeps ids stable for callers
    /// that hold them.)
    pub fn compact(&mut self) -> usize {
        self.dead_since_compact = 0;
        let mut reclaimed = 0;
        for id in 0..self.len as u32 {
            if self.node(id).deleted {
                continue;
            }
            let levels = self.node(id).links.len();
            for l in 0..levels {
                let any_dead = self.node(id).links[l]
                    .iter()
                    .any(|&n| self.node(n).deleted);
                if !any_dead {
                    continue;
                }
                let links = self.node(id).links[l].clone();
                let mut kept: Vec<u32> = links
                    .iter()
                    .copied()
                    .filter(|&n| !self.node(n).deleted)
                    .collect();
                for &n in &links {
                    if !self.node(n).deleted {
                        continue;
                    }
                    reclaimed += 1;
                    for &b in &self.node(n).links[l] {
                        if b != id
                            && !self.node(b).deleted
                            && !kept.contains(&b)
                        {
                            kept.push(b);
                        }
                    }
                }
                self.node_mut(id).links[l] = kept;
                self.shrink(id, l);
            }
        }
        // Dead nodes stop holding links entirely: their lists are the
        // reclaimed memory, and nothing routes through them any more.
        for id in 0..self.len as u32 {
            if self.node(id).deleted && !self.node(id).links.is_empty() {
                self.node_mut(id).links = Vec::new();
            }
        }
        reclaimed
    }

    /// Re-pick the entry point after the current one was tombstoned:
    /// the highest-level live node (O(n) scan, but only ever paid when
    /// the entry itself is removed). An empty live set clears the entry.
    fn repick_entry(&mut self) {
        let mut best: Option<(usize, u32)> = None;
        for id in 0..self.len as u32 {
            let n = self.node(id);
            if n.deleted {
                continue;
            }
            let lv = n.links.len().saturating_sub(1);
            if best.map_or(true, |(bl, _)| lv > bl) {
                best = Some((lv, id));
            }
        }
        match best {
            Some((lv, id)) => {
                self.entry = Some(id);
                self.max_level = lv;
            }
            None => {
                self.entry = None;
                self.max_level = 0;
            }
        }
    }

    /// Tombstones added since the last [`Hnsw::compact`] (the eviction
    /// path's churn-trigger counter; see `LayerDb::admit_demoting`).
    pub fn dead_since_compact(&self) -> u64 {
        self.dead_since_compact
    }

    /// Total dead ids still referenced from live nodes' neighbour lists
    /// (0 right after [`Hnsw::compact`]; the churn regression test's
    /// search-cost proxy — every dead slot is a wasted traversal visit).
    /// O(index) — diagnostics and tests, not the serve path.
    pub fn dead_link_slots(&self) -> usize {
        (0..self.len as u32)
            .filter(|&id| !self.node(id).deleted)
            .map(|id| {
                self.node(id)
                    .links
                    .iter()
                    .flat_map(|l| l.iter())
                    .filter(|&&n| self.node(n).deleted)
                    .count()
            })
            .sum()
    }
}

impl VectorIndex for Hnsw {
    fn add(&mut self, v: &[f32]) -> u32 {
        assert_eq!(v.len(), self.dim, "dimension mismatch");
        let id = self.len as u32;
        let level = self.rng.hnsw_level(self.level_mult);
        self.push_node(v, level + 1);

        let Some(entry) = self.entry else {
            self.entry = Some(id);
            self.max_level = level;
            return id;
        };

        let mut cur = entry;
        for l in ((level + 1)..=self.max_level).rev() {
            cur = self.greedy(v, cur, l);
        }
        for l in (0..=level.min(self.max_level)).rev() {
            let hits = self.search_level(v, cur, l, self.params.ef_construction);
            cur = hits.first().map_or(cur, |h| h.id);
            let mut neighbours = self.select(&hits, if l == 0 {
                self.params.m * 2
            } else {
                self.params.m
            });
            if neighbours.is_empty() {
                // No live candidate reachable at this level: bridge
                // through the routing node (live — greedy and the beam
                // skip tombstones) so the new vector stays reachable.
                neighbours.push(cur);
            }
            for &n in &neighbours {
                self.node_mut(id).links[l].push(n);
                self.node_mut(n).links[l].push(id);
                self.shrink(n, l);
            }
        }
        if level > self.max_level {
            self.max_level = level;
            self.entry = Some(id);
        }
        id
    }

    fn search(&self, q: &[f32], k: usize) -> Vec<Hit> {
        self.search_ef(q, k, self.params.ef_search)
    }

    fn len(&self) -> usize {
        self.len
    }

    fn remove(&mut self, id: u32) -> bool {
        if id as usize >= self.len || self.node(id).deleted {
            return false;
        }
        self.node_mut(id).deleted = true;
        self.live -= 1;
        self.dead_since_compact += 1;
        // Searches start at the entry point; a tombstoned entry would
        // make every search start on (and an empty index search return)
        // a dead node, so hand the role to a live survivor.
        if self.entry == Some(id) {
            self.repick_entry();
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memo::index::BruteForceIndex;

    fn random_vecs(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::seeded(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.next_gaussian()).collect())
            .collect()
    }

    #[test]
    fn empty_index_returns_nothing() {
        let idx = Hnsw::new(4, HnswParams::default());
        assert!(idx.search(&[0.0; 4], 3).is_empty());
    }

    #[test]
    fn single_element() {
        let mut idx = Hnsw::new(2, HnswParams::default());
        idx.add(&[1.0, 2.0]);
        let hits = idx.search(&[1.0, 2.0], 1);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].id, 0);
        assert!(hits[0].dist_sq < 1e-9);
    }

    #[test]
    fn exact_match_found() {
        let vecs = random_vecs(200, 16, 1);
        let mut idx = Hnsw::new(16, HnswParams::default());
        for v in &vecs {
            idx.add(v);
        }
        for probe in [0usize, 57, 123, 199] {
            let hits = idx.search(&vecs[probe], 1);
            assert_eq!(hits[0].id, probe as u32, "probe {probe}");
        }
    }

    #[test]
    fn recall_at_10_vs_bruteforce() {
        let dim = 16;
        let vecs = random_vecs(500, dim, 2);
        let mut hnsw = Hnsw::new(dim, HnswParams::default());
        let mut bf = BruteForceIndex::new(dim);
        for v in &vecs {
            hnsw.add(v);
            bf.add(v);
        }
        let queries = random_vecs(50, dim, 3);
        let mut found = 0usize;
        let mut total = 0usize;
        for q in &queries {
            let exact: Vec<u32> =
                bf.search(q, 10).into_iter().map(|h| h.id).collect();
            let approx: Vec<u32> =
                hnsw.search_ef(q, 10, 64).into_iter().map(|h| h.id).collect();
            total += exact.len();
            found += exact.iter().filter(|e| approx.contains(e)).count();
        }
        let recall = found as f64 / total as f64;
        assert!(recall > 0.9, "recall {recall}");
    }

    #[test]
    fn results_sorted_and_unique() {
        let vecs = random_vecs(300, 8, 4);
        let mut idx = Hnsw::new(8, HnswParams::default());
        for v in &vecs {
            idx.add(v);
        }
        let hits = idx.search(&vecs[5], 20);
        for w in hits.windows(2) {
            assert!(w[0].dist_sq <= w[1].dist_sq);
        }
        let mut ids: Vec<u32> = hits.iter().map(|h| h.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), hits.len());
    }

    #[test]
    fn removed_ids_stop_matching_and_stop_expanding() {
        let vecs = random_vecs(300, 8, 6);
        let mut idx = Hnsw::new(8, HnswParams::default());
        for v in &vecs {
            idx.add(v);
        }
        // Tombstone every third vector (including, with high likelihood,
        // routing hubs) and verify none of them is ever returned while
        // recall on the survivors stays intact — traversal now *skips*
        // tombstones instead of routing through them, so this doubles as
        // the connectivity check for the skip path.
        let mut removed = Vec::new();
        for id in (0..300u32).step_by(3) {
            assert!(idx.remove(id));
            removed.push(id);
        }
        assert!(!idx.remove(removed[0]), "double remove must report false");
        assert!(idx.is_deleted(removed[0]));
        assert!(!idx.is_deleted(1));
        assert_eq!(idx.live_len(), 200);
        assert_eq!(idx.len(), 300);
        for probe in [1usize, 50, 100, 250] {
            let hits = idx.search_ef(&vecs[probe], 10, 128);
            assert!(!hits.is_empty());
            for h in &hits {
                assert!(!removed.contains(&h.id), "tombstoned id {}", h.id);
            }
            if probe % 3 != 0 {
                assert_eq!(hits[0].id, probe as u32, "live self-match");
            }
        }
    }

    #[test]
    fn all_removed_returns_nothing() {
        let mut idx = Hnsw::new(4, HnswParams::default());
        for i in 0..10 {
            idx.add(&[i as f32, 0.0, 0.0, 0.0]);
        }
        for id in 0..10 {
            idx.remove(id);
        }
        assert!(idx.search(&[0.0; 4], 3).is_empty());
        // Adding after a full purge works and is findable again.
        let id = idx.add(&[1.0, 2.0, 3.0, 4.0]);
        let hits = idx.search(&[1.0, 2.0, 3.0, 4.0], 1);
        assert_eq!(hits[0].id, id);
    }

    #[test]
    fn removing_the_entry_repicks_a_live_one() {
        let vecs = random_vecs(100, 8, 9);
        let mut idx = Hnsw::new(8, HnswParams::default());
        for v in &vecs {
            idx.add(v);
        }
        // Remove every node but one, searching as we go: each removal
        // that hits the current entry point must hand the role to a live
        // survivor (a dead entry would trip `search_ef`'s debug
        // assertion, and a search can only start — and therefore only
        // return anything — from a live entry).
        for id in 0..100u32 {
            if id == 37 {
                continue;
            }
            assert!(idx.remove(id));
            let hits = idx.search_ef(&vecs[37], 5, 64);
            assert!(!hits.is_empty(), "no hits after remove({id})");
            assert!(hits.iter().all(|h| !idx.is_deleted(h.id)));
        }
        assert_eq!(idx.live_len(), 1);
        let hits = idx.search_ef(&vecs[37], 1, 64);
        assert_eq!(hits[0].id, 37, "the last live node must be the entry");
    }

    /// PR 9 bugfix regression: heavy insert/remove churn (with the
    /// maintenance `compact` a long-lived index gets) must neither leak
    /// dead neighbour slots — the search-cost proxy: every dead slot is
    /// a wasted traversal visit — nor erode recall on the survivors.
    #[test]
    fn churn_with_compact_keeps_recall_and_reclaims_links() {
        let dim = 8;
        let mut rng = Pcg32::seeded(0xc4u64);
        let mut idx = Hnsw::new(dim, HnswParams::default());
        let mut live: Vec<(u32, Vec<f32>)> = Vec::new();
        let mut reclaimed_total = 0usize;
        for _round in 0..6 {
            for _ in 0..150 {
                let v: Vec<f32> =
                    (0..dim).map(|_| rng.next_gaussian()).collect();
                let id = idx.add(&v);
                live.push((id, v));
            }
            for _ in 0..100 {
                let pick = rng.range_usize(0, live.len());
                let (id, _) = live.swap_remove(pick);
                assert!(idx.remove(id));
            }
            reclaimed_total += idx.compact();
            assert_eq!(idx.dead_link_slots(), 0,
                       "compact must reclaim every dead neighbour slot");
        }
        assert!(reclaimed_total > 0, "churn must have produced dead links");
        assert_eq!(idx.live_len(), live.len());
        // Recall of the survivors vs the exact oracle: 600 tombstones
        // out of 900 inserted must not have severed the live graph.
        let mut bf = BruteForceIndex::new(dim);
        for (_, v) in &live {
            bf.add(v);
        }
        let queries = random_vecs(30, dim, 0xc5);
        let mut found = 0usize;
        let mut total = 0usize;
        for q in &queries {
            let exact: Vec<u32> = bf
                .search(q, 10)
                .into_iter()
                .map(|h| live[h.id as usize].0)
                .collect();
            let approx: Vec<u32> =
                idx.search_ef(q, 10, 96).into_iter().map(|h| h.id).collect();
            for h in &approx {
                assert!(!idx.is_deleted(*h), "tombstoned id {h} returned");
            }
            total += exact.len();
            found += exact.iter().filter(|e| approx.contains(e)).count();
        }
        let recall = found as f64 / total as f64;
        assert!(recall > 0.8, "post-churn recall {recall}");
    }

    /// Tentpole: a clone shares chunks with its parent; mutating the
    /// clone deep-copies only the touched node records, and the frozen
    /// parent keeps answering from its own generation.
    #[test]
    fn clone_is_generational_and_freezes_the_parent() {
        let dim = 8;
        let vecs = random_vecs(1000, dim, 11);
        let mut idx = Hnsw::new(dim, HnswParams::default());
        for v in &vecs {
            idx.add(v);
        }
        let frozen = idx.clone();
        assert_eq!(frozen.touched_nodes(), 0, "a fresh clone touched nothing");
        let before_len = frozen.len();

        // Mutate the parent: one insert plus one tombstone.
        let extra: Vec<f32> = vecs[0].iter().map(|x| x + 0.01).collect();
        let new_id = idx.add(&extra);
        assert!(idx.remove(3));

        // The writer copied O(batch) node records, not the whole graph.
        let touched = idx.touched_nodes();
        assert!(touched > 0, "mutations must register as touched");
        assert!(
            (touched as usize) < idx.len() / 2,
            "touched {touched} of {} nodes — generational clone degraded \
             to a full copy",
            idx.len()
        );

        // The frozen generation still answers from its own state.
        assert_eq!(frozen.len(), before_len);
        assert!(!frozen.is_deleted(3));
        let hits = frozen.search_ef(&vecs[3], 1, 64);
        assert_eq!(hits[0].id, 3, "frozen snapshot lost a pre-clone entry");
        assert!(
            frozen.search_ef(&extra, 10, 64).iter().all(|h| h.id != new_id),
            "frozen snapshot sees a post-clone insert"
        );
        // And the writer sees its own mutations.
        assert!(idx.is_deleted(3));
        assert_eq!(idx.search_ef(&extra, 1, 64)[0].id, new_id);
    }

    /// The full-clone baseline arm: `unshare_all` deep-copies the whole
    /// graph and reports it through the same touched counter.
    #[test]
    fn unshare_all_touches_every_node() {
        let dim = 4;
        let vecs = random_vecs(600, dim, 12);
        let mut idx = Hnsw::new(dim, HnswParams::default());
        for v in &vecs {
            idx.add(v);
        }
        let mut full = idx.clone();
        full.unshare_all();
        // Every node record plus every vector row recopied.
        assert_eq!(full.touched_nodes(), 2 * vecs.len() as u64);
        // A second pass is a no-op: everything is already exclusive.
        let again = full.touched_nodes();
        full.unshare_all();
        assert_eq!(full.touched_nodes(), again);
    }

    #[test]
    fn deterministic_given_seed() {
        let vecs = random_vecs(100, 8, 5);
        let build = || {
            let mut idx = Hnsw::new(8, HnswParams::default());
            for v in &vecs {
                idx.add(v);
            }
            idx.search(&vecs[0], 5)
        };
        assert_eq!(build(), build());
    }
}
