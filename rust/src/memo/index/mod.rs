//! The index database (paper §5.3): approximate nearest-neighbour search
//! over hidden-state embeddings. HNSW (the paper uses Faiss-HNSW) is
//! implemented from scratch, with an exact brute-force index as the
//! search-quality baseline (paper Fig. 7).

pub mod bruteforce;
pub mod hnsw;

pub use bruteforce::BruteForceIndex;
pub use hnsw::{Hnsw, HnswParams};

/// A (vector id, squared-L2 distance) search hit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hit {
    /// Id of the stored vector (insertion order, dense).
    pub id: u32,
    /// Squared L2 distance from the query.
    pub dist_sq: f32,
}

/// Common interface over the exact and approximate indexes.
pub trait VectorIndex {
    /// Insert a vector; ids are assigned densely in insertion order.
    fn add(&mut self, v: &[f32]) -> u32;
    /// `k` nearest neighbours of `q`, nearest first.
    fn search(&self, q: &[f32], k: usize) -> Vec<Hit>;
    /// Number of stored vectors (tombstoned ones included).
    fn len(&self) -> usize;
    /// Tombstone a vector: it stops matching searches but keeps its id
    /// (and, for graph indexes, keeps routing). Returns `false` when the
    /// id is unknown or already removed.
    fn remove(&mut self, id: u32) -> bool;
    /// Whether the index stores no vectors at all.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}
