//! Exact nearest-neighbour baseline (the paper's "exhaustive search").

use crate::kernels::simd::l2_sq;
use crate::memo::index::{Hit, VectorIndex};

/// Flat store + linear scan. O(N·d) per query; used for Fig. 7 quality
/// comparisons and as the recall oracle in property tests. Deletion is by
/// tombstone, mirroring [`crate::memo::index::Hnsw`].
pub struct BruteForceIndex {
    dim: usize,
    data: Vec<f32>,
    deleted: Vec<bool>,
}

impl BruteForceIndex {
    /// Empty index over `dim`-dimensional vectors.
    pub fn new(dim: usize) -> Self {
        BruteForceIndex { dim, data: Vec::new(), deleted: Vec::new() }
    }

    /// Stored vector by id.
    pub fn vector(&self, id: u32) -> &[f32] {
        let i = id as usize * self.dim;
        &self.data[i..i + self.dim]
    }
}

impl VectorIndex for BruteForceIndex {
    fn add(&mut self, v: &[f32]) -> u32 {
        assert_eq!(v.len(), self.dim, "dimension mismatch");
        let id = self.len() as u32;
        self.data.extend_from_slice(v);
        self.deleted.push(false);
        id
    }

    fn search(&self, q: &[f32], k: usize) -> Vec<Hit> {
        let n = self.len();
        let mut hits: Vec<Hit> = (0..n)
            .filter(|&i| !self.deleted[i])
            .map(|i| Hit {
                id: i as u32,
                dist_sq: l2_sq(q, &self.data[i * self.dim..(i + 1) * self.dim]),
            })
            .collect();
        hits.sort_by(|a, b| a.dist_sq.partial_cmp(&b.dist_sq).unwrap());
        hits.truncate(k);
        hits
    }

    fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    fn remove(&mut self, id: u32) -> bool {
        match self.deleted.get_mut(id as usize) {
            Some(d) if !*d => {
                *d = true;
                true
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_exact_match_first() {
        let mut idx = BruteForceIndex::new(3);
        idx.add(&[0.0, 0.0, 0.0]);
        idx.add(&[1.0, 0.0, 0.0]);
        idx.add(&[0.0, 2.0, 0.0]);
        let hits = idx.search(&[1.0, 0.1, 0.0], 2);
        assert_eq!(hits[0].id, 1);
        assert_eq!(hits.len(), 2);
        assert!(hits[0].dist_sq <= hits[1].dist_sq);
    }

    #[test]
    fn k_larger_than_n() {
        let mut idx = BruteForceIndex::new(2);
        idx.add(&[0.0, 0.0]);
        assert_eq!(idx.search(&[1.0, 1.0], 5).len(), 1);
    }

    #[test]
    fn removed_entries_stop_matching() {
        let mut idx = BruteForceIndex::new(2);
        idx.add(&[0.0, 0.0]);
        idx.add(&[1.0, 0.0]);
        assert!(idx.remove(1));
        assert!(!idx.remove(1));
        assert!(!idx.remove(99));
        let hits = idx.search(&[1.0, 0.0], 5);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].id, 0);
    }
}
