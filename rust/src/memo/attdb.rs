//! The attention database: per-layer APM stores plus their HNSW indexes.
//!
//! One `LayerDb` per self-attention layer (the paper's memoization
//! granularity): an `ApmArena` holding the APM payloads `[heads, L, L]`,
//! an HNSW index over the embedding feature-vectors of the hidden states
//! that produced them, and reuse counters for the Fig. 11 analysis.

use crate::config::ModelConfig;
use crate::memo::arena::{ApmArena, ApmId};
use crate::memo::index::{Hnsw, HnswParams, VectorIndex};
use crate::{Error, Result};

/// Result of a lookup: nearest stored entry + similarity estimate.
#[derive(Debug, Clone, Copy)]
pub struct Lookup {
    pub id: ApmId,
    /// Estimated similarity `1 − ‖e(q) − e(x)‖₂` (embeddings are
    /// L2-normalised, so the distance lives in [0, 2]).
    pub similarity: f32,
}

/// One layer's attention + index database.
pub struct LayerDb {
    arena: ApmArena,
    index: Hnsw,
    /// Reuse count per entry (Fig. 11). Interior mutability so engines can
    /// share a built database read-only behind `Arc` and still account
    /// reuse.
    reuse: std::sync::Mutex<Vec<u32>>,
}

impl LayerDb {
    pub fn new(cfg: &ModelConfig, seq_len: usize, params: HnswParams) -> Self {
        LayerDb {
            arena: ApmArena::new(cfg.apm_elems(seq_len))
                .expect("arena creation"),
            index: Hnsw::new(cfg.embed_dim, params),
            reuse: std::sync::Mutex::new(Vec::new()),
        }
    }

    /// Insert one (feature vector, APM) pair.
    pub fn insert(&mut self, feature: &[f32], apm: &[f32]) -> Result<ApmId> {
        let id = self.arena.push(apm)?;
        let iid = self.index.add(feature);
        debug_assert_eq!(iid, id.0, "arena and index ids must stay aligned");
        self.reuse.lock().unwrap().push(0);
        Ok(id)
    }

    /// Nearest entry for a query feature vector; `ef` overrides the beam.
    pub fn lookup(&self, feature: &[f32], ef: usize) -> Option<Lookup> {
        let hit = self.index.search_ef(feature, 1, ef).into_iter().next()?;
        Some(Lookup {
            id: ApmId(hit.id),
            similarity: 1.0 - hit.dist_sq.sqrt(),
        })
    }

    /// Record that an entry was used for memoization.
    pub fn mark_reused(&self, id: ApmId) {
        if let Some(c) = self.reuse.lock().unwrap().get_mut(id.0 as usize) {
            *c += 1;
        }
    }

    pub fn arena(&self) -> &ApmArena {
        &self.arena
    }

    pub fn len(&self) -> usize {
        self.arena.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arena.is_empty()
    }

    pub fn reuse_counts(&self) -> Vec<u32> {
        self.reuse.lock().unwrap().clone()
    }

    /// Stored feature vector for an entry (persistence).
    pub fn index_vector(&self, id: ApmId) -> &[f32] {
        self.index.vector(id.0)
    }
}

/// The full multi-layer database for one model family.
pub struct AttentionDb {
    pub family: String,
    pub seq_len: usize,
    layers: Vec<LayerDb>,
    apm_elems: usize,
    embed_dim: usize,
}

impl AttentionDb {
    pub fn new(cfg: &ModelConfig, seq_len: usize, params: HnswParams) -> Self {
        AttentionDb {
            family: cfg.family.clone(),
            seq_len,
            layers: (0..cfg.layers)
                .map(|_| LayerDb::new(cfg, seq_len, params))
                .collect(),
            apm_elems: cfg.apm_elems(seq_len),
            embed_dim: cfg.embed_dim,
        }
    }

    pub fn layer(&self, i: usize) -> &LayerDb {
        &self.layers[i]
    }

    pub fn layer_mut(&mut self, i: usize) -> &mut LayerDb {
        &mut self.layers[i]
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Entries per f32 APM payload.
    pub fn apm_elems(&self) -> usize {
        self.apm_elems
    }

    pub fn embed_dim(&self) -> usize {
        self.embed_dim
    }

    /// Total entries across layers.
    pub fn total_entries(&self) -> usize {
        self.layers.iter().map(LayerDb::len).sum()
    }

    /// Total resident payload bytes (the paper's "pre-populated DB size").
    pub fn resident_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.arena().resident_bytes()).sum()
    }

    /// Bulk-insert a layer's batch of (features [n, d], apms [n, elems]).
    pub fn insert_batch(&mut self, layer: usize, features: &[f32],
                        apms: &[f32]) -> Result<Vec<ApmId>> {
        let d = self.embed_dim;
        let e = self.apm_elems;
        if features.len() % d != 0 || apms.len() % e != 0
            || features.len() / d != apms.len() / e
        {
            return Err(Error::memo(format!(
                "insert_batch: {} features vs {} apms",
                features.len() / d,
                apms.len() / e
            )));
        }
        let n = features.len() / d;
        let ldb = &mut self.layers[layer];
        (0..n)
            .map(|i| ldb.insert(&features[i * d..(i + 1) * d],
                                &apms[i * e..(i + 1) * e]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn cfg() -> ModelConfig {
        ModelConfig {
            family: "bert".into(),
            vocab_size: 256,
            hidden: 32,
            layers: 2,
            heads: 2,
            ffn: 64,
            max_len: 16,
            num_classes: 2,
            rel_pos_buckets: 8,
            embed_dim: 8,
            embed_hidden: 16,
            embed_segments: 4,
            causal: false,
        }
    }

    fn unit(rng: &mut Pcg32, d: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..d).map(|_| rng.next_gaussian()).collect();
        let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        v.iter_mut().for_each(|x| *x /= n);
        v
    }

    #[test]
    fn insert_and_lookup_self() {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 16, HnswParams::default());
        let mut rng = Pcg32::seeded(1);
        let elems = c.apm_elems(16);
        let mut feats = Vec::new();
        for _ in 0..20 {
            let f = unit(&mut rng, c.embed_dim);
            let apm = vec![1.0 / 16.0; elems];
            db.layer_mut(0).insert(&f, &apm).unwrap();
            feats.push(f);
        }
        let hit = db.layer(0).lookup(&feats[7], 32).unwrap();
        assert_eq!(hit.id, ApmId(7));
        assert!(hit.similarity > 0.999, "{}", hit.similarity);
    }

    #[test]
    fn batch_insert_validates_counts() {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 16, HnswParams::default());
        let d = c.embed_dim;
        let e = c.apm_elems(16);
        assert!(db.insert_batch(0, &vec![0.0; 2 * d], &vec![0.0; e]).is_err());
        let ids = db
            .insert_batch(1, &vec![0.1; 2 * d], &vec![0.0; 2 * e])
            .unwrap();
        assert_eq!(ids.len(), 2);
        assert_eq!(db.total_entries(), 2);
    }

    #[test]
    fn reuse_counters() {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 16, HnswParams::default());
        let f = vec![0.5; c.embed_dim];
        let apm = vec![0.0; c.apm_elems(16)];
        let id = db.layer_mut(0).insert(&f, &apm).unwrap();
        db.layer(0).mark_reused(id);
        db.layer(0).mark_reused(id);
        assert_eq!(db.layer(0).reuse_counts(), vec![2]);
    }

    #[test]
    fn empty_lookup_is_none() {
        let c = cfg();
        let db = AttentionDb::new(&c, 16, HnswParams::default());
        assert!(db.layer(0).lookup(&vec![0.0; c.embed_dim], 16).is_none());
    }
}
