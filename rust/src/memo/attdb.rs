//! The attention database: per-layer APM stores plus their HNSW indexes.
//!
//! One `LayerDb` per self-attention layer (the paper's memoization
//! granularity): an `ApmArena` holding the APM payloads `[heads, L, L]`,
//! an HNSW index over the embedding feature-vectors of the hidden states
//! that produced them, and reuse counters for the Fig. 11 analysis.
//!
//! Beyond the paper's offline pre-population, a `LayerDb` is writable at
//! serve time: [`LayerDb::admit`] stores a freshly computed (feature, APM)
//! pair under a capacity budget, evicting via a reuse-aware clock
//! ([`LayerDb::evict_victim`]) when the budget is hit. Eviction frees the
//! arena's page slot for reuse and tombstones the index entry, so retired
//! ids stop matching without an index rebuild.

use std::sync::atomic::{AtomicU32, AtomicU8, Ordering};
use std::sync::Arc;

use crate::config::ModelConfig;
use crate::memo::arena::{ApmArena, ApmId, StoreHandle};
use crate::memo::index::{Hnsw, HnswParams, VectorIndex};
use crate::{Error, Result};

/// Result of a lookup: nearest stored entry + similarity estimate.
#[derive(Debug, Clone, Copy)]
pub struct Lookup {
    /// Id of the matched entry.
    pub id: ApmId,
    /// Estimated similarity `1 − ‖e(q) − e(x)‖₂` (embeddings are
    /// L2-normalised, so the distance lives in [0, 2]).
    pub similarity: f32,
    /// Epoch stamp of the entry at lookup time. Fetching through
    /// [`crate::memo::ApmArena::get_checked`] with this stamp can never
    /// observe a reused slot's stale bytes, even if an eviction or
    /// compaction raced in between (see `ApmArena::epoch`).
    pub epoch: u64,
}

/// What one serve-time admission did.
#[derive(Debug, Clone)]
pub struct AdmitOutcome {
    /// Id of the admitted entry.
    pub id: ApmId,
    /// Entries evicted to make room (empty below capacity).
    pub evicted: Vec<ApmId>,
}

/// Entries per chunk of the reuse track. Chunks are cache-line-aligned
/// blocks of per-entry atomics: a reuse mark touches one `AtomicU32` and
/// two `AtomicU8`s inside one chunk, so concurrent readers marking
/// different (hot) entries land on different lines instead of all
/// serializing through one mutex — the lock the PR 5 hit path still paid.
const TRACK_CHUNK: usize = 256;

/// One chunk of per-entry reuse state (see [`ReuseTrack`]).
#[repr(align(64))]
struct TrackChunk {
    /// Total reuses per entry (Fig. 11); evicted entries keep their
    /// final count.
    counts: [AtomicU32; TRACK_CHUNK],
    /// Clock reference counters (second-chance bits, saturating at 3):
    /// bumped on reuse, decayed by the eviction clock.
    refs: [AtomicU8; TRACK_CHUNK],
    /// 1 when the entry was admitted or reused since the last warm
    /// snapshot; `save_warm` persists only warm entries and clears the
    /// bits afterwards (the snapshot compaction policy).
    warm: [AtomicU8; TRACK_CHUNK],
}

impl TrackChunk {
    fn new() -> Self {
        TrackChunk {
            counts: std::array::from_fn(|_| AtomicU32::new(0)),
            refs: std::array::from_fn(|_| AtomicU8::new(0)),
            warm: std::array::from_fn(|_| AtomicU8::new(0)),
        }
    }
}

/// Per-entry reuse accounting as chunked atomics — no lock anywhere on
/// the mark path. The chunk list is cloned per copy-on-write snapshot
/// (cheap `Arc` copies) while the counters inside are shared across the
/// whole lineage, so reuse marked by readers of a frozen snapshot keeps
/// feeding the live eviction clock, exactly as the mutex version did.
/// All counter updates are `Relaxed`: the track is an eviction/persistence
/// heuristic, never a correctness input.
#[derive(Clone, Default)]
struct ReuseTrack {
    chunks: Vec<Arc<TrackChunk>>,
    /// Entries this snapshot knows about. Marks are accepted for any id
    /// within the *allocated* chunks (a frozen snapshot may legitimately
    /// mark an id a newer lineage issued — the chunk is shared), but
    /// serialization reads stop at `len`.
    len: usize,
}

impl ReuseTrack {
    /// `(chunk, index)` of an id the caller may touch, `None` past the
    /// allocated chunks.
    fn cell(&self, i: usize) -> Option<(&TrackChunk, usize)> {
        self.chunks
            .get(i / TRACK_CHUNK)
            .map(|c| (c.as_ref(), i % TRACK_CHUNK))
    }

    /// Append one entry's state (writer-side; the slot in the shared
    /// chunk is unused by every frozen snapshot, whose `len` is smaller).
    fn push(&mut self, count: u32, refs: u8, warm: u8) {
        if self.len % TRACK_CHUNK == 0 {
            self.chunks.push(Arc::new(TrackChunk::new()));
        }
        let c = &self.chunks[self.len / TRACK_CHUNK];
        let i = self.len % TRACK_CHUNK;
        c.counts[i].store(count, Ordering::Relaxed);
        c.refs[i].store(refs, Ordering::Relaxed);
        c.warm[i].store(warm, Ordering::Relaxed);
        self.len += 1;
    }

    /// Lock-free reuse mark: count +1, clock ref saturating +1, warm bit
    /// set. Safe from any snapshot sharing the chunks.
    fn mark(&self, i: usize) {
        let Some((c, k)) = self.cell(i) else { return };
        c.counts[k].fetch_add(1, Ordering::Relaxed);
        let _ = c.refs[k].fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |r| if r >= 3 { None } else { Some(r + 1) },
        );
        c.warm[k].store(1, Ordering::Relaxed);
    }

    /// Clock ref of an entry (eviction scan).
    fn refs_of(&self, i: usize) -> u8 {
        self.cell(i).map_or(0, |(c, k)| c.refs[k].load(Ordering::Relaxed))
    }

    /// Decay an entry's clock ref by one (eviction scan), saturating at 0.
    fn decay(&self, i: usize) {
        if let Some((c, k)) = self.cell(i) {
            let _ = c.refs[k].fetch_update(
                Ordering::Relaxed,
                Ordering::Relaxed,
                |r| if r == 0 { None } else { Some(r - 1) },
            );
        }
    }

    fn count_of(&self, i: usize) -> u32 {
        self.cell(i)
            .map_or(0, |(c, k)| c.counts[k].load(Ordering::Relaxed))
    }

    fn warm_of(&self, i: usize) -> u8 {
        self.cell(i).map_or(0, |(c, k)| c.warm[k].load(Ordering::Relaxed))
    }

    fn set_warm(&self, i: usize, v: u8) {
        if let Some((c, k)) = self.cell(i) {
            c.warm[k].store(v, Ordering::Relaxed);
        }
    }

    fn set_restored(&self, i: usize, count: u32, refs: u8) {
        if let Some((c, k)) = self.cell(i) {
            c.counts[k].store(count, Ordering::Relaxed);
            c.refs[k].store(refs.min(3), Ordering::Relaxed);
        }
    }
}

/// Don't bother compacting tombstones below this id-space size — small
/// layers never pay enough sweep/search cost to justify a rebuild.
const COMPACT_MIN_IDS: usize = 64;

/// One layer's attention + index database.
pub struct LayerDb {
    arena: ApmArena,
    index: Hnsw,
    /// Chunk-shared across copy-on-write snapshots of this layer (the
    /// reuse signal is a heuristic that should keep accumulating while
    /// frozen snapshots serve reads); replaced wholesale by `compact`,
    /// which renumbers ids. Pure atomics: `mark_reused` takes no lock.
    reuse: ReuseTrack,
    /// Eviction clock position (an id in `[0, arena.next_id())`).
    hand: usize,
    /// Bench baseline: deep-copy the whole HNSW graph on every
    /// `cow_clone`, as the pre-generational index did
    /// (`MemoConfig::full_index_clone`). Never set in production.
    full_index_clone: bool,
}

impl LayerDb {
    /// Empty layer database sized for `cfg`'s APM shape at `seq_len`.
    pub fn new(cfg: &ModelConfig, seq_len: usize, params: HnswParams) -> Self {
        LayerDb {
            arena: ApmArena::new(cfg.apm_elems(seq_len))
                .expect("arena creation"),
            index: Hnsw::new(cfg.embed_dim, params),
            reuse: ReuseTrack::default(),
            hand: 0,
            full_index_clone: false,
        }
    }

    /// Copy-on-write snapshot for the seqlock tier: the index and the
    /// arena share their chunked tables with the copy (generational
    /// clones — a mutation of the copy unshares only the chunks it
    /// touches), the arena's payload store and the reuse-track chunks
    /// are shared outright — reuse marked by readers of a frozen
    /// snapshot keeps feeding the live eviction clock.
    pub(crate) fn cow_clone(&self) -> LayerDb {
        let mut index = self.index.clone();
        if self.full_index_clone {
            // The O(index) whole-graph copy the generational layout
            // replaced; kept as the write-path bench's baseline arm.
            index.unshare_all();
        }
        LayerDb {
            arena: self.arena.cow_clone(),
            index,
            reuse: self.reuse.clone(),
            hand: self.hand,
            full_index_clone: self.full_index_clone,
        }
    }

    /// Force every `cow_clone` of this layer to deep-copy the whole
    /// index graph (the pre-generational behaviour) — the A/B baseline
    /// of the write-path bench, wired from `MemoConfig::full_index_clone`.
    pub(crate) fn set_full_index_clone(&mut self, on: bool) {
        self.full_index_clone = on;
    }

    /// Node records and vector rows the index deep-copied since this
    /// working copy was cloned off the published snapshot — the actual
    /// copy cost of the mutations behind one publish (the tier
    /// aggregates it into `publish_touched_nodes`).
    pub(crate) fn index_touched_nodes(&self) -> u64 {
        self.index.touched_nodes()
    }

    /// Route the arena's evictions through the deferred-reclaim list (the
    /// concurrent tier's slot discipline; see `ApmArena::set_defer_free`).
    pub(crate) fn set_defer_free(&mut self, on: bool) {
        self.arena.set_defer_free(on);
    }

    /// Drain the arena slots freed since the last call (deferred mode).
    pub(crate) fn take_pending_free(&mut self) -> Vec<u32> {
        self.arena.take_pending_free()
    }

    /// Return quiesced arena slots to the free list.
    pub(crate) fn release_free_slots(&mut self, slots: Vec<u32>) {
        self.arena.release_slots(slots);
    }

    /// Owned identity of the arena's current backing store (the tier tags
    /// freed-slot lists with it; see `ApmArena::store_handle`).
    pub(crate) fn store_handle(&self) -> StoreHandle {
        self.arena.store_handle()
    }

    /// Whether this layer's arena still lives on the store `h` identifies
    /// (false across a compaction, which rebuilds onto a fresh store).
    pub(crate) fn is_on_store(&self, h: &StoreHandle) -> bool {
        self.arena.is_on_store(h)
    }

    /// Insert one (feature vector, APM) pair.
    pub fn insert(&mut self, feature: &[f32], apm: &[f32]) -> Result<ApmId> {
        let id = self.arena.push(apm)?;
        let iid = self.index.add(feature);
        debug_assert_eq!(iid, id.0, "arena and index ids must stay aligned");
        // Fresh entries survive their first snapshot (warm = 1).
        self.reuse.push(0, 0, 1);
        Ok(id)
    }

    /// Insert an entry restored from a warm snapshot, carrying over its
    /// reuse count and clock reference bits (see `memo::persist`).
    pub fn insert_restored(&mut self, feature: &[f32], apm: &[f32],
                           count: u32, refs: u8) -> Result<ApmId> {
        let id = self.insert(feature, apm)?;
        self.reuse.set_restored(id.0 as usize, count, refs);
        Ok(id)
    }

    /// Serve-time admission: insert under a `capacity` budget (0 =
    /// unbounded), evicting clock victims first so occupancy never
    /// exceeds the budget.
    ///
    /// Ids are stable only until the next `admit`: admission may trigger
    /// a tombstone compaction (see [`LayerDb::compact`]), which renumbers
    /// live entries — so the returned [`AdmitOutcome::id`] must be used
    /// (or discarded) before admitting again.
    pub fn admit(&mut self, feature: &[f32], apm: &[f32],
                 capacity: usize) -> Result<AdmitOutcome> {
        self.admit_demoting(feature, apm, capacity, &mut |_, _| {})
    }

    /// [`LayerDb::admit`] with eviction capture: each capacity victim's
    /// stored feature vector and APM payload are handed to `demote`
    /// *before* the eviction frees its slot — the two-tier spill hook
    /// (`memo/cold.rs`). The sink runs on the writer path (the tier
    /// holds its shard mutex across the whole admission), so captured
    /// slices are stable for the duration of the call.
    pub fn admit_demoting(&mut self, feature: &[f32], apm: &[f32],
                          capacity: usize,
                          demote: &mut dyn FnMut(&[f32], &[f32]))
                          -> Result<AdmitOutcome> {
        let mut evicted = Vec::new();
        if capacity > 0 {
            while self.len() >= capacity {
                match self.pick_victim() {
                    Some(id) => {
                        demote(self.index.vector(id.0),
                               self.arena.get(id)?);
                        self.evict(id)?;
                        evicted.push(id);
                    }
                    None => break,
                }
            }
        }
        let id = self.insert(feature, apm)?;
        // Keep the id space bounded by the live set: once tombstones
        // dominate (4× the live count), rebuild. Without this, churn
        // grows the HNSW graph (and every search's `visited` bitmap, and
        // the eviction clock's sweep span) linearly with total
        // admissions ever made.
        let span = self.arena.next_id() as usize;
        if span >= COMPACT_MIN_IDS && span >= 4 * self.len() {
            self.compact()?;
        } else if self.index.dead_since_compact() as usize
            >= COMPACT_MIN_IDS.max(self.index.live_len())
        {
            // Steady-state churn at capacity rarely crosses the 4×-span
            // wholesale rebuild above (evict + admit holds the live
            // count flat while the id space creeps), but every eviction
            // leaves a tombstone in live neighbour lists. Once the
            // tombstones added since the last link reclaim rival the
            // live set, sweep them in place (id-stable — cheaper than
            // the rebuild and invisible to id holders).
            self.index.compact();
        }
        Ok(AdmitOutcome { id, evicted })
    }

    /// Dead ids still referenced from the index's live neighbour lists
    /// (O(index) diagnostic; the churn-compaction regression test's
    /// bound).
    pub fn index_dead_link_slots(&self) -> usize {
        self.index.dead_link_slots()
    }

    /// Tombstones accumulated in the index since its last link
    /// compaction (the churn-trigger counter; diagnostics and tests).
    pub fn index_dead_since_compact(&self) -> u64 {
        self.index.dead_since_compact()
    }

    /// Rebuild the arena, index and reuse tracking from the live entries
    /// only, compacting tombstoned ids away. Live entries are renumbered
    /// densely (in prior-id order); reuse counts and clock state carry
    /// over. Outstanding `ApmId`s from before the compaction are invalid
    /// afterwards.
    pub fn compact(&mut self) -> Result<()> {
        let ids = self.arena.live_ids();
        let mut arena = ApmArena::new(self.arena.entry_elems())?;
        // The rebuilt arena is a new id universe: epoch stamps taken before
        // the compaction must not validate against renumbered entries.
        arena.set_generation(self.arena.generation().wrapping_add(1));
        // The rebuild lands on a *fresh* backing store; keep the owner's
        // reclaim discipline. The old store (and any slots pending
        // reclaim on it) is retired wholesale once the last snapshot
        // referencing it drops.
        arena.set_defer_free(self.arena.defer_free());
        let mut index = Hnsw::new(self.index.dim(), *self.index.params());
        let mut track = ReuseTrack::default();
        for &id in &ids {
            let nid = arena.push(self.arena.get(id)?)?;
            let iid = index.add(self.index.vector(id.0));
            debug_assert_eq!(iid, nid.0, "compaction id alignment");
            let i = id.0 as usize;
            track.push(
                self.reuse.count_of(i),
                self.reuse.refs_of(i),
                self.reuse.warm_of(i),
            );
        }
        self.arena = arena;
        self.index = index;
        // A fresh track (fresh chunks): readers of pre-compaction
        // snapshots keep marking reuse on *their* (correctly sized)
        // chunks; those marks are lost to the rebuilt clock, which is
        // fine for a heuristic — corruption from renumbered ids is not.
        self.reuse = track;
        self.hand = 0;
        Ok(())
    }

    /// Evict one entry: frees its arena slot and tombstones its index id.
    pub fn evict(&mut self, id: ApmId) -> Result<()> {
        self.arena.remove(id)?;
        self.index.remove(id.0);
        Ok(())
    }

    /// Pick and evict the clock victim: sweep ids from the hand, evicting
    /// the first live entry whose reference counter has decayed to zero
    /// and decaying the others — entries reused since the last sweeps
    /// survive (reuse-aware LRU approximation). Falls back to the first
    /// live entry after two full sweeps; `None` on an empty layer.
    pub fn evict_victim(&mut self) -> Option<ApmId> {
        let v = self.pick_victim()?;
        self.evict(v).ok()?;
        Some(v)
    }

    /// Run the clock sweep and advance the hand, returning the victim
    /// *without* evicting it — the demotion path captures the victim's
    /// feature and payload first ([`LayerDb::admit_demoting`]).
    fn pick_victim(&mut self) -> Option<ApmId> {
        let span = self.arena.next_id() as usize;
        if span == 0 || self.arena.is_empty() {
            return None;
        }
        let mut victim: Option<ApmId> = None;
        let mut first_live: Option<u32> = None;
        for step in 0..2 * span {
            let id = ((self.hand + step) % span) as u32;
            if !self.arena.is_live(ApmId(id)) {
                continue;
            }
            if first_live.is_none() {
                first_live = Some(id);
            }
            if self.reuse.refs_of(id as usize) == 0 {
                victim = Some(ApmId(id));
                break;
            }
            self.reuse.decay(id as usize);
        }
        if victim.is_none() {
            victim = first_live.map(ApmId);
        }
        let v = victim?;
        self.hand = (v.0 as usize + 1) % span;
        Some(v)
    }

    /// Nearest entry for a query feature vector; `ef` overrides the beam.
    pub fn lookup(&self, feature: &[f32], ef: usize) -> Option<Lookup> {
        let hit = self.index.search_ef(feature, 1, ef).into_iter().next()?;
        let id = ApmId(hit.id);
        let epoch = self.arena.epoch(id).ok()?;
        Some(Lookup {
            id,
            similarity: 1.0 - hit.dist_sq.sqrt(),
            epoch,
        })
    }

    /// Record that an entry was used for memoization. Lock-free (chunked
    /// atomics): hit-path callers — including readers of frozen snapshots
    /// — touch no mutex; the mark lands on the chunk shared with the live
    /// lineage, feeding its eviction clock.
    pub fn mark_reused(&self, id: ApmId) {
        self.reuse.mark(id.0 as usize);
    }

    /// The layer's APM payload arena.
    pub fn arena(&self) -> &ApmArena {
        &self.arena
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.arena.len()
    }

    /// Whether no entries are live.
    pub fn is_empty(&self) -> bool {
        self.arena.is_empty()
    }

    /// Ids of all live entries, ascending.
    pub fn live_ids(&self) -> Vec<ApmId> {
        self.arena.live_ids()
    }

    /// Per-id reuse counts (Fig. 11); evicted ids keep their final count.
    /// Snapshot of the shared atomic counters (`Relaxed` loads — a mark
    /// racing the read may or may not be included, which is fine for a
    /// heuristic that persistence treats as advisory).
    pub fn reuse_counts(&self) -> Vec<u32> {
        (0..self.reuse.len).map(|i| self.reuse.count_of(i)).collect()
    }

    /// Per-id clock reference bits (persistence carries these over so a
    /// reloaded snapshot keeps its eviction ordering). Atomic snapshot
    /// like [`LayerDb::reuse_counts`].
    pub fn reuse_refs(&self) -> Vec<u8> {
        (0..self.reuse.len).map(|i| self.reuse.refs_of(i)).collect()
    }

    /// Per-id "admitted or reused since the last warm snapshot" bits —
    /// the snapshot compaction signal: `save_warm` skips entries whose
    /// bit is 0 (idle since the previous snapshot) instead of persisting
    /// them.
    pub fn warm_bits(&self) -> Vec<u8> {
        (0..self.reuse.len).map(|i| self.reuse.warm_of(i)).collect()
    }

    /// Start a new snapshot epoch: clear every since-last-snapshot bit.
    /// Takes `&self` so it runs against a published snapshot like
    /// `mark_reused` (the track chunks are shared across snapshot copies).
    pub fn clear_warm_bits(&self) {
        for i in 0..self.reuse.len {
            self.reuse.set_warm(i, 0);
        }
    }

    /// Clear the since-last-snapshot bits of exactly `ids` — the entries
    /// a snapshot just serialized. `save_warm` calls this inside the
    /// same writer-quiesced section it serialized under, so an entry
    /// admitted or re-warmed concurrently (which never appears in `ids`)
    /// keeps its bit and survives into the *next* snapshot — preserving
    /// the one-snapshot grace period.
    pub fn clear_warm_bits_for(&self, ids: &[ApmId]) {
        for id in ids {
            if (id.0 as usize) < self.reuse.len {
                self.reuse.set_warm(id.0 as usize, 0);
            }
        }
    }

    /// Stored feature vector for an entry (persistence).
    pub fn index_vector(&self, id: ApmId) -> &[f32] {
        self.index.vector(id.0)
    }
}

/// The full multi-layer database for one model family.
pub struct AttentionDb {
    /// Model family the database serves (e.g. `"bert"`).
    pub family: String,
    /// Sequence length the APM entries were computed at.
    pub seq_len: usize,
    layers: Vec<LayerDb>,
    apm_elems: usize,
    embed_dim: usize,
}

impl AttentionDb {
    /// Empty database with one [`LayerDb`] per self-attention layer.
    pub fn new(cfg: &ModelConfig, seq_len: usize, params: HnswParams) -> Self {
        AttentionDb {
            family: cfg.family.clone(),
            seq_len,
            layers: (0..cfg.layers)
                .map(|_| LayerDb::new(cfg, seq_len, params))
                .collect(),
            apm_elems: cfg.apm_elems(seq_len),
            embed_dim: cfg.embed_dim,
        }
    }

    /// One layer's database (immutable).
    pub fn layer(&self, i: usize) -> &LayerDb {
        &self.layers[i]
    }

    /// One layer's database (mutable: inserts, admissions, eviction).
    pub fn layer_mut(&mut self, i: usize) -> &mut LayerDb {
        &mut self.layers[i]
    }

    /// Number of per-layer databases.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Entries per f32 APM payload.
    pub fn apm_elems(&self) -> usize {
        self.apm_elems
    }

    /// Dimensionality of the embedding feature vectors.
    pub fn embed_dim(&self) -> usize {
        self.embed_dim
    }

    /// Total live entries across layers.
    pub fn total_entries(&self) -> usize {
        self.layers.iter().map(LayerDb::len).sum()
    }

    /// Total resident payload bytes (the paper's "pre-populated DB size").
    pub fn resident_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.arena().resident_bytes()).sum()
    }

    /// Bulk-insert a layer's batch of (features [n, d], apms [n, elems]).
    pub fn insert_batch(&mut self, layer: usize, features: &[f32],
                        apms: &[f32]) -> Result<Vec<ApmId>> {
        let d = self.embed_dim;
        let e = self.apm_elems;
        if features.len() % d != 0 || apms.len() % e != 0
            || features.len() / d != apms.len() / e
        {
            return Err(Error::memo(format!(
                "insert_batch: {} features vs {} apms",
                features.len() / d,
                apms.len() / e
            )));
        }
        let n = features.len() / d;
        let ldb = &mut self.layers[layer];
        (0..n)
            .map(|i| ldb.insert(&features[i * d..(i + 1) * d],
                                &apms[i * e..(i + 1) * e]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn cfg() -> ModelConfig {
        ModelConfig {
            family: "bert".into(),
            vocab_size: 256,
            hidden: 32,
            layers: 2,
            heads: 2,
            ffn: 64,
            max_len: 16,
            num_classes: 2,
            rel_pos_buckets: 8,
            embed_dim: 8,
            embed_hidden: 16,
            embed_segments: 4,
            causal: false,
        }
    }

    fn unit(rng: &mut Pcg32, d: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..d).map(|_| rng.next_gaussian()).collect();
        let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        v.iter_mut().for_each(|x| *x /= n);
        v
    }

    #[test]
    fn insert_and_lookup_self() {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 16, HnswParams::default());
        let mut rng = Pcg32::seeded(1);
        let elems = c.apm_elems(16);
        let mut feats = Vec::new();
        for _ in 0..20 {
            let f = unit(&mut rng, c.embed_dim);
            let apm = vec![1.0 / 16.0; elems];
            db.layer_mut(0).insert(&f, &apm).unwrap();
            feats.push(f);
        }
        let hit = db.layer(0).lookup(&feats[7], 32).unwrap();
        assert_eq!(hit.id, ApmId(7));
        assert!(hit.similarity > 0.999, "{}", hit.similarity);
    }

    #[test]
    fn batch_insert_validates_counts() {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 16, HnswParams::default());
        let d = c.embed_dim;
        let e = c.apm_elems(16);
        assert!(db.insert_batch(0, &vec![0.0; 2 * d], &vec![0.0; e]).is_err());
        let ids = db
            .insert_batch(1, &vec![0.1; 2 * d], &vec![0.0; 2 * e])
            .unwrap();
        assert_eq!(ids.len(), 2);
        assert_eq!(db.total_entries(), 2);
    }

    #[test]
    fn reuse_counters() {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 16, HnswParams::default());
        let f = vec![0.5; c.embed_dim];
        let apm = vec![0.0; c.apm_elems(16)];
        let id = db.layer_mut(0).insert(&f, &apm).unwrap();
        db.layer(0).mark_reused(id);
        db.layer(0).mark_reused(id);
        assert_eq!(db.layer(0).reuse_counts(), vec![2]);
    }

    #[test]
    fn empty_lookup_is_none() {
        let c = cfg();
        let db = AttentionDb::new(&c, 16, HnswParams::default());
        assert!(db.layer(0).lookup(&vec![0.0; c.embed_dim], 16).is_none());
    }

    #[test]
    fn admission_respects_capacity() {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 16, HnswParams::default());
        let mut rng = Pcg32::seeded(9);
        let elems = c.apm_elems(16);
        let cap = 5usize;
        for i in 0..20 {
            let f = unit(&mut rng, c.embed_dim);
            let out = db
                .layer_mut(0)
                .admit(&f, &vec![i as f32; elems], cap)
                .unwrap();
            assert!(db.layer(0).len() <= cap, "occupancy over budget");
            if i >= cap {
                assert!(!out.evicted.is_empty(), "at capacity must evict");
            }
        }
        assert_eq!(db.layer(0).len(), cap);
        // Every live id resolves; every evicted id is dead.
        for id in db.layer(0).live_ids() {
            db.layer(0).arena().get(id).unwrap();
        }
        assert!(db.layer(0).arena().get(ApmId(0)).is_err());
    }

    #[test]
    fn eviction_prefers_never_reused_entries() {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 16, HnswParams::default());
        let mut rng = Pcg32::seeded(11);
        let elems = c.apm_elems(16);
        let cap = 4usize;
        let mut hot = None;
        for i in 0..cap {
            let f = unit(&mut rng, c.embed_dim);
            let id = db.layer_mut(0).admit(&f, &vec![0.0; elems], cap)
                .unwrap().id;
            if i == 1 {
                hot = Some(id);
            }
        }
        // Heavily reuse one entry, then admit twice over budget: the cold
        // entries must go first, the hot one must survive.
        let hot = hot.unwrap();
        for _ in 0..3 {
            db.layer(0).mark_reused(hot);
        }
        let mut evicted = Vec::new();
        for _ in 0..2 {
            let f = unit(&mut rng, c.embed_dim);
            evicted.extend(
                db.layer_mut(0).admit(&f, &vec![1.0; elems], cap)
                    .unwrap().evicted,
            );
        }
        assert_eq!(evicted.len(), 2);
        assert!(!evicted.contains(&hot), "reused entry evicted first");
        assert!(db.layer(0).arena().is_live(hot));
    }

    /// The two-tier spill hook: an over-budget admission hands each
    /// victim's stored feature and payload to the demotion sink before
    /// the eviction frees its slot.
    #[test]
    fn admit_demoting_captures_victims_before_eviction() {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 16, HnswParams::default());
        let mut rng = Pcg32::seeded(29);
        let elems = c.apm_elems(16);
        let cap = 3usize;
        let mut feats = Vec::new();
        for i in 0..cap {
            let f = unit(&mut rng, c.embed_dim);
            db.layer_mut(0)
                .admit(&f, &vec![i as f32; elems], cap)
                .unwrap();
            feats.push(f);
        }
        let mut demoted: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
        let f = unit(&mut rng, c.embed_dim);
        let out = db
            .layer_mut(0)
            .admit_demoting(&f, &vec![9.0; elems], cap, &mut |df, da| {
                demoted.push((df.to_vec(), da.to_vec()))
            })
            .unwrap();
        assert_eq!(out.evicted.len(), 1);
        assert_eq!(demoted.len(), 1);
        let (df, da) = &demoted[0];
        assert_eq!(da, &vec![0.0f32; elems],
                   "victim payload captured intact");
        assert_eq!(df, &feats[0], "victim feature captured intact");
        assert_eq!(db.layer(0).len(), cap);
        assert!(!db.layer(0).arena().is_live(out.evicted[0]),
                "victim slot freed after capture");
    }

    #[test]
    fn churn_compacts_id_space() {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 16, HnswParams::default());
        let mut rng = Pcg32::seeded(17);
        let elems = c.apm_elems(16);
        let cap = 8usize;
        for i in 0..10 * COMPACT_MIN_IDS {
            let f = unit(&mut rng, c.embed_dim);
            db.layer_mut(0).admit(&f, &vec![i as f32; elems], cap).unwrap();
        }
        let layer = db.layer(0);
        assert_eq!(layer.len(), cap);
        // The id space stays bounded near the compaction threshold instead
        // of growing with total admissions (640 here).
        assert!((layer.arena().next_id() as usize) <= COMPACT_MIN_IDS + cap,
                "id space {} not compacted", layer.arena().next_id());
        // Entries stay self-consistent across rebuilds.
        for id in layer.live_ids() {
            layer.arena().get(id).unwrap();
            let v = layer.index_vector(id).to_vec();
            let hit = layer.lookup(&v, 48).unwrap();
            assert_eq!(hit.id, id);
        }
    }

    #[test]
    fn churn_keeps_dead_links_bounded_without_manual_compact() {
        // Regime where the 4×-span wholesale rebuild in `admit_demoting`
        // can never fire (span stays below 4 × capacity), so the only
        // mechanism reclaiming tombstoned neighbour links is the
        // churn-triggered `Hnsw::compact`. Removing that trigger makes
        // this test fail: no reset is ever observed and the dead-link
        // count grows with total admissions.
        let c = cfg();
        let mut db = AttentionDb::new(&c, 16, HnswParams::default());
        let mut rng = Pcg32::seeded(23);
        let elems = c.apm_elems(16);
        let cap = 100usize;
        let total = 390usize; // span < 4 * cap throughout
        let threshold = COMPACT_MIN_IDS.max(cap) as u64;
        let mut resets = 0usize;
        let mut prev_counter = 0u64;
        for i in 0..total {
            let f = unit(&mut rng, c.embed_dim);
            db.layer_mut(0).admit(&f, &vec![i as f32; elems], cap).unwrap();
            let layer = db.layer(0);
            let counter = layer.index_dead_since_compact();
            // Bounded: the trigger fires the moment the counter reaches
            // the threshold, so it can never exceed it between admits.
            assert!(counter <= threshold,
                    "dead counter {} above trigger threshold {}",
                    counter, threshold);
            if counter < prev_counter {
                // The in-place link compaction just ran: every dead id
                // has been swept from the live neighbour lists.
                assert_eq!(layer.index_dead_link_slots(), 0,
                           "links not swept at reset");
                resets += 1;
            }
            prev_counter = counter;
        }
        // (total - cap) evictions with a reclaim every `threshold`:
        // sustained churn fires the trigger repeatedly on its own.
        assert!(resets >= 2, "link compaction fired {} times", resets);
        // No wholesale rebuild happened (ids were never renumbered), so
        // the resets above really came from the in-place sweep.
        let layer = db.layer(0);
        assert_eq!(layer.arena().next_id() as usize, total);
        assert_eq!(layer.len(), cap);
    }

    /// The concurrent-eviction regression (satellite fix): a lookup result
    /// held across an eviction/compaction in the same shard must never
    /// resolve to a reused slot's fresh bytes — the epoch stamp must turn
    /// the fetch into an error instead.
    #[test]
    fn stale_lookup_stamp_never_reads_reused_slot() {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 16, HnswParams::default());
        let mut rng = Pcg32::seeded(23);
        let elems = c.apm_elems(16);
        let mut feats = Vec::new();
        for i in 0..4 {
            let f = unit(&mut rng, c.embed_dim);
            db.layer_mut(0).insert(&f, &vec![i as f32; elems]).unwrap();
            feats.push(f);
        }
        let stale = db.layer(0).lookup(&feats[3], 32).unwrap();
        assert_eq!(stale.id, ApmId(3));

        // Evict everything else, compact (renumber), then refill: the old
        // id 3 becomes live again with a *different* entry's payload.
        for id in [0, 1, 2] {
            db.layer_mut(0).evict(ApmId(id)).unwrap();
        }
        db.layer_mut(0).compact().unwrap();
        for i in 0..3 {
            let f = unit(&mut rng, c.embed_dim);
            db.layer_mut(0)
                .insert(&f, &vec![100.0 + i as f32; elems])
                .unwrap();
        }
        let layer = db.layer(0);
        assert!(layer.arena().is_live(stale.id),
                "id renumbered onto a different live entry");
        // Unchecked read would serve foreign bytes; the checked read errs.
        assert_ne!(layer.arena().get(stale.id).unwrap()[0], 3.0);
        assert!(layer.arena().get_checked(stale.id, stale.epoch).is_err());
        // A fresh lookup fetches consistently.
        let fresh = layer.lookup(&feats[3], 32).unwrap();
        assert_eq!(
            layer.arena().get_checked(fresh.id, fresh.epoch).unwrap()[0],
            3.0
        );
    }

    #[test]
    fn warm_bits_track_snapshot_epochs() {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 16, HnswParams::default());
        let f = vec![0.5; c.embed_dim];
        let apm = vec![0.0; c.apm_elems(16)];
        let a = db.layer_mut(0).insert(&f, &apm).unwrap();
        let b = db.layer_mut(0).insert(&f, &apm).unwrap();
        assert_eq!(db.layer(0).warm_bits(), vec![1, 1],
                   "fresh entries start warm");
        // A snapshot epoch clears the bits; only touched entries re-warm.
        db.layer(0).clear_warm_bits();
        assert_eq!(db.layer(0).warm_bits(), vec![0, 0]);
        db.layer(0).mark_reused(a);
        assert_eq!(db.layer(0).warm_bits(), vec![1, 0]);
        let _ = b;
        // Compaction carries the bits over with the surviving entries.
        db.layer_mut(0).compact().unwrap();
        assert_eq!(db.layer(0).warm_bits(), vec![1, 0]);
    }

    #[test]
    fn restored_entries_carry_reuse_state() {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 16, HnswParams::default());
        let f = vec![0.5; c.embed_dim];
        let apm = vec![0.0; c.apm_elems(16)];
        db.layer_mut(0).insert_restored(&f, &apm, 7, 9).unwrap();
        assert_eq!(db.layer(0).reuse_counts(), vec![7]);
        assert_eq!(db.layer(0).reuse_refs(), vec![3], "refs saturate at 3");
    }

    #[test]
    fn evicted_ids_stop_matching_lookup() {
        let c = cfg();
        let mut db = AttentionDb::new(&c, 16, HnswParams::default());
        let mut rng = Pcg32::seeded(13);
        let elems = c.apm_elems(16);
        let f0 = unit(&mut rng, c.embed_dim);
        let id0 = db.layer_mut(0).insert(&f0, &vec![0.0; elems]).unwrap();
        let f1 = unit(&mut rng, c.embed_dim);
        db.layer_mut(0).insert(&f1, &vec![1.0; elems]).unwrap();
        db.layer_mut(0).evict(id0).unwrap();
        let hit = db.layer(0).lookup(&f0, 32).unwrap();
        assert_ne!(hit.id, id0, "evicted id must not match");
        // The freed slot's next tenant gets a fresh id and exact lookup.
        let f2 = unit(&mut rng, c.embed_dim);
        let id2 = db.layer_mut(0).insert(&f2, &vec![2.0; elems]).unwrap();
        assert_eq!(id2, ApmId(2));
        let hit2 = db.layer(0).lookup(&f2, 32).unwrap();
        assert_eq!(hit2.id, id2);
        assert_eq!(db.layer(0).arena().get(id2).unwrap(), &vec![2.0; elems][..]);
    }

    /// The seqlock tier's snapshot unit: a `cow_clone` must freeze the
    /// view (index hits, live set, payload bytes) while the original
    /// mutates, and reuse marked through either side must land on the
    /// shared clock.
    #[test]
    fn cow_clone_freezes_view_and_shares_reuse() {
        let c = cfg();
        let mut db = LayerDb::new(&c, 16, HnswParams::default());
        db.set_defer_free(true);
        let mut rng = Pcg32::seeded(51);
        let elems = c.apm_elems(16);
        let f0 = unit(&mut rng, c.embed_dim);
        let f1 = unit(&mut rng, c.embed_dim);
        let id0 = db.insert(&f0, &vec![0.0; elems]).unwrap();
        let snap = db.cow_clone();
        assert!(snap.is_on_store(&db.store_handle()));
        // Mutate the original: evict the entry, insert another.
        db.evict(id0).unwrap();
        let id1 = db.insert(&f1, &vec![1.0; elems]).unwrap();
        // The snapshot still serves the pre-mutation view, bytes intact.
        assert!(snap.arena().is_live(id0));
        let hit = snap.lookup(&f0, 32).unwrap();
        assert_eq!(hit.id, id0);
        assert_eq!(
            snap.arena().get_checked(hit.id, hit.epoch).unwrap(),
            &vec![0.0; elems][..]
        );
        assert!(snap.lookup(&f1, 32).map_or(true, |h| h.id == id0),
                "snapshot must not see the post-snapshot insert");
        // The live side sees the new state.
        assert!(!db.arena().is_live(id0));
        assert_eq!(db.lookup(&f1, 32).unwrap().id, id1);
        // Reuse marked through the snapshot feeds the shared clock.
        snap.mark_reused(id1);
        assert_eq!(db.reuse_counts()[id1.0 as usize], 1,
                   "snapshot reuse marks must reach the shared track");
    }
}
