//! Memoization thresholds (paper Table 2).
//!
//! The paper pins per-model absolute thresholds on the Eq. 1 similarity
//! scale. Our thresholds apply to the *search-estimated* similarity
//! `1 − ‖e(q) − e(db)‖₂` returned by the index database, whose scale
//! depends on the trained embedder; so the per-family defaults here are
//! expressed as quantiles calibrated during DB building (`DbBuilder`
//! records the distance distribution) with Table 2-like spacing between
//! the three levels. A fixed absolute override is available for
//! experiments that sweep the threshold explicitly (Fig. 4).

use crate::config::MemoLevel;

/// Calibrated thresholds for one family.
#[derive(Debug, Clone, Copy)]
pub struct Thresholds {
    /// Strictest level: admits only the most similar lookups.
    pub conservative: f32,
    /// The default middle ground.
    pub moderate: f32,
    /// Loosest level: maximum memoization rate, most accuracy risk.
    pub aggressive: f32,
}

impl Thresholds {
    /// Threshold for a level (`Off` returns +∞ so nothing ever memoizes).
    pub fn for_level(&self, level: MemoLevel) -> f32 {
        match level {
            MemoLevel::Off => f32::INFINITY,
            MemoLevel::Conservative => self.conservative,
            MemoLevel::Moderate => self.moderate,
            MemoLevel::Aggressive => self.aggressive,
        }
    }

    /// Calibrate from a sample of estimated similarities observed between
    /// training queries and their nearest database entries.
    ///
    /// Conservative admits roughly the top 30% most-similar lookups,
    /// moderate ~50%, aggressive ~70% — mirroring the relative spacing the
    /// paper's absolute values produce on its models (Table 2 / Fig. 4).
    pub fn calibrate(mut sims: Vec<f32>) -> Thresholds {
        if sims.is_empty() {
            // No data: thresholds that admit only near-exact matches.
            return Thresholds {
                conservative: 0.95,
                moderate: 0.9,
                aggressive: 0.85,
            };
        }
        sims.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |frac: f64| -> f32 {
            let idx = ((sims.len() - 1) as f64 * frac).round() as usize;
            sims[idx]
        };
        Thresholds {
            conservative: q(0.70),
            moderate: q(0.50),
            aggressive: q(0.30),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_are_ordered() {
        let sims: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        let t = Thresholds::calibrate(sims);
        assert!(t.conservative >= t.moderate);
        assert!(t.moderate >= t.aggressive);
    }

    #[test]
    fn off_never_memoizes() {
        let t = Thresholds::calibrate(vec![0.5; 10]);
        assert_eq!(t.for_level(MemoLevel::Off), f32::INFINITY);
        assert!(t.for_level(MemoLevel::Aggressive).is_finite());
    }

    #[test]
    fn empty_calibration_is_conservative() {
        let t = Thresholds::calibrate(vec![]);
        assert!(t.conservative > t.aggressive);
        assert!(t.conservative >= 0.9);
    }

    #[test]
    fn quantiles_admit_expected_fractions() {
        let sims: Vec<f32> = (0..1000).map(|i| i as f32 / 1000.0).collect();
        let t = Thresholds::calibrate(sims.clone());
        let admitted = |thr: f32| {
            sims.iter().filter(|&&s| s >= thr).count() as f64
                / sims.len() as f64
        };
        assert!((admitted(t.conservative) - 0.30).abs() < 0.02);
        assert!((admitted(t.moderate) - 0.50).abs() < 0.02);
        assert!((admitted(t.aggressive) - 0.70).abs() < 0.02);
    }
}
