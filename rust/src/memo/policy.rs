//! Selective memoization (paper §5.4, Eq. 3).
//!
//! Per layer i: `PBᵢ = T_attnᵢ · αᵢ − T_overheadᵢ`; memoization is
//! attempted only where `PBᵢ > 0`. `T_attn` (score-computation time),
//! `T_overhead` (embedding + search + mapping) and `α` (layer hit rate)
//! are measured offline on the training set by `DbBuilder`, then scaled
//! online by the ratio of inference-batch token count to profiled token
//! count (the paper's linear-scaling rule).

/// Offline profile of one layer.
#[derive(Debug, Clone, Copy)]
pub struct LayerProfile {
    /// Seconds to compute attention scores for the profiled token count.
    pub t_attn: f64,
    /// Seconds of memoization overhead (embed + search + map) for the same.
    pub t_overhead: f64,
    /// Seconds of the memoized remainder (`attn_apply`).
    pub t_apply: f64,
    /// Seconds of the fused non-memoized layer (`layer_full`).
    pub t_fused: f64,
    /// Layer memoization rate α measured on the training set.
    pub alpha: f64,
    /// Token count the timings were measured over (batch × seq).
    pub profiled_tokens: u64,
}

impl LayerProfile {
    /// Fused-aware Eq. 3 (§Perf extension, see DESIGN.md): the paper's
    /// `PB = T_attn·α − T_overhead` assumes skipping scores is the whole
    /// story; on this runtime the non-memoized path is a *fused* kernel
    /// that is cheaper than split scores+apply, so the honest benefit is
    ///
    ///   PB = T_fused − (T_overhead + (1−α)·T_attn + T_apply)
    ///
    /// which reduces to the paper's form when T_fused ≈ T_attn + T_apply.
    pub fn benefit(&self, tokens: u64) -> f64 {
        let scale = if self.profiled_tokens == 0 {
            1.0
        } else {
            tokens as f64 / self.profiled_tokens as f64
        };
        let memo_cost =
            self.t_overhead + (1.0 - self.alpha) * self.t_attn + self.t_apply;
        (self.t_fused - memo_cost) * scale
    }
}

/// The per-layer decision table.
#[derive(Debug, Clone)]
pub struct SelectivePolicy {
    layers: Vec<LayerProfile>,
    /// Disabled ⇒ always attempt (the "no selective memoization" baseline).
    pub enabled: bool,
}

impl SelectivePolicy {
    /// Policy over explicit per-layer profiles.
    pub fn new(layers: Vec<LayerProfile>, enabled: bool) -> Self {
        SelectivePolicy { layers, enabled }
    }

    /// Policy that always attempts memoization (profile-free).
    pub fn always(num_layers: usize) -> Self {
        SelectivePolicy {
            layers: vec![
                LayerProfile {
                    t_attn: 1.0,
                    t_overhead: 0.0,
                    t_apply: 0.0,
                    t_fused: 2.0,
                    alpha: 1.0,
                    profiled_tokens: 1,
                };
                num_layers
            ],
            enabled: false,
        }
    }

    /// The per-layer Eq. 3 profiles backing the decisions.
    pub fn profiles(&self) -> &[LayerProfile] {
        &self.layers
    }

    /// Should layer `i` attempt memoization for a batch of `tokens`?
    pub fn attempt(&self, layer: usize, tokens: u64) -> bool {
        if !self.enabled {
            return true;
        }
        self.layers
            .get(layer)
            .map_or(true, |p| p.benefit(tokens) > 0.0)
    }

    /// Layers that would attempt at a given token count.
    pub fn active_layers(&self, tokens: u64) -> Vec<usize> {
        (0..self.layers.len())
            .filter(|&i| self.attempt(i, tokens))
            .collect()
    }
}

/// Serve-time admission gate for the online attention database.
///
/// Admission costs the split path (scores are computed for misses anyway,
/// but the layer forgoes the cheaper fused kernel), so it is only worth
/// doing on layers where memoization can eventually pay. The gate applies
/// the paper's selective-memoization logic (Eq. 3) with an *optimistic*
/// hit rate: during a per-layer warm-up window it always admits (there is
/// no signal yet), after which it admits only when the layer's profiled
/// benefit at `α = 1` — the best case a warmed database can reach — is
/// positive. A layer whose overhead exceeds its attention saving can never
/// profit, so it never grows a database.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionPolicy {
    /// Master switch (mirrors `MemoConfig::online_admission`).
    pub enabled: bool,
    /// Per-layer attempts to observe before the Eq. 3 gate activates.
    pub min_attempts: u64,
}

impl AdmissionPolicy {
    /// Gate with an explicit switch and warm-up window.
    ///
    /// ```
    /// use attmemo::memo::AdmissionPolicy;
    /// let gate = AdmissionPolicy::new(true, 10);
    /// // Inside the warm-up window every layer admits…
    /// assert!(gate.should_admit(None, 5, 128));
    /// // …and a disabled gate never does.
    /// assert!(!AdmissionPolicy::new(false, 0).should_admit(None, 0, 128));
    /// ```
    pub fn new(enabled: bool, min_attempts: u64) -> Self {
        AdmissionPolicy { enabled, min_attempts }
    }

    /// Should a layer admit its freshly computed miss APMs?
    ///
    /// `profile` is the layer's offline Eq. 3 profile (`None` for
    /// profile-free engines, e.g. a cold start without a built database —
    /// those always admit once enabled), `attempts` the layer's lookups so
    /// far, `tokens` the batch token count for profile scaling.
    pub fn should_admit(&self, profile: Option<&LayerProfile>, attempts: u64,
                        tokens: u64) -> bool {
        if !self.enabled {
            return false;
        }
        if attempts < self.min_attempts {
            return true;
        }
        profile.map_or(true, |p| {
            LayerProfile { alpha: 1.0, ..*p }.benefit(tokens) > 0.0
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prof(t_attn: f64, t_overhead: f64, alpha: f64) -> LayerProfile {
        // t_fused = t_attn + t_apply reduces the fused-aware form to the
        // paper's `PB = t_attn·α − t_overhead`.
        LayerProfile { t_attn, t_overhead, t_apply: 0.5, t_fused: 1.5,
                       alpha, profiled_tokens: 1000 }
    }

    #[test]
    fn eq3_sign_drives_decision() {
        // with t_fused = t_attn + t_apply: benefit = t_attn*alpha - t_overhead
        let pol = SelectivePolicy::new(
            vec![
                prof(1.0, 0.2, 0.5), // 0.3 > 0 → attempt
                prof(1.0, 0.6, 0.5), // -0.1 < 0 → skip
                prof(1.0, 0.5, 0.5), // 0 → skip (strict >)
            ],
            true,
        );
        assert!(pol.attempt(0, 1000));
        assert!(!pol.attempt(1, 1000));
        assert!(!pol.attempt(2, 1000));
        assert_eq!(pol.active_layers(1000), vec![0]);
    }

    #[test]
    fn fused_advantage_disables_low_alpha_layers() {
        // A fast fused path (t_fused < split cost) demands higher alpha.
        let p = LayerProfile { t_attn: 1.0, t_overhead: 0.05, t_apply: 0.25,
                               t_fused: 1.0, alpha: 0.2,
                               profiled_tokens: 1000 };
        assert!(p.benefit(1000) < 0.0);
        let p2 = LayerProfile { alpha: 0.9, ..p };
        assert!(p2.benefit(1000) > 0.0);
    }

    #[test]
    fn scaling_is_sign_preserving() {
        // Linear scaling multiplies both terms; the decision must not flip
        // with token count.
        let pol = SelectivePolicy::new(vec![prof(1.0, 0.6, 0.5)], true);
        assert!(!pol.attempt(0, 10));
        assert!(!pol.attempt(0, 1_000_000));
    }

    #[test]
    fn disabled_policy_always_attempts() {
        let pol = SelectivePolicy::new(vec![prof(1.0, 9.0, 0.1)], false);
        assert!(pol.attempt(0, 1000));
        let pol2 = SelectivePolicy::always(3);
        assert_eq!(pol2.active_layers(1), vec![0, 1, 2]);
    }

    #[test]
    fn out_of_range_layer_defaults_to_attempt() {
        let pol = SelectivePolicy::new(vec![], true);
        assert!(pol.attempt(5, 100));
    }

    #[test]
    fn admission_disabled_never_admits() {
        let gate = AdmissionPolicy::new(false, 10);
        assert!(!gate.should_admit(None, 0, 100));
    }

    #[test]
    fn admission_warmup_always_admits() {
        let gate = AdmissionPolicy::new(true, 10);
        // Even a hopeless profile admits inside the warm-up window.
        let bad = prof(1.0, 5.0, 0.0);
        assert!(gate.should_admit(Some(&bad), 9, 1000));
        assert!(gate.should_admit(None, 0, 1000));
    }

    #[test]
    fn admission_gates_on_optimistic_benefit() {
        let gate = AdmissionPolicy::new(true, 0);
        // benefit(alpha=1) = t_attn - t_overhead (with t_fused = t_attn +
        // t_apply): positive overheads below t_attn admit, above never do.
        assert!(gate.should_admit(Some(&prof(1.0, 0.5, 0.0)), 100, 1000));
        assert!(!gate.should_admit(Some(&prof(1.0, 1.5, 0.9)), 100, 1000));
        // Profile-free engines admit whenever enabled.
        assert!(gate.should_admit(None, 100, 1000));
    }
}
